//! PJRT runtime: loads AOT HLO-text artifacts and executes them from the
//! Rust hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (the bundled xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-id serialized protos; the text parser
//! reassigns ids).  Python never runs on this path.

pub mod manifest;

pub use manifest::{ExecSpec, Manifest, TensorSpec};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

/// Flat input buffers for one training-step execution.
#[derive(Debug, Clone)]
pub struct StepInputs {
    /// f32[B,S,d] gathered input-side rows.
    pub syn0: Vec<f32>,
    /// f32[B,S,d] gathered output-side rows of sentence words.
    pub syn1: Vec<f32>,
    /// f32[B,S,N,d] gathered output-side rows of per-window negatives.
    pub neg: Vec<f32>,
    /// i32[B] true sentence lengths.
    pub lens: Vec<i32>,
    /// learning rate.
    pub lr: f32,
}

impl StepInputs {
    /// Allocate zeroed buffers for a spec (reused across batches).
    pub fn zeroed(spec: &ExecSpec) -> Self {
        StepInputs {
            syn0: vec![0.0; spec.b * spec.s * spec.d],
            syn1: vec![0.0; spec.b * spec.s * spec.d],
            neg: vec![0.0; spec.b * spec.s * spec.n * spec.d],
            lens: vec![0; spec.b],
            lr: 0.0,
        }
    }
}

/// Flat output buffers of one training-step execution.
#[derive(Debug, Clone)]
pub struct StepOutputs {
    pub d_syn0: Vec<f32>,
    pub d_syn1: Vec<f32>,
    pub d_neg: Vec<f32>,
    pub loss: Vec<f32>,
}

/// Cumulative executor statistics.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub exec_seconds: f64,
    pub compile_seconds: f64,
}

/// A compiled training-step executable.
pub struct TrainStep {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl TrainStep {
    /// Execute one batch.  Validates buffer sizes against the spec.
    pub fn run(&self, inp: &StepInputs) -> Result<StepOutputs> {
        let (b, s, d, n) = (self.spec.b, self.spec.s, self.spec.d, self.spec.n);
        anyhow::ensure!(
            inp.syn0.len() == b * s * d,
            "syn0 len {} != {}",
            inp.syn0.len(),
            b * s * d
        );
        anyhow::ensure!(inp.syn1.len() == b * s * d, "syn1 len mismatch");
        anyhow::ensure!(inp.neg.len() == b * s * n * d, "neg len mismatch");
        anyhow::ensure!(inp.lens.len() == b, "lens len mismatch");

        // single-copy marshaling (perf: Literal::vec1 + reshape would copy
        // each buffer twice — EXPERIMENTS.md §Perf iteration 1)
        let f32_lit = |data: &[f32], dims: &[usize]| {
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                dims,
                bytemuck_f32(data),
            )
        };
        let syn0 = f32_lit(&inp.syn0, &[b, s, d])?;
        let syn1 = f32_lit(&inp.syn1, &[b, s, d])?;
        let neg = f32_lit(&inp.neg, &[b, s, n, d])?;
        let lens = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[b],
            bytemuck_i32(&inp.lens),
        )?;
        let lr = xla::Literal::scalar(inp.lr);

        let result = self
            .exe
            .execute::<xla::Literal>(&[syn0, syn1, neg, lens, lr])?[0][0]
            .to_literal_sync()?;
        let (o0, o1, o2, o3) = result.to_tuple4()?;
        Ok(StepOutputs {
            d_syn0: o0.to_vec::<f32>()?,
            d_syn1: o1.to_vec::<f32>()?,
            d_neg: o2.to_vec::<f32>()?,
            loss: o3.to_vec::<f32>()?,
        })
    }
}

/// View an f32 slice as bytes (native endianness; XLA literals are host
/// layout).
fn bytemuck_f32(data: &[f32]) -> &[u8] {
    // SAFETY: any f32 bit pattern is a valid byte sequence, u8 alignment
    // is 1, and len * 4 covers exactly the source allocation; the
    // borrow keeps the source alive for the view's lifetime.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    }
}

fn bytemuck_i32(data: &[i32]) -> &[u8] {
    // SAFETY: same argument as bytemuck_f32 — plain-old-data reinterpret
    // to the alignment-1 u8, length covering exactly the source slice.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    }
}

/// The PJRT engine: one client + a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, std::sync::Arc<TrainStep>>,
    stats: ExecStats,
}

impl Engine {
    /// Create a CPU PJRT engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
            stats: ExecStats::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    /// Load + compile an executable by manifest name (cached).
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<TrainStep>> {
        if let Some(step) = self.cache.get(name) {
            return Ok(step.clone());
        }
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| {
                anyhow!(
                    "executable '{name}' not in manifest (have: {})",
                    self.manifest
                        .executables
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?
            .clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))
            .with_context(|| format!("artifact {}", spec.file.display()))?;
        self.stats.compile_seconds += t0.elapsed().as_secs_f64();
        let step = std::sync::Arc::new(TrainStep { spec, exe });
        self.cache.insert(name.to_string(), step.clone());
        Ok(step)
    }

    /// Execute a loaded step, accounting stats.
    pub fn run(&mut self, step: &TrainStep, inp: &StepInputs) -> Result<StepOutputs> {
        let t0 = Instant::now();
        let out = step.run(inp)?;
        self.stats.executions += 1;
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! Engine round-trip tests live in `rust/tests/` (they need built
    //! artifacts); here we cover the pure helpers.
    use super::*;

    fn spec() -> ExecSpec {
        ExecSpec {
            name: "t".into(),
            variant: "full_w2v".into(),
            file: "/dev/null".into(),
            b: 2,
            s: 8,
            d: 4,
            n: 2,
            wf: 2,
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn zeroed_inputs_sized_from_spec() {
        let inp = StepInputs::zeroed(&spec());
        assert_eq!(inp.syn0.len(), 64);
        assert_eq!(inp.syn1.len(), 64);
        assert_eq!(inp.neg.len(), 128);
        assert_eq!(inp.lens.len(), 2);
    }
}
