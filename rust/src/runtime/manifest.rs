//! Artifact manifest: the machine-readable index `python/compile/aot.py`
//! writes next to the HLO text files.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Tensor descriptor (dtype + shape) from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: str_field(v, "name")?,
            dtype: str_field(v, "dtype")?,
            shape: v
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }
}

/// One AOT executable's description.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub variant: String,
    pub file: PathBuf,
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub n: usize,
    pub wf: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub executables: Vec<ExecSpec>,
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("missing string field '{key}'"))
}

fn usize_field(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing integer field '{key}'"))
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let doc = Json::parse(text).context("parsing manifest.json")?;
        let format = usize_field(&doc, "format")?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let interchange = str_field(&doc, "interchange")?;
        if interchange != "hlo-text" {
            bail!("unsupported interchange '{interchange}'");
        }
        let mut executables = Vec::new();
        for e in doc
            .get("executables")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing executables"))?
        {
            let spec = ExecSpec {
                name: str_field(e, "name")?,
                variant: str_field(e, "variant")?,
                file: dir.join(str_field(e, "file")?),
                b: usize_field(e, "b")?,
                s: usize_field(e, "s")?,
                d: usize_field(e, "d")?,
                n: usize_field(e, "n")?,
                wf: usize_field(e, "wf")?,
                inputs: e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing inputs"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing outputs"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            };
            spec.validate()?;
            executables.push(spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), executables })
    }

    pub fn find(&self, name: &str) -> Option<&ExecSpec> {
        self.executables.iter().find(|e| e.name == name)
    }

    /// All executables of a given kernel variant.
    pub fn by_variant(&self, variant: &str) -> Vec<&ExecSpec> {
        self.executables.iter().filter(|e| e.variant == variant).collect()
    }
}

impl ExecSpec {
    /// Check the I/O contract matches what the coordinator expects
    /// (DESIGN.md Section 8) so shape bugs fail at load, not at scatter.
    pub fn validate(&self) -> Result<()> {
        let (b, s, d, n) = (self.b, self.s, self.d, self.n);
        let want_inputs = [
            ("syn0", "f32", vec![b, s, d]),
            ("syn1", "f32", vec![b, s, d]),
            ("neg", "f32", vec![b, s, n, d]),
            ("lens", "i32", vec![b]),
            ("lr", "f32", vec![]),
        ];
        if self.inputs.len() != want_inputs.len() {
            bail!("{}: expected 5 inputs, got {}", self.name, self.inputs.len());
        }
        for (got, (name, dtype, shape)) in self.inputs.iter().zip(&want_inputs)
        {
            if got.name != *name || got.dtype != *dtype || got.shape != *shape
            {
                bail!(
                    "{}: input mismatch: got {:?}, want ({name}, {dtype}, {shape:?})",
                    self.name,
                    got
                );
            }
        }
        let want_outputs = [
            ("d_syn0", vec![b, s, d]),
            ("d_syn1", vec![b, s, d]),
            ("d_neg", vec![b, s, n, d]),
            ("loss", vec![b]),
        ];
        if self.outputs.len() != want_outputs.len() {
            bail!("{}: expected 4 outputs", self.name);
        }
        for (got, (name, shape)) in self.outputs.iter().zip(&want_outputs) {
            if got.name != *name || got.shape != *shape {
                bail!("{}: output mismatch: {:?}", self.name, got);
            }
        }
        if self.s < 2 * self.wf + 1 {
            bail!("{}: S < 2*Wf+1", self.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, b: usize) -> String {
        let (s, d, n) = (8, 4, 2);
        format!(
            r#"{{"name":"{name}","variant":"full_w2v","file":"{name}.hlo.txt",
              "b":{b},"s":{s},"d":{d},"n":{n},"wf":2,
              "inputs":[
               {{"name":"syn0","dtype":"f32","shape":[{b},{s},{d}]}},
               {{"name":"syn1","dtype":"f32","shape":[{b},{s},{d}]}},
               {{"name":"neg","dtype":"f32","shape":[{b},{s},{n},{d}]}},
               {{"name":"lens","dtype":"i32","shape":[{b}]}},
               {{"name":"lr","dtype":"f32","shape":[]}}],
              "outputs":[
               {{"name":"d_syn0","dtype":"f32","shape":[{b},{s},{d}]}},
               {{"name":"d_syn1","dtype":"f32","shape":[{b},{s},{d}]}},
               {{"name":"d_neg","dtype":"f32","shape":[{b},{s},{n},{d}]}},
               {{"name":"loss","dtype":"f32","shape":[{b}]}}]}}"#
        )
    }

    fn doc(entries: &[String]) -> String {
        format!(
            r#"{{"format":1,"interchange":"hlo-text","executables":[{}]}}"#,
            entries.join(",")
        )
    }

    #[test]
    fn parses_valid_manifest() {
        let text = doc(&[entry("k1", 2), entry("k2", 4)]);
        let m = Manifest::parse(Path::new("/tmp/a"), &text).unwrap();
        assert_eq!(m.executables.len(), 2);
        let e = m.find("k1").unwrap();
        assert_eq!(e.b, 2);
        assert_eq!(e.inputs[2].shape, vec![2, 8, 2, 4]);
        assert_eq!(e.file, Path::new("/tmp/a/k1.hlo.txt"));
        assert_eq!(m.by_variant("full_w2v").len(), 2);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let text = r#"{"format":2,"interchange":"hlo-text","executables":[]}"#;
        assert!(Manifest::parse(Path::new("."), text).is_err());
        let text = r#"{"format":1,"interchange":"proto","executables":[]}"#;
        assert!(Manifest::parse(Path::new("."), text).is_err());
    }

    #[test]
    fn rejects_io_contract_violation() {
        // wrong neg shape: swap n and d
        let bad = entry("k", 2).replace("[2,8,2,4]", "[2,8,4,2]");
        let text = doc(&[bad]);
        assert!(Manifest::parse(Path::new("."), &text).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.executables.is_empty());
            assert!(m.find("full_w2v_b64_s32_d128_n5_w3").is_some());
            for e in &m.executables {
                assert!(e.file.exists(), "missing {}", e.file.display());
            }
        }
    }
}
