//! CPU roofline model: judge the `vecops` kernels against the
//! hardware's memory-bound ceiling, not just against last week's
//! scalar numbers.
//!
//! This is the CPU edition of the paper's Figure 1 argument.  Each
//! kernel has a fixed *arithmetic intensity* (FLOPs per DRAM byte
//! streamed), so a roofline — peak FLOP/s from the active SIMD width
//! crossed with memory bandwidth — predicts an attainable ceiling per
//! kernel.  The reuse story shows up directly in the AI column: a
//! plain [`crate::vecops::dot`] does 2 flops per 8 streamed bytes
//! (AI 0.25, hopelessly memory-bound), while the Q=4 tile kernels feed
//! every streamed row element to four cache-resident query accumulators
//! (AI 2.0 for f32 rows, 8.0 for int8 rows) — the same lift the paper
//! gets from context-window and negative-sample reuse.
//!
//! Model inputs and their sources:
//!
//! * **Peak FLOP/s** — `clock_ghz x 2 x f32_lanes(level)`: one vector
//!   multiply plus one vector add per cycle (the kernels avoid FMA by
//!   bit-identity contract, so FMA throughput is deliberately *not*
//!   counted).  Clock comes from `FULLW2V_CPU_GHZ` or defaults to
//!   3.0 GHz.  For `scalar`, lanes = 1: the model scores explicit
//!   vectorization, so an autovectorized scalar build may legitimately
//!   exceed its nominal ceiling (`achieved_frac > 1`).
//! * **Memory bandwidth** — `FULLW2V_MEM_BW_GBS` if set, otherwise
//!   measured with a single-core two-stream dot over buffers well past
//!   LLC size.  Single-core, because the kernel microbenchmarks below
//!   are single-threaded too.
//!
//! [`measure_kernels`] runs the real dispatch-table kernels over a
//! DRAM-resident working set and reports achieved GFLOP/s against the
//! predicted ceiling; `bench_throughput` and `bench_serve` embed the
//! result in their `BENCH_*.json` artifacts (`"roofline"` section) so
//! every future kernel PR is judged against the same curve.

use crate::gpusim::Roofline;
use crate::util::benchkit;
use crate::util::json::{obj, Json};
use crate::vecops::{self, Dispatch, SimdLevel, Q_TILE};

/// CPU modeling parameters (the CPU sibling of `gpusim::ArchSpec`).
#[derive(Debug, Clone)]
pub struct CpuSpec {
    pub cores: usize,
    pub clock_ghz: f64,
    /// `"FULLW2V_CPU_GHZ"` or `"assumed"`.
    pub clock_source: &'static str,
    /// Single-core stream bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// `"FULLW2V_MEM_BW_GBS"` or `"measured"`.
    pub bw_source: &'static str,
}

impl CpuSpec {
    /// Pure constructor for tests and configured environments.
    pub fn with(cores: usize, clock_ghz: f64, mem_bw_gbs: f64) -> CpuSpec {
        CpuSpec {
            cores,
            clock_ghz,
            clock_source: "assumed",
            mem_bw_gbs,
            bw_source: "configured",
        }
    }

    /// Detect this host: core count from the OS, clock from
    /// `FULLW2V_CPU_GHZ` (default 3.0), bandwidth from
    /// `FULLW2V_MEM_BW_GBS` or a ~0.3 s single-core measurement.
    pub fn detect() -> CpuSpec {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let (clock_ghz, clock_source) = match env_f64("FULLW2V_CPU_GHZ") {
            Some(v) => (v, "FULLW2V_CPU_GHZ"),
            None => (3.0, "assumed"),
        };
        let (mem_bw_gbs, bw_source) = match env_f64("FULLW2V_MEM_BW_GBS") {
            Some(v) => (v, "FULLW2V_MEM_BW_GBS"),
            None => (measure_bandwidth_gbs(), "measured"),
        };
        CpuSpec { cores, clock_ghz, clock_source, mem_bw_gbs, bw_source }
    }

    /// Single-core peak GFLOP/s at a dispatch level: one vector
    /// multiply + one vector add per cycle, no FMA (see module docs).
    pub fn peak_gflops(&self, level: SimdLevel) -> f64 {
        self.clock_ghz * (2 * level.f32_lanes()) as f64
    }

    /// The single-core roofline curve at a dispatch level — the same
    /// [`Roofline`] type the GPU `ArchSpec`s produce.
    pub fn roofline(&self, level: SimdLevel) -> Roofline {
        Roofline {
            peak_gflops: self.peak_gflops(level),
            mem_bw_gbs: self.mem_bw_gbs,
        }
    }
}

fn env_f64(name: &str) -> Option<f64> {
    std::env::var(name).ok()?.trim().parse::<f64>().ok().filter(|v| *v > 0.0)
}

/// One kernel's fixed flop/byte shape (per streamed row element; see
/// [`kernel_shapes`] for the byte accounting).
#[derive(Debug, Clone, Copy)]
pub struct KernelShape {
    pub kernel: &'static str,
    pub flops_per_elem: f64,
    /// DRAM bytes streamed per element; operands that stay
    /// cache-resident across the pass (queries, the held vector) are
    /// not counted — that reuse is exactly what lifts AI.
    pub bytes_per_elem: f64,
}

impl KernelShape {
    pub fn ai(&self) -> f64 {
        self.flops_per_elem / self.bytes_per_elem
    }
}

/// The modeled kernels, in ascending-reuse order.  Byte accounting:
/// `dot`/`dot_f64` stream two f32 operands (8 B/elem); `dot_i8`
/// streams i8 codes + an f32 query (5 B/elem); `axpy` streams x and
/// does a read-modify-write of y (12 B/elem); the tile kernels stream
/// rows once while [`Q_TILE`] query vectors stay cache-resident, so
/// each row element (4 B f32, 1 B i8) feeds `2 x Q_TILE` flops.
pub fn kernel_shapes() -> [KernelShape; 6] {
    let q = Q_TILE as f64;
    [
        KernelShape { kernel: "dot", flops_per_elem: 2.0, bytes_per_elem: 8.0 },
        KernelShape {
            kernel: "dot_f64",
            flops_per_elem: 2.0,
            bytes_per_elem: 8.0,
        },
        KernelShape {
            kernel: "dot_i8",
            flops_per_elem: 2.0,
            bytes_per_elem: 5.0,
        },
        KernelShape {
            kernel: "axpy",
            flops_per_elem: 2.0,
            bytes_per_elem: 12.0,
        },
        KernelShape {
            kernel: "tile_f32",
            flops_per_elem: 2.0 * q,
            bytes_per_elem: 4.0,
        },
        KernelShape {
            kernel: "tile_i8",
            flops_per_elem: 2.0 * q,
            bytes_per_elem: 1.0,
        },
    ]
}

/// One measured kernel at one dispatch level, judged against the
/// roofline.
#[derive(Debug, Clone)]
pub struct KernelMeasure {
    pub kernel: &'static str,
    pub level: SimdLevel,
    pub ai: f64,
    /// Achieved GFLOP/s (best pass).
    pub gflops: f64,
    /// Roofline-predicted ceiling at this kernel's AI and this level's
    /// peak.
    pub ceiling_gflops: f64,
    /// `gflops / ceiling_gflops`.  May exceed 1.0: the scalar level
    /// models 1 lane but the compiler may autovectorize, and a working
    /// set that partially fits in LLC beats the DRAM bandwidth term.
    pub achieved_frac: f64,
}

/// Default working set for [`measure_kernels`]: 64 Ki rows x 128 dims
/// = 32 MiB of f32 rows (8 MiB of int8 codes) — past typical LLC, so
/// the bandwidth term of the roofline is honest.
pub const DEFAULT_ROWS: usize = 64 * 1024;
pub const DEFAULT_DIM: usize = 128;

/// Measure a single-core single-level bandwidth estimate: a two-stream
/// f32 dot over 2 x 32 MiB, best of 3 passes, at the best detected
/// level (explicit SIMD saturates a core's memory pipeline; scalar may
/// not).
pub fn measure_bandwidth_gbs() -> f64 {
    let n = 8 << 20; // 8 Mi f32 per stream = 32 MiB each
    let a = vec![0.5f32; n];
    let b = vec![0.25f32; n];
    let d = Dispatch::for_level(vecops::detect_level())
        .expect("detected level is always available");
    let stats = benchkit::bench(1, 3, || {
        std::hint::black_box(d.dot(&a, &b));
    });
    let bytes = (2 * n * std::mem::size_of::<f32>()) as f64;
    bytes / stats.min_secs.max(1e-9) / 1e9
}

/// Run every modeled kernel at `level` over a `rows x dim` working set
/// and judge each against `spec`'s roofline at that level.  Errors if
/// the host lacks `level`.
pub fn measure_kernels(
    spec: &CpuSpec,
    level: SimdLevel,
    rows: usize,
    dim: usize,
) -> Result<Vec<KernelMeasure>, String> {
    assert!(rows >= Q_TILE && dim > 0, "degenerate roofline working set");
    let d = Dispatch::for_level(level)?;
    let roof = spec.roofline(level);

    // Deterministic, small-magnitude data: axpy accumulates into the
    // rows across passes, so values must stay far from overflow.
    let rowsf: Vec<f32> =
        (0..rows * dim).map(|i| ((i * 37 % 256) as f32 - 128.0) * 1e-3).collect();
    let mut rows_mut = rowsf.clone();
    let codes: Vec<i8> = (0..rows * dim).map(|i| (i * 53 % 255) as i8).collect();
    let scales: Vec<f32> = (0..rows).map(|r| 0.002 + (r % 7) as f32 * 1e-4).collect();
    let queries: Vec<Vec<f32>> = (0..Q_TILE)
        .map(|q| (0..dim).map(|i| ((q * 31 + i * 7) as f32 * 0.11).sin()).collect())
        .collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
    let q0 = qrefs[0];
    let mut tile_out = vec![0.0f32; Q_TILE * rows];

    let elems = (rows * dim) as f64;
    let mut out = Vec::new();
    for shape in kernel_shapes() {
        let flops_per_pass = shape.flops_per_elem * elems;
        let stats = match shape.kernel {
            "dot" => benchkit::bench(1, 5, || {
                let mut s = 0.0f32;
                for row in rowsf.chunks_exact(dim) {
                    s += d.dot(row, q0);
                }
                std::hint::black_box(s);
            }),
            "dot_f64" => benchkit::bench(1, 5, || {
                let mut s = 0.0f64;
                for row in rowsf.chunks_exact(dim) {
                    s += d.dot_f64(row, q0);
                }
                std::hint::black_box(s);
            }),
            "dot_i8" => benchkit::bench(1, 5, || {
                let mut s = 0.0f32;
                for (r, row) in codes.chunks_exact(dim).enumerate() {
                    s += d.dot_i8(row, scales[r], q0);
                }
                std::hint::black_box(s);
            }),
            "axpy" => benchkit::bench(1, 5, || {
                for row in rows_mut.chunks_exact_mut(dim) {
                    d.axpy(1e-7, q0, row);
                }
                std::hint::black_box(rows_mut.first().copied());
            }),
            "tile_f32" => benchkit::bench(1, 5, || {
                d.tile_scores_f32(&rowsf, dim, &qrefs, &mut tile_out);
                std::hint::black_box(tile_out.first().copied());
            }),
            "tile_i8" => benchkit::bench(1, 5, || {
                d.tile_scores_i8(&codes, &scales, dim, &qrefs, &mut tile_out);
                std::hint::black_box(tile_out.first().copied());
            }),
            other => unreachable!("unmodeled kernel {other}"),
        };
        let gflops = flops_per_pass / stats.min_secs.max(1e-9) / 1e9;
        let ceiling = roof.attainable_gflops(shape.ai());
        out.push(KernelMeasure {
            kernel: shape.kernel,
            level,
            ai: shape.ai(),
            gflops,
            ceiling_gflops: ceiling,
            achieved_frac: gflops / ceiling.max(1e-9),
        });
    }
    Ok(out)
}

/// The `"roofline"` artifact section shared by `bench_throughput` and
/// `bench_serve`: the CPU model plus one row per (kernel, level).
pub fn roofline_json(spec: &CpuSpec, measures: &[KernelMeasure]) -> Json {
    let active = vecops::simd_selection();
    let cpu = obj(vec![
        ("arch", Json::Str(std::env::consts::ARCH.to_string())),
        ("simd", Json::Str(active.level.name().to_string())),
        ("simd_source", Json::Str(active.source.to_string())),
        ("cores", Json::Num(spec.cores as f64)),
        ("clock_ghz", Json::Num(spec.clock_ghz)),
        ("clock_source", Json::Str(spec.clock_source.to_string())),
        ("mem_bw_gbs", Json::Num(spec.mem_bw_gbs)),
        ("bw_source", Json::Str(spec.bw_source.to_string())),
        ("peak_gflops_core", Json::Num(spec.peak_gflops(active.level))),
        ("knee_flop_per_byte", Json::Num(spec.roofline(active.level).knee())),
    ]);
    let kernels = measures
        .iter()
        .map(|m| {
            obj(vec![
                ("kernel", Json::Str(m.kernel.to_string())),
                ("simd", Json::Str(m.level.name().to_string())),
                ("ai", Json::Num(m.ai)),
                ("gflops", Json::Num(m.gflops)),
                ("ceiling_gflops", Json::Num(m.ceiling_gflops)),
                ("achieved_frac", Json::Num(m.achieved_frac)),
            ])
        })
        .collect();
    obj(vec![("cpu", cpu), ("kernels", Json::Arr(kernels))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_lifts_arithmetic_intensity() {
        let shapes = kernel_shapes();
        let ai = |name: &str| {
            shapes.iter().find(|s| s.kernel == name).unwrap().ai()
        };
        // The paper's Figure 1 narrative, kernel by kernel: tiles
        // (reuse) sit far right of the pair kernels (no reuse).
        assert_eq!(ai("dot"), 0.25);
        assert_eq!(ai("tile_f32"), 2.0);
        assert_eq!(ai("tile_i8"), 8.0);
        assert!(ai("dot") < ai("dot_i8"));
        assert!(ai("dot_i8") < ai("tile_f32"));
        assert!(ai("tile_f32") < ai("tile_i8"));
        assert!(ai("axpy") < ai("dot"));
    }

    #[test]
    fn roofline_ceilings_follow_level_width() {
        let spec = CpuSpec::with(8, 3.0, 10.0);
        // scalar: 1 lane -> 6 GF/s peak; avx2: 8 lanes -> 48 GF/s.
        assert_eq!(spec.peak_gflops(SimdLevel::Scalar), 6.0);
        assert_eq!(spec.peak_gflops(SimdLevel::Avx2), 48.0);
        assert_eq!(spec.peak_gflops(SimdLevel::Avx512), 96.0);
        // dot (AI 0.25) is memory-bound at every width...
        let dot_ai = 0.25;
        assert_eq!(spec.roofline(SimdLevel::Avx2).attainable_gflops(dot_ai), 2.5);
        // ...while the int8 tile (AI 8.0) is compute-bound at AVX2.
        assert_eq!(spec.roofline(SimdLevel::Avx2).attainable_gflops(8.0), 48.0);
        assert_eq!(spec.roofline(SimdLevel::Scalar).attainable_gflops(8.0), 6.0);
    }

    /// Tiny-working-set smoke: the measurement harness runs every
    /// kernel on every available level and produces positive,
    /// shape-consistent numbers.  (Real sizes run in the benches.)
    #[test]
    fn measure_kernels_smoke() {
        let spec = CpuSpec::with(1, 3.0, 10.0);
        for level in vecops::available_levels() {
            let ms = measure_kernels(&spec, level, 64, 32).unwrap();
            assert_eq!(ms.len(), kernel_shapes().len());
            for m in &ms {
                assert!(m.gflops > 0.0, "{} {level}", m.kernel);
                assert!(m.ceiling_gflops > 0.0);
                assert!(m.achieved_frac > 0.0);
                assert_eq!(m.level, level);
            }
        }
    }

    #[test]
    fn roofline_json_has_expected_sections() {
        let spec = CpuSpec::with(4, 3.0, 12.0);
        let ms = measure_kernels(&spec, SimdLevel::Scalar, 16, 8).unwrap();
        let j = roofline_json(&spec, &ms);
        assert!(j.get("cpu").is_some());
        let kernels = j.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), kernel_shapes().len());
        assert!(kernels[0].get("achieved_frac").is_some());
        let text = format!("{j}");
        assert!(text.contains("ceiling_gflops"), "{text}");
    }
}
