//! Analytical GPU memory-traffic model (the nsight substitute).
//!
//! Regenerates the paper's Table 4 (memory demand in GB/epoch at L1/TEX,
//! L2, DRAM) and the traffic half of Figure 1 (arithmetic intensity) from
//! the algorithmic structure of each implementation, per Figure 3 of the
//! paper.  The per-window access counts below are derived from each
//! variant's loop structure; the DRAM level additionally runs a
//! Che-approximation LRU model over the Zipf-distributed row-reuse stream,
//! with the effective cache share scaled down by each variant's resident
//! concurrency (more simultaneous thread blocks with bigger footprints →
//! more contention — this is what makes accSGNS's DRAM demand the largest
//! while low-occupancy Wombat stays L2-resident, as the paper measures).
//!
//! Absolute bytes depend on the corpus; the reproduction target is the
//! *shape*: per-level ordering of implementations and reduction factors
//! (FULL-W2V cutting ~90% of total demand, Section 5.3.1).
//!
//! The [`cpu`] submodule applies the same Figure 1 roofline discipline
//! to this host: per-`vecops`-kernel arithmetic intensity, measured or
//! configured memory bandwidth, and achieved-vs-ceiling fractions that
//! the benches embed in their `BENCH_*.json` artifacts.

pub mod cpu;

use crate::corpus::vocab::Vocab;

/// Implementation variants the model covers (= kernel variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    FullW2v,
    FullRegister,
    AccSgns,
    Wombat,
}

impl Variant {
    pub const ALL: [Variant; 4] = [
        Variant::FullW2v,
        Variant::FullRegister,
        Variant::AccSgns,
        Variant::Wombat,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Variant::FullW2v => "FULL-W2V",
            Variant::FullRegister => "FULL-Register",
            Variant::AccSgns => "accSGNS",
            Variant::Wombat => "Wombat",
        }
    }

    pub fn kernel_name(&self) -> &'static str {
        match self {
            Variant::FullW2v => "full_w2v",
            Variant::FullRegister => "full_register",
            Variant::AccSgns => "acc_sgns",
            Variant::Wombat => "wombat",
        }
    }
}

/// Training workload parameters the traffic depends on.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Words per epoch (post-subsampling words actually trained).
    pub words_per_epoch: u64,
    /// Fixed context width W_f.
    pub wf: usize,
    /// Negatives per window N.
    pub n: usize,
    /// Embedding dimension d.
    pub d: usize,
    /// Vocabulary size (for the reuse model).
    pub vocab: usize,
    /// Zipf exponent of word frequencies (~1 for natural corpora).
    pub zipf_s: f64,
}

impl Workload {
    /// Paper's Text8 setting (Table 3 + Section 5.1 hyperparameters).
    pub fn text8_paper() -> Self {
        Workload {
            words_per_epoch: 16_718_845,
            wf: 3,
            n: 5,
            d: 128,
            vocab: 71_291,
            zipf_s: 1.0,
        }
    }

    pub fn from_vocab(vocab: &Vocab, words_per_epoch: u64, wf: usize, n: usize, d: usize) -> Self {
        Workload { words_per_epoch, wf, n, d, vocab: vocab.len(), zipf_s: 1.0 }
    }

    /// Bytes per embedding row.
    pub fn row_bytes(&self) -> f64 {
        (self.d * 4) as f64
    }
}

/// Per-window row-access counts at each level (unit: d-float rows).
#[derive(Debug, Clone, Copy)]
pub struct AccessProfile {
    /// Requests satisfied at L1/TEX/shared (explicit shared-memory ops and
    /// L1-resident reuse).
    pub l1_rows: f64,
    /// Requests that must be served from L2 (L1/shared cannot hold them).
    pub l2_rows: f64,
    /// Unique-row traffic presented to the L2->DRAM boundary before the
    /// reuse model (compulsory + lifetime-bounded).
    pub dram_candidate_rows: f64,
    /// Effective L2 share (0..1]: concurrency/footprint contention factor
    /// used by the reuse model (from the variant's occupancy profile).
    pub l2_share: f64,
}

/// Structural access profile of a variant (paper Figure 3 / Section 3).
///
/// Derivations (per window of 2W_f context pairings, N+1 output rows):
/// * FULL-W2V: ring-buffer read+accumulate per context row (4W_f shared
///   rows), center+negatives register-cached (read N+1, write N+1 via L1),
///   syn0 fill/drain amortized to 2 rows/window at L2; center+negatives
///   round-trip L2 once per window (2N+2).
/// * FULL-Register: same negative registers, but context rows round-trip
///   the cache hierarchy once per *negative* iteration (the loop re-walks
///   the window per sample): 4W_f(N+1) L1 rows, of which one full pass
///   (4W_f) misses to L2 each window.
/// * accSGNS: per-pair processing — both the context row and every output
///   row round-trip per pair: 8W_f(N+1) L1 rows; per-pair output traffic
///   also reaches L2 (2W_f(N+1)).
/// * Wombat: per-window shared-memory staging plus shuffle-reduction
///   doubles L1-level transactions over accSGNS (the paper measures 2x);
///   every window stages its whole working set through L2 (4W_f(N+1)),
///   but the staging stream is highly local so its DRAM candidates are
///   small and its low occupancy leaves it most of the L2.
pub fn access_profile(v: Variant, w: &Workload) -> AccessProfile {
    let wf = w.wf as f64;
    let n = w.n as f64;
    match v {
        Variant::FullW2v => AccessProfile {
            l1_rows: 4.0 * wf + 2.0 * (n + 1.0),
            l2_rows: 2.0 + 2.0 * (n + 1.0),
            dram_candidate_rows: 2.0 + 2.0 * (n + 1.0),
            l2_share: 1.0,
        },
        Variant::FullRegister => AccessProfile {
            l1_rows: 4.0 * wf * (n + 1.0) + 2.0 * (n + 1.0),
            l2_rows: 4.0 * wf + 2.0 * (n + 1.0),
            dram_candidate_rows: 2.0 + 2.0 * (n + 1.0),
            l2_share: 0.6, // near-peak occupancy -> heavy L2 contention
        },
        Variant::AccSgns => AccessProfile {
            l1_rows: 8.0 * wf * (n + 1.0),
            l2_rows: 2.0 * wf * (n + 1.0) + 2.0 * (n + 1.0),
            dram_candidate_rows: 2.0 + 2.0 * (n + 1.0),
            l2_share: 0.35, // big per-block footprint, no explicit reuse
        },
        Variant::Wombat => AccessProfile {
            l1_rows: 16.0 * wf * (n + 1.0),
            l2_rows: 4.0 * wf * (n + 1.0),
            dram_candidate_rows: 2.0 + 2.0 * (n + 1.0),
            l2_share: 0.9, // low occupancy leaves the L2 to few blocks
        },
    }
}

/// Result of the traffic model for one (variant, workload, L2 size).
#[derive(Debug, Clone)]
pub struct TrafficReport {
    pub variant: Variant,
    /// GB per epoch at each level.
    pub l1_gb: f64,
    pub l2_gb: f64,
    pub dram_gb: f64,
    /// FLOPs per epoch (same for all variants — identical math).
    pub flops: f64,
    /// Arithmetic intensity vs DRAM bytes (the roofline x-axis).
    pub arithmetic_intensity: f64,
    /// Arithmetic intensity vs *total* hierarchy traffic — the paper's
    /// Section 5 "increases the arithmetic intensity by 23.9x / 16.5x"
    /// claim counts every level the kernel touches.
    pub ai_total: f64,
}

impl TrafficReport {
    pub fn sum_gb(&self) -> f64 {
        self.l1_gb + self.l2_gb + self.dram_gb
    }
}

/// Che-approximation hit probability for an LRU cache of `cache_rows`
/// over a Zipf(s) popularity stream of `vocab` items.
///
/// Solves sum_i (1 - exp(-q_i * t)) = C for the characteristic time `t`
/// (bisection), then returns the request-weighted hit rate
/// sum_i q_i (1 - exp(-q_i t)).
pub fn zipf_lru_hit_rate(vocab: usize, zipf_s: f64, cache_rows: f64) -> f64 {
    if vocab == 0 {
        return 0.0;
    }
    if cache_rows >= vocab as f64 {
        return 1.0;
    }
    // normalized Zipf popularities (computed once; 71k items is cheap)
    let mut q: Vec<f64> = (1..=vocab)
        .map(|r| 1.0 / (r as f64).powf(zipf_s))
        .collect();
    let z: f64 = q.iter().sum();
    for x in q.iter_mut() {
        *x /= z;
    }
    let occupancy = |t: f64| -> f64 {
        // LINT: allow(kernel-purity): analytical cache-model series
        // (Che approximation), not an embedding kernel.
        q.iter().map(|&p| 1.0 - (-p * t).exp()).sum()
    };
    // bisection on t: occupancy is increasing in t
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    while occupancy(hi) < cache_rows {
        hi *= 2.0;
        if hi > 1e18 {
            break;
        }
    }
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if occupancy(mid) < cache_rows {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let t = 0.5 * (lo + hi);
    // LINT: allow(kernel-purity): as above — analytical model series.
    q.iter().map(|&p| p * (1.0 - (-p * t).exp())).sum()
}

/// FLOPs per window: three m x (N+1) x d matrix products (forward dots,
/// dC, dU) plus activation overhead (paper Section 3.1's update rule).
pub fn flops_per_window(w: &Workload) -> f64 {
    let m = 2.0 * w.wf as f64;
    let cols = (w.n + 1) as f64;
    let d = w.d as f64;
    6.0 * m * cols * d + 4.0 * m * cols
}

/// Run the traffic model for one variant.
pub fn traffic(v: Variant, w: &Workload, l2_bytes: f64) -> TrafficReport {
    let prof = access_profile(v, w);
    let windows = w.words_per_epoch as f64;
    let rb = w.row_bytes();
    let l1_gb = prof.l1_rows * windows * rb / 1e9;
    let l2_gb = prof.l2_rows * windows * rb / 1e9;
    let cache_rows = prof.l2_share * l2_bytes / rb;
    let hit = zipf_lru_hit_rate(w.vocab, w.zipf_s, cache_rows);
    let dram_gb = prof.dram_candidate_rows * windows * rb * (1.0 - hit) / 1e9
        // compulsory epoch traffic: both matrices stream through once
        + 2.0 * (w.vocab * w.d * 4) as f64 / 1e9;
    let flops = flops_per_window(w) * windows;
    TrafficReport {
        variant: v,
        l1_gb,
        l2_gb,
        dram_gb,
        flops,
        arithmetic_intensity: flops / (dram_gb * 1e9).max(1.0),
        ai_total: flops / ((l1_gb + l2_gb + dram_gb) * 1e9).max(1.0),
    }
}

/// Table 4 for all variants.
pub fn table4(w: &Workload, l2_bytes: f64) -> Vec<TrafficReport> {
    Variant::ALL.iter().map(|&v| traffic(v, w, l2_bytes)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const V100_L2: f64 = 6.0 * 1024.0 * 1024.0;

    fn w() -> Workload {
        Workload::text8_paper()
    }

    #[test]
    fn lru_model_sane() {
        // full cache -> all hits; tiny cache -> few hits
        assert_eq!(zipf_lru_hit_rate(1000, 1.0, 1000.0), 1.0);
        let small = zipf_lru_hit_rate(10_000, 1.0, 10.0);
        let big = zipf_lru_hit_rate(10_000, 1.0, 5_000.0);
        assert!(small < big);
        assert!(small > 0.0 && small < 0.5);
        assert!(big > 0.6 && big <= 1.0);
        // Zipf head concentration: even 1% capacity catches >25% of requests
        let one_pct = zipf_lru_hit_rate(100_000, 1.0, 1000.0);
        assert!(one_pct > 0.25, "{one_pct}");
    }

    #[test]
    fn per_level_ordering_matches_paper() {
        let t = table4(&w(), V100_L2);
        let by = |v: Variant| t.iter().find(|r| r.variant == v).unwrap();
        let (fw, fr, acc, wo) = (
            by(Variant::FullW2v),
            by(Variant::FullRegister),
            by(Variant::AccSgns),
            by(Variant::Wombat),
        );
        // Table 4 shape: FULL-W2V minimal everywhere; Wombat max L1;
        // accSGNS max DRAM; sums ordered FULL-W2V < FULL-Register <
        // accSGNS < Wombat.
        assert!(fw.l1_gb < fr.l1_gb && fr.l1_gb < acc.l1_gb);
        assert!(acc.l1_gb < wo.l1_gb);
        assert!(fw.l2_gb < fr.l2_gb && fr.l2_gb < acc.l2_gb);
        assert!(acc.l2_gb < wo.l2_gb);
        assert!(acc.dram_gb > fr.dram_gb);
        assert!(acc.dram_gb > wo.dram_gb);
        assert!(fw.sum_gb() < fr.sum_gb());
        assert!(fr.sum_gb() < acc.sum_gb());
        assert!(acc.sum_gb() < wo.sum_gb());
    }

    #[test]
    fn fullw2v_reduction_factor() {
        let t = table4(&w(), V100_L2);
        let by = |v: Variant| t.iter().find(|r| r.variant == v).unwrap();
        let reduction_vs_wombat = 1.0
            - by(Variant::FullW2v).sum_gb() / by(Variant::Wombat).sum_gb();
        // paper: 94.0% total demand reduction vs Wombat; shape target >=85%
        assert!(
            reduction_vs_wombat > 0.85,
            "reduction {reduction_vs_wombat}"
        );
        let reduction_vs_reg = 1.0
            - by(Variant::FullW2v).sum_gb()
                / by(Variant::FullRegister).sum_gb();
        // paper: 87.0% vs FULL-Register; target >= 60%
        assert!(reduction_vs_reg > 0.6, "reduction {reduction_vs_reg}");
    }

    #[test]
    fn arithmetic_intensity_ordering() {
        let t = table4(&w(), V100_L2);
        let by = |v: Variant| t.iter().find(|r| r.variant == v).unwrap();
        // Figure 1 / Section 5: FULL-W2V far to the right of accSGNS and
        // Wombat.  Against total hierarchy traffic (the paper's 23.9x /
        // 16.5x claim) the gain must be large.
        assert!(
            by(Variant::FullW2v).ai_total
                > 4.0 * by(Variant::AccSgns).ai_total
        );
        assert!(
            by(Variant::FullW2v).ai_total
                > 4.0 * by(Variant::Wombat).ai_total
        );
        // and the roofline x-axis (DRAM AI) still orders the same way
        assert!(
            by(Variant::FullW2v).arithmetic_intensity
                > by(Variant::AccSgns).arithmetic_intensity
        );
        assert!(
            by(Variant::FullW2v).arithmetic_intensity
                > by(Variant::Wombat).arithmetic_intensity
        );
    }

    #[test]
    fn context_reuse_reduction_formula() {
        // Section 3.2: global accesses for context words drop by
        // 2Wf/(2Wf+1): 86% at Wf=3 of the context component.  Check the
        // L1-vs-L2 context rows encode that lifetime reuse.
        let p_full = access_profile(Variant::FullW2v, &w());
        let p_reg = access_profile(Variant::FullRegister, &w());
        // FULL-W2V context traffic to L2 is the amortized fill/drain (2)
        // vs FULL-Register's per-window 4Wf
        let ctx_full = 2.0;
        let ctx_reg = 4.0 * w().wf as f64;
        let reduction = 1.0 - ctx_full / ctx_reg;
        assert!(reduction > 0.8, "{reduction}");
        assert!(p_full.l2_rows < p_reg.l2_rows);
    }

    #[test]
    fn flops_identical_across_variants() {
        let t = table4(&w(), V100_L2);
        for r in &t {
            assert_eq!(r.flops, t[0].flops);
        }
    }

    #[test]
    fn scales_linearly_with_corpus() {
        let mut w2 = w();
        w2.words_per_epoch *= 2;
        let a = traffic(Variant::FullW2v, &w(), V100_L2);
        let b = traffic(Variant::FullW2v, &w2, V100_L2);
        assert!((b.l1_gb / a.l1_gb - 2.0).abs() < 0.01);
    }
}
