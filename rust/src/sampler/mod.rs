//! Negative sampling: the unigram^0.75 distribution (word2vec's noise
//! distribution) with O(1) draws via the alias method, plus window
//! geometry helpers shared by the batcher and the CPU baselines.

pub mod unigram;
pub mod window;

pub use unigram::UnigramTable;
pub use window::{context_positions, window_pair_count};
