//! Unigram^alpha negative-sampling table.
//!
//! word2vec.c materializes a 100M-entry table; we use Vose's alias method:
//! identical distribution, O(V) memory, O(1) draws — this is part of why
//! the FULL-W2V-style batcher (Table 1) outruns the baseline batchers.

use crate::corpus::vocab::Vocab;
use crate::util::rng::Pcg32;

/// Alias-method sampler over word ids with probability ∝ count^alpha.
#[derive(Debug, Clone)]
pub struct UnigramTable {
    prob: Vec<f32>,
    alias: Vec<u32>,
}

impl UnigramTable {
    /// Standard word2vec distortion alpha = 0.75.
    pub const DEFAULT_ALPHA: f64 = 0.75;

    pub fn new(vocab: &Vocab, alpha: f64) -> Self {
        let weights: Vec<f64> = vocab
            .counts()
            .iter()
            .map(|&c| (c as f64).powf(alpha))
            .collect();
        Self::from_weights(&weights)
    }

    /// Build from arbitrary non-negative weights (exposed for tests and for
    /// the pSGNScc baseline's modified noise distribution).
    pub fn from_weights(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "empty weight vector");
        let sum: f64 = weights.iter().sum();
        assert!(sum > 0.0, "weights must not all be zero");
        // Vose's alias method
        let mut prob = vec![0f32; n];
        let mut alias = vec![0u32; n];
        let scaled: Vec<f64> =
            weights.iter().map(|w| w * n as f64 / sum).collect();
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        let mut scaled = scaled;
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s] as f32;
            alias[s] = l as u32;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &l in &large {
            prob[l] = 1.0;
        }
        for &s in &small {
            prob[s] = 1.0; // numerical leftovers
        }
        UnigramTable { prob, alias }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one word id.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg32) -> u32 {
        let i = rng.next_bounded(self.prob.len() as u32) as usize;
        if rng.next_f32() < self.prob[i] {
            i as u32
        } else {
            self.alias[i]
        }
    }

    /// Draw a negative that differs from `avoid` (word2vec redraws when the
    /// negative equals the target word).
    #[inline]
    pub fn sample_avoiding(&self, rng: &mut Pcg32, avoid: u32) -> u32 {
        if self.len() == 1 {
            return 0;
        }
        loop {
            let s = self.sample(rng);
            if s != avoid {
                return s;
            }
        }
    }

    /// Fill a slice with negatives avoiding `avoid`.
    pub fn fill(&self, rng: &mut Pcg32, avoid: u32, out: &mut [u32]) {
        for slot in out.iter_mut() {
            *slot = self.sample_avoiding(rng, avoid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;

    #[test]
    fn empirical_matches_distorted_distribution() {
        let counts =
            vec![("a".to_string(), 1000u64), ("b".to_string(), 100), ("c".to_string(), 10)];
        let v = Vocab::from_counts(counts, 1);
        let t = UnigramTable::new(&v, 0.75);
        let mut rng = Pcg32::new(123);
        let mut hist = [0u64; 3];
        let n = 200_000;
        for _ in 0..n {
            hist[t.sample(&mut rng) as usize] += 1;
        }
        let want: Vec<f64> = v
            .counts()
            .iter()
            .map(|&c| (c as f64).powf(0.75))
            .collect();
        let wsum: f64 = want.iter().sum();
        for i in 0..3 {
            let got = hist[i] as f64 / n as f64;
            let expect = want[i] / wsum;
            assert!(
                (got - expect).abs() < 0.01,
                "id {i}: got {got:.4} want {expect:.4}"
            );
        }
    }

    #[test]
    fn uniform_weights_uniform_draws() {
        let t = UnigramTable::from_weights(&[1.0; 8]);
        let mut rng = Pcg32::new(5);
        let mut hist = [0u64; 8];
        for _ in 0..80_000 {
            hist[t.sample(&mut rng) as usize] += 1;
        }
        for &h in &hist {
            let p = h as f64 / 80_000.0;
            assert!((p - 0.125).abs() < 0.01);
        }
    }

    #[test]
    fn avoids_target() {
        let t = UnigramTable::from_weights(&[100.0, 1.0]);
        let mut rng = Pcg32::new(9);
        for _ in 0..1000 {
            assert_eq!(t.sample_avoiding(&mut rng, 0), 1);
        }
    }

    #[test]
    fn fill_length_and_range() {
        let t = UnigramTable::from_weights(&[1.0, 2.0, 3.0, 4.0]);
        let mut rng = Pcg32::new(2);
        let mut out = [0u32; 16];
        t.fill(&mut rng, 2, &mut out);
        assert!(out.iter().all(|&x| x < 4 && x != 2));
    }

    #[test]
    fn degenerate_single_word() {
        let t = UnigramTable::from_weights(&[5.0]);
        let mut rng = Pcg32::new(3);
        assert_eq!(t.sample(&mut rng), 0);
        assert_eq!(t.sample_avoiding(&mut rng, 0), 0); // can't avoid
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_weights_panic() {
        UnigramTable::from_weights(&[]);
    }
}
