//! Context-window geometry (fixed width W_f, paper Section 3.2).
//!
//! FULL-W2V replaces word2vec's per-position random width `b ∈ [1, W]`
//! with the fixed width `W_f = ceil(W/2)` (the mean of the random
//! distribution), which is what makes the shared-memory ring buffer
//! statically sizable.  These helpers define the window shape used by the
//! batcher, the CPU baselines, and the analytical memory model, and they
//! must agree with the Pallas kernels' `_window_geometry`.

/// Context positions of the window centered at `t` in a sentence of
/// `len` words with fixed width `wf` (center excluded).
pub fn context_positions(t: usize, wf: usize, len: usize) -> Vec<usize> {
    if t >= len {
        return Vec::new();
    }
    let lo = t.saturating_sub(wf);
    let hi = (t + wf).min(len - 1);
    (lo..=hi).filter(|&j| j != t).collect()
}

/// Total (context, center) pair count of a sentence: the unit the paper's
/// throughput metric (words/sec) multiplies into training work.
pub fn window_pair_count(len: usize, wf: usize) -> usize {
    (0..len).map(|t| context_positions(t, wf, len).len()).sum()
}

/// Closed-form pair count (used to cross-check the enumeration and by the
/// analytical memory model where sentences are long).
pub fn window_pair_count_closed(len: usize, wf: usize) -> usize {
    if len <= 1 {
        return 0;
    }
    let full = 2 * wf * len;
    // boundary loss: first/last wf positions lose (wf - i) pairs each side
    let loss: usize = (0..wf.min(len))
        .map(|i| (wf - i).min(len.saturating_sub(1)))
        .sum();
    full.saturating_sub(2 * loss).min(len * (len - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_window() {
        assert_eq!(context_positions(5, 2, 20), vec![3, 4, 6, 7]);
    }

    #[test]
    fn boundary_windows() {
        assert_eq!(context_positions(0, 3, 10), vec![1, 2, 3]);
        assert_eq!(context_positions(9, 3, 10), vec![6, 7, 8]);
        assert_eq!(context_positions(1, 3, 10), vec![0, 2, 3, 4]);
    }

    #[test]
    fn short_sentences() {
        assert_eq!(context_positions(0, 3, 1), Vec::<usize>::new());
        assert_eq!(context_positions(0, 3, 2), vec![1]);
        assert_eq!(context_positions(0, 2, 0), Vec::<usize>::new());
        assert_eq!(context_positions(5, 2, 3), Vec::<usize>::new()); // t >= len
    }

    #[test]
    fn pair_count_enumeration_vs_closed_form() {
        for len in 0..40 {
            for wf in 1..6 {
                assert_eq!(
                    window_pair_count(len, wf),
                    window_pair_count_closed(len, wf),
                    "len={len} wf={wf}"
                );
            }
        }
    }

    #[test]
    fn pair_count_examples() {
        // len=6, wf=1: 2*6-2 = 10 (matches the kernel test's expectation)
        assert_eq!(window_pair_count(6, 1), 10);
        // every word pairs with every other when wf >= len
        assert_eq!(window_pair_count(4, 10), 12);
    }
}
