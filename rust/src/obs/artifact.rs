//! Bench artifact emitter: persisted `BENCH_*.json` perf snapshots.
//!
//! Benches run with `--artifact PATH` write one JSON document so CI can
//! upload them and the perf trajectory is comparable across PRs (ROADMAP
//! Open item 2). Schema (v1):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "bench": "bench_serve",
//!   "git_rev": "abc1234",
//!   "created_unix": 1754000000,
//!   "config": { ... },            // knob values the run used
//!   ...                           // bench-specific sections: table rows,
//! }                               // stage breakdowns, reuse factors,
//! ```                             // latency quantiles
//!
//! Every section a bench emits should be a plain array/object of numbers
//! so downstream diffing needs no schema knowledge beyond v1.

use std::io;
use std::path::Path;

use crate::util::json::{obj, Json};

/// Best-effort git revision: `$GITHUB_SHA` (CI), then `git rev-parse`,
/// then `"unknown"` — artifacts must still emit outside a checkout.
pub fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    if let Ok(out) = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
    {
        if out.status.success() {
            let rev = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !rev.is_empty() {
                return rev;
            }
        }
    }
    "unknown".to_string()
}

/// Write a schema-v1 artifact document to `path`.
pub fn emit(
    path: &Path,
    bench: &str,
    config: Json,
    sections: Vec<(&str, Json)>,
) -> io::Result<()> {
    let unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut fields = vec![
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str(bench.to_string())),
        ("git_rev", Json::Str(git_rev())),
        ("created_unix", Json::Num(unix as f64)),
        ("config", config),
    ];
    fields.extend(sections);
    std::fs::write(path, format!("{}\n", obj(fields)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_parseable_schema_v1() {
        let dir = std::env::temp_dir().join("fullw2v_obs_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        emit(
            &path,
            "bench_test",
            obj(vec![("rows", Json::Num(8.0))]),
            vec![(
                "latency",
                obj(vec![("p50_us", Json::Num(1.25))]),
            )],
        )
        .unwrap();
        let doc = Json::parse(std::fs::read_to_string(&path).unwrap().trim())
            .unwrap();
        assert_eq!(doc.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("bench_test"));
        assert!(doc.get("git_rev").unwrap().as_str().is_some());
        assert_eq!(
            doc.get("config").unwrap().get("rows").unwrap().as_usize(),
            Some(8)
        );
        assert_eq!(
            doc.get("latency").unwrap().get("p50_us").unwrap().as_f64(),
            Some(1.25)
        );
        std::fs::remove_file(&path).ok();
    }
}
