//! Bench artifact emitter: persisted `BENCH_*.json` perf snapshots.
//!
//! Benches run with `--artifact PATH` write one JSON document so CI can
//! upload them and the perf trajectory is comparable across PRs (ROADMAP
//! Open item 2). Schema (v1):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "bench": "bench_serve",
//!   "git_rev": "abc1234",
//!   "created_unix": 1754000000,
//!   "config": { ... },            // knob values the run used
//!   ...                           // bench-specific sections: table rows,
//! }                               // stage breakdowns, reuse factors,
//! ```                             // latency quantiles
//!
//! Every section a bench emits should be a plain array/object of numbers
//! so downstream diffing needs no schema knowledge beyond v1.
//!
//! The reader half ([`read`], [`diff`], [`benchdiff`]) turns two such
//! artifacts into a regression verdict: numeric leaves are flattened to
//! dotted paths (`probe_plan.2.rows_loaded_per_query`), a pinned rule
//! table names the series whose drift gates CI (direction-aware: fewer
//! rows loaded is good, less reuse is bad), and `fullw2v benchdiff`
//! exits non-zero past tolerance. Sections absent from either artifact
//! are tolerated — benches grow sections over time, and the first CI run
//! after a new bench lands has no old counterpart to compare.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::util::json::{obj, Json};

/// Best-effort git revision: `$GITHUB_SHA` (CI), then `git rev-parse`,
/// then `"unknown"` — artifacts must still emit outside a checkout.
pub fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    if let Ok(out) = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
    {
        if out.status.success() {
            let rev = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !rev.is_empty() {
                return rev;
            }
        }
    }
    "unknown".to_string()
}

/// Write a schema-v1 artifact document to `path`.
pub fn emit(
    path: &Path,
    bench: &str,
    config: Json,
    sections: Vec<(&str, Json)>,
) -> io::Result<()> {
    let unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut fields = vec![
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str(bench.to_string())),
        ("git_rev", Json::Str(git_rev())),
        ("created_unix", Json::Num(unix as f64)),
        ("config", config),
    ];
    fields.extend(sections);
    std::fs::write(path, format!("{}\n", obj(fields)))
}

/// Read and validate a schema-v1 artifact document.
pub fn read(path: &Path) -> io::Result<Json> {
    let text = std::fs::read_to_string(path)?;
    let doc = Json::parse(text.trim()).map_err(|e| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("{}: not a JSON artifact: {e}", path.display()),
        )
    })?;
    match doc.get("schema").and_then(Json::as_f64) {
        Some(v) if v == 1.0 => Ok(doc),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: unsupported artifact schema {other:?} (want 1)",
                path.display()
            ),
        )),
    }
}

/// Flatten every numeric leaf to a dotted path (`latency.p50_us`,
/// `thread_scaling.0.words_per_sec`). Array elements are addressed by
/// index — row order is stable for a given bench. The run-identity
/// fields (`schema`, `created_unix`) are excluded: they differ between
/// any two runs by construction and must never trip a `--fail-on .*`.
pub fn flatten(doc: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    walk(doc, "", 0, &mut out);
    out
}

fn walk(j: &Json, prefix: &str, depth: usize, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Num(n) => {
            out.insert(prefix.to_string(), *n);
        }
        Json::Arr(v) => {
            for (i, x) in v.iter().enumerate() {
                walk(x, &join(prefix, &i.to_string()), depth + 1, out);
            }
        }
        Json::Obj(m) => {
            for (k, v) in m {
                if depth == 0 && (k == "schema" || k == "created_unix") {
                    continue;
                }
                walk(v, &join(prefix, k), depth + 1, out);
            }
        }
        _ => {}
    }
}

fn join(prefix: &str, key: &str) -> String {
    if prefix.is_empty() {
        key.to_string()
    } else {
        format!("{prefix}.{key}")
    }
}

/// Which way a pinned series is allowed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Growth past tolerance is a regression (rows loaded, latency).
    LowerIsBetter,
    /// Shrinkage past tolerance is a regression (reuse, roofline frac).
    HigherIsBetter,
    /// Any relative drift past tolerance is a regression (`--fail-on`).
    Either,
}

/// One gating rule: series matching `pattern` may drift at most
/// `tol_pct` percent in the bad direction.
#[derive(Debug, Clone)]
pub struct Rule {
    pub pattern: String,
    pub direction: Direction,
    pub tol_pct: f64,
}

/// The pinned perf series every benchdiff run gates on. Tolerances are
/// deliberately loose — CI runners are noisy; these catch collapses
/// (a reuse path silently disabled, a probe plan scanning everything),
/// not single-digit noise.
pub fn default_rules() -> Vec<Rule> {
    let pin = |pattern: &str, direction, tol_pct| Rule {
        pattern: pattern.to_string(),
        direction,
        tol_pct,
    };
    vec![
        pin("rows_loaded_per_query$", Direction::LowerIsBetter, 10.0),
        pin("rows_advanced$", Direction::LowerIsBetter, 10.0),
        pin("neg_reuse$", Direction::HigherIsBetter, 10.0),
        pin("achieved_frac$", Direction::HigherIsBetter, 20.0),
        pin("p50_us$", Direction::LowerIsBetter, 50.0),
        pin("p99_us$", Direction::LowerIsBetter, 50.0),
    ]
}

/// Absolute percentage-point drift allowed for any stage's share of its
/// breakdown (stage *seconds* scale with runner speed, shares don't).
pub const STAGE_SHARE_TOL_POINTS: f64 = 15.0;

/// One series that moved past its rule's tolerance.
#[derive(Debug, Clone)]
pub struct Regression {
    pub path: String,
    pub old: f64,
    pub new: f64,
    /// Relative drift in percent (share drift in points for stages).
    pub change_pct: f64,
    pub tol_pct: f64,
}

/// Outcome of comparing two artifacts.
#[derive(Debug, Default)]
pub struct DiffReport {
    /// Series matched by some rule and present in both artifacts.
    pub compared: usize,
    pub regressions: Vec<Regression>,
    /// Rule-matched series present in only one artifact (informational:
    /// sections come and go as benches evolve).
    pub missing: Vec<String>,
}

impl DiffReport {
    pub fn regressed(&self) -> bool {
        !self.regressions.is_empty()
    }

    /// Human-readable verdict, one line per finding.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.regressions {
            out.push_str(&format!(
                "REGRESSION {}: {} -> {} ({:+.1}% vs tol {:.0}%)\n",
                r.path, r.old, r.new, r.change_pct, r.tol_pct
            ));
        }
        for m in &self.missing {
            out.push_str(&format!("note: {m} present in only one artifact\n"));
        }
        out.push_str(&format!(
            "benchdiff: {} series compared, {} regression(s)\n",
            self.compared,
            self.regressions.len()
        ));
        out
    }
}

/// Parse one `--fail-on PATTERN=PCT` argument.
pub fn parse_fail_on(s: &str) -> Result<Rule, String> {
    let (pattern, pct) = s
        .rsplit_once('=')
        .ok_or_else(|| format!("--fail-on wants PATTERN=PCT, got '{s}'"))?;
    if pattern.is_empty() {
        return Err(format!("--fail-on has an empty pattern: '{s}'"));
    }
    let tol_pct: f64 = pct
        .parse()
        .map_err(|_| format!("--fail-on tolerance must be a number, got '{pct}'"))?;
    if tol_pct.is_nan() || tol_pct < 0.0 {
        return Err(format!("--fail-on tolerance must be >= 0, got '{pct}'"));
    }
    Ok(Rule {
        pattern: pattern.to_string(),
        direction: Direction::Either,
        tol_pct,
    })
}

/// Compare two artifacts under the pinned rules plus any `extra` rules.
///
/// Stage breakdowns (paths containing a `stages` component) are compared
/// as shares of their own breakdown's total, in absolute percentage
/// points — wall-clock seconds vary with runner speed, the *shape* of
/// the decomposition shouldn't. Everything else is gated on relative
/// drift in the rule's bad direction. Series matched by a rule but
/// present in only one artifact are reported, not failed.
pub fn diff(old: &Json, new: &Json, extra: &[Rule]) -> DiffReport {
    let old_flat = flatten(old);
    let new_flat = flatten(new);
    let mut report = DiffReport::default();

    let mut rules = default_rules();
    rules.extend(extra.iter().cloned());
    for rule in &rules {
        for (path, &old_v) in &old_flat {
            if !rx_match(&rule.pattern, path) {
                continue;
            }
            let Some(&new_v) = new_flat.get(path) else {
                report.missing.push(path.clone());
                continue;
            };
            report.compared += 1;
            if old_v.abs() < 1e-12 {
                continue; // relative drift from zero is undefined
            }
            let rel_pct = (new_v - old_v) / old_v * 100.0;
            let bad = match rule.direction {
                Direction::LowerIsBetter => rel_pct,
                Direction::HigherIsBetter => -rel_pct,
                Direction::Either => rel_pct.abs(),
            };
            if bad > rule.tol_pct {
                report.regressions.push(Regression {
                    path: path.clone(),
                    old: old_v,
                    new: new_v,
                    change_pct: rel_pct,
                    tol_pct: rule.tol_pct,
                });
            }
        }
        for path in new_flat.keys() {
            if rx_match(&rule.pattern, path) && !old_flat.contains_key(path) {
                report.missing.push(path.clone());
            }
        }
    }

    diff_stage_shares(&old_flat, &new_flat, &mut report);
    report.missing.sort();
    report.missing.dedup();
    report
}

/// Group `...stages.<name>` paths by breakdown, normalize each side to
/// shares, and flag absolute drift past [`STAGE_SHARE_TOL_POINTS`].
fn diff_stage_shares(
    old_flat: &BTreeMap<String, f64>,
    new_flat: &BTreeMap<String, f64>,
    report: &mut DiffReport,
) {
    // prefix (up to and including "stages") -> [(path, old, new)]
    let mut groups: BTreeMap<String, Vec<(String, f64, f64)>> = BTreeMap::new();
    for (path, &old_v) in old_flat {
        let Some(prefix) = stages_prefix(path) else { continue };
        let Some(&new_v) = new_flat.get(path) else { continue };
        groups
            .entry(prefix.to_string())
            .or_default()
            .push((path.clone(), old_v, new_v));
    }
    for members in groups.values() {
        let old_total: f64 = members.iter().map(|(_, o, _)| o).sum();
        let new_total: f64 = members.iter().map(|(_, _, n)| n).sum();
        if old_total <= 0.0 || new_total <= 0.0 {
            continue; // empty breakdown: shares undefined
        }
        for (path, old_v, new_v) in members {
            report.compared += 1;
            let old_share = old_v / old_total * 100.0;
            let new_share = new_v / new_total * 100.0;
            let drift = new_share - old_share;
            if drift.abs() > STAGE_SHARE_TOL_POINTS {
                report.regressions.push(Regression {
                    path: format!("{path} (share)"),
                    old: old_share,
                    new: new_share,
                    change_pct: drift,
                    tol_pct: STAGE_SHARE_TOL_POINTS,
                });
            }
        }
    }
}

/// `Some(prefix through "stages")` if `path` sits inside a stage
/// breakdown: `stages.batch_fill`, `thread_scaling.0.stages.lookup`.
fn stages_prefix(path: &str) -> Option<&str> {
    let parts: Vec<&str> = path.split('.').collect();
    let pos = parts.iter().rposition(|p| *p == "stages")?;
    if pos + 1 != parts.len() - 1 {
        return None; // "stages" must hold the leaf directly
    }
    let prefix_len: usize =
        parts[..=pos].iter().map(|p| p.len() + 1).sum::<usize>() - 1;
    Some(&path[..prefix_len])
}

/// Minimal regex matcher over the subset the rule table needs:
/// `^` (anchor start), `$` (anchor end), `.` (any char), `c*`
/// (zero or more of the preceding char) — the classic Kernighan–Pike
/// matcher, byte-wise. Everything else matches literally. No regex
/// crate offline; this subset covers every pinned pattern and keeps
/// `--fail-on` expressive enough for dotted-path selection.
pub fn rx_match(pattern: &str, text: &str) -> bool {
    let p = pattern.as_bytes();
    let t = text.as_bytes();
    if p.first() == Some(&b'^') {
        return match_here(&p[1..], t);
    }
    let mut i = 0;
    loop {
        if match_here(p, &t[i..]) {
            return true;
        }
        if i >= t.len() {
            return false;
        }
        i += 1;
    }
}

fn match_here(p: &[u8], t: &[u8]) -> bool {
    let Some(&first) = p.first() else { return true };
    if p.get(1) == Some(&b'*') {
        return match_star(first, &p[2..], t);
    }
    if p == b"$" {
        return t.is_empty();
    }
    match t.first() {
        Some(&c) if first == b'.' || first == c => {
            match_here(&p[1..], &t[1..])
        }
        _ => false,
    }
}

fn match_star(c: u8, p: &[u8], t: &[u8]) -> bool {
    let mut i = 0;
    loop {
        if match_here(p, &t[i..]) {
            return true;
        }
        match t.get(i) {
            Some(&x) if c == b'.' || x == c => i += 1,
            _ => return false,
        }
    }
}

/// CLI entry: read both artifacts, diff under the pinned rules plus
/// `--fail-on` extras, return the rendered report and whether to fail.
pub fn benchdiff(
    old_path: &Path,
    new_path: &Path,
    fail_on: &[String],
) -> Result<(String, bool), String> {
    let mut extra = Vec::new();
    for s in fail_on {
        extra.push(parse_fail_on(s)?);
    }
    let old = read(old_path).map_err(|e| e.to_string())?;
    let new = read(new_path).map_err(|e| e.to_string())?;
    let report = diff(&old, &new, &extra);
    Ok((report.render(), report.regressed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_parseable_schema_v1() {
        let dir = std::env::temp_dir().join("fullw2v_obs_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        emit(
            &path,
            "bench_test",
            obj(vec![("rows", Json::Num(8.0))]),
            vec![(
                "latency",
                obj(vec![("p50_us", Json::Num(1.25))]),
            )],
        )
        .unwrap();
        let doc = Json::parse(std::fs::read_to_string(&path).unwrap().trim())
            .unwrap();
        assert_eq!(doc.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("bench_test"));
        assert!(doc.get("git_rev").unwrap().as_str().is_some());
        assert_eq!(
            doc.get("config").unwrap().get("rows").unwrap().as_usize(),
            Some(8)
        );
        assert_eq!(
            doc.get("latency").unwrap().get("p50_us").unwrap().as_f64(),
            Some(1.25)
        );
        std::fs::remove_file(&path).ok();
    }

    /// A minimal but representative artifact: probe-plan rows, reuse
    /// ratio, roofline fraction, latency quantiles, a stage breakdown.
    fn fixture(
        rows_loaded: f64,
        neg_reuse: f64,
        achieved: f64,
        p99: f64,
        stage_a: f64,
        stage_b: f64,
    ) -> Json {
        Json::parse(&format!(
            r#"{{
              "schema": 1, "bench": "bench_serve", "git_rev": "abc",
              "created_unix": 1754000000,
              "config": {{"shards": 4}},
              "probe_plan": [
                {{"nprobe": 4, "rows_loaded_per_query": {rows_loaded}}}
              ],
              "scan_reuse": {{"rows_advanced": 5000, "neg_reuse": {neg_reuse}}},
              "roofline": {{"achieved_frac": {achieved}}},
              "latency": {{"p50_us": 100, "p99_us": {p99}}},
              "stages": {{"shard_scan": {stage_a}, "topk_merge": {stage_b}}}
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn flatten_produces_dotted_paths_and_skips_identity_fields() {
        let flat = flatten(&fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0));
        assert_eq!(flat.get("probe_plan.0.rows_loaded_per_query"), Some(&1000.0));
        assert_eq!(flat.get("scan_reuse.neg_reuse"), Some(&4.0));
        assert_eq!(flat.get("latency.p99_us"), Some(&900.0));
        assert_eq!(flat.get("stages.shard_scan"), Some(&8.0));
        assert_eq!(flat.get("config.shards"), Some(&4.0));
        // run identity never participates in diffing
        assert!(!flat.contains_key("schema"));
        assert!(!flat.contains_key("created_unix"));
    }

    #[test]
    fn rx_matcher_covers_the_rule_subset() {
        assert!(rx_match("rows_loaded_per_query$", "probe_plan.0.rows_loaded_per_query"));
        assert!(!rx_match("rows_loaded_per_query$", "rows_loaded_per_query_x"));
        assert!(rx_match("^latency", "latency.p50_us"));
        assert!(!rx_match("^latency", "x.latency.p50_us"));
        assert!(rx_match("p.._us$", "latency.p99_us"));
        assert!(rx_match("probe.*query$", "probe_plan.0.rows_loaded_per_query"));
        assert!(rx_match("a*b", "b"));
        assert!(rx_match("a*b", "aaab"));
        assert!(!rx_match("^a*b$", "aaac"));
        assert!(rx_match("", "anything"));
        assert!(rx_match("^$", ""));
        assert!(!rx_match("^$", "x"));
    }

    #[test]
    fn identical_artifacts_pass() {
        let a = fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        let report = diff(&a, &a, &[]);
        assert!(!report.regressed(), "{}", report.render());
        assert!(report.compared > 0);
        assert!(report.missing.is_empty());
    }

    #[test]
    fn rows_loaded_regression_fails_improvement_passes() {
        let old = fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        // +20% rows loaded per query: past the 10% gate
        let worse = fixture(1200.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        let report = diff(&old, &worse, &[]);
        assert!(report.regressed());
        assert!(report
            .regressions
            .iter()
            .any(|r| r.path == "probe_plan.0.rows_loaded_per_query"));
        let shown = report.render();
        assert!(shown.contains("REGRESSION"), "{shown}");
        assert!(shown.contains("rows_loaded_per_query"), "{shown}");
        // -20% is an improvement under LowerIsBetter: no finding
        let better = fixture(800.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        assert!(!diff(&old, &better, &[]).regressed());
    }

    #[test]
    fn higher_is_better_series_gate_on_drops() {
        let old = fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        // reuse collapsing 4.0 -> 2.0 and roofline 0.5 -> 0.3 both fail
        let worse = fixture(1000.0, 2.0, 0.3, 900.0, 8.0, 2.0);
        let report = diff(&old, &worse, &[]);
        let paths: Vec<&str> =
            report.regressions.iter().map(|r| r.path.as_str()).collect();
        assert!(paths.contains(&"scan_reuse.neg_reuse"), "{paths:?}");
        assert!(paths.contains(&"roofline.achieved_frac"), "{paths:?}");
        // gains in those series never fail
        let better = fixture(1000.0, 8.0, 0.9, 900.0, 8.0, 2.0);
        assert!(!diff(&old, &better, &[]).regressed());
    }

    #[test]
    fn stage_shares_gate_on_point_drift_not_seconds() {
        let old = fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        // 10x slower runner, identical 80/20 shape: no finding
        let slower = fixture(1000.0, 4.0, 0.5, 900.0, 80.0, 20.0);
        assert!(!diff(&old, &slower, &[]).regressed());
        // same total, shape inverts 80/20 -> 20/80: both stages flagged
        let inverted = fixture(1000.0, 4.0, 0.5, 900.0, 2.0, 8.0);
        let report = diff(&old, &inverted, &[]);
        assert!(report
            .regressions
            .iter()
            .any(|r| r.path == "stages.shard_scan (share)"));
    }

    #[test]
    fn missing_sections_are_tolerated() {
        let old = fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        let new = Json::parse(
            r#"{"schema": 1, "bench": "bench_serve",
                "latency": {"p50_us": 100, "p99_us": 900}}"#,
        )
        .unwrap();
        let report = diff(&old, &new, &[]);
        assert!(!report.regressed(), "{}", report.render());
        assert!(report
            .missing
            .iter()
            .any(|m| m == "probe_plan.0.rows_loaded_per_query"));
    }

    #[test]
    fn fail_on_overrides_add_rules_in_both_directions() {
        let old = fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0);
        // p50 drifts +6%: passes the loose 50% default gate
        let new = Json::parse(
            r#"{"schema": 1,
                "latency": {"p50_us": 106, "p99_us": 900}}"#,
        )
        .unwrap();
        assert!(!diff(&old, &new, &[]).regressed());
        let strict = parse_fail_on("p50_us$=5").unwrap();
        assert_eq!(strict.direction, Direction::Either);
        let report = diff(&old, &new, &[strict.clone()]);
        assert!(report.regressed(), "{}", report.render());
        // Either also fires on drops past tolerance
        let dropped = Json::parse(
            r#"{"schema": 1,
                "latency": {"p50_us": 90, "p99_us": 900}}"#,
        )
        .unwrap();
        assert!(diff(&old, &dropped, &[strict]).regressed());

        assert!(parse_fail_on("nope").is_err());
        assert!(parse_fail_on("=5").is_err());
        assert!(parse_fail_on("x=fast").is_err());
        assert!(parse_fail_on("x=-2").is_err());
    }

    #[test]
    fn zero_baselines_never_divide() {
        let old = Json::parse(
            r#"{"schema": 1, "scan_reuse": {"neg_reuse": 0},
                "stages": {"a": 0, "b": 0}}"#,
        )
        .unwrap();
        let new = Json::parse(
            r#"{"schema": 1, "scan_reuse": {"neg_reuse": 3},
                "stages": {"a": 0, "b": 0}}"#,
        )
        .unwrap();
        let report = diff(&old, &new, &[]);
        assert!(!report.regressed(), "{}", report.render());
    }

    #[test]
    fn benchdiff_end_to_end_exit_semantics() {
        let dir = std::env::temp_dir().join("fullw2v_benchdiff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let old_p = dir.join("old.json");
        let new_p = dir.join("new.json");
        std::fs::write(
            &old_p,
            fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0).to_string(),
        )
        .unwrap();
        std::fs::write(
            &new_p,
            fixture(1000.0, 4.0, 0.5, 900.0, 8.0, 2.0).to_string(),
        )
        .unwrap();
        let (_, regressed) = benchdiff(&old_p, &new_p, &[]).unwrap();
        assert!(!regressed, "identical artifacts must pass");
        std::fs::write(
            &new_p,
            fixture(1300.0, 4.0, 0.5, 900.0, 8.0, 2.0).to_string(),
        )
        .unwrap();
        let (text, regressed) = benchdiff(&old_p, &new_p, &[]).unwrap();
        assert!(regressed, "injected +30% rows regression must fail");
        assert!(text.contains("rows_loaded_per_query"), "{text}");
        // malformed --fail-on and unreadable inputs surface as errors
        assert!(benchdiff(&old_p, &new_p, &["bogus".into()]).is_err());
        assert!(benchdiff(Path::new("/nonexistent.json"), &new_p, &[]).is_err());
        // schema gate: v2 documents are rejected, not misread
        std::fs::write(&new_p, r#"{"schema": 2}"#).unwrap();
        assert!(read(&new_p).is_err());
        std::fs::remove_file(&old_p).ok();
        std::fs::remove_file(&new_p).ok();
    }
}
