//! Stage timers: decompose a pipeline's wall time into named stages.
//!
//! [`StageTimes`] is an index-addressed accumulator over a fixed
//! `'static` stage-name list (one per pipeline: the serve engine's
//! queue-wait/batch-fill/... stages, the trainer's
//! corpus-iteration/context-ring/... stages). Per-worker instances merge
//! into one report, and because stages are measured as contiguous laps of
//! a single [`Span`] clock, their sums reconcile with the measured total
//! by construction — the invariant the reports assert in tests.

use std::time::Instant;

use crate::util::json::{obj, Json};
use crate::util::tables::{f, Table};

/// Lap clock: `lap_ns()` returns nanoseconds since the previous lap (or
/// construction) and restarts, so consecutive laps tile the elapsed time
/// with no gaps.
#[derive(Debug)]
pub struct Span {
    last: Instant,
}

impl Span {
    pub fn start() -> Self {
        Span { last: Instant::now() }
    }

    pub fn lap_ns(&mut self) -> u64 {
        let now = Instant::now();
        let ns = now.duration_since(self.last).as_nanos() as u64;
        self.last = now;
        ns
    }
}

/// Accumulated nanoseconds per named stage.
///
/// `Default` is the empty breakdown (no stages); `merge` lets an empty
/// instance adopt its peer's stage list, so reports can derive `Default`
/// and still fold worker-local breakdowns in.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageTimes {
    names: &'static [&'static str],
    nanos: Vec<u64>,
}

impl StageTimes {
    pub fn new(names: &'static [&'static str]) -> Self {
        StageTimes { names, nanos: vec![0; names.len()] }
    }

    /// Adopt a stage list if still empty (used by lazily-initialised
    /// owners that derive `Default`).
    pub fn ensure(&mut self, names: &'static [&'static str]) {
        if self.names.is_empty() {
            *self = StageTimes::new(names);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn add(&mut self, stage: usize, ns: u64) {
        self.nanos[stage] += ns;
    }

    pub fn get_ns(&self, stage: usize) -> u64 {
        self.nanos.get(stage).copied().unwrap_or(0)
    }

    /// Total across all stages, in nanoseconds.
    pub fn total_ns(&self) -> u64 {
        self.nanos.iter().sum()
    }

    pub fn names(&self) -> &'static [&'static str] {
        self.names
    }

    /// Iterate `(name, nanos)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.names.iter().copied().zip(self.nanos.iter().copied())
    }

    /// Fold another breakdown in. Panics if both are non-empty with
    /// different stage lists — stage sets are fixed per pipeline.
    pub fn merge(&mut self, other: &StageTimes) {
        if other.is_empty() {
            return;
        }
        if self.is_empty() {
            *self = other.clone();
            return;
        }
        assert_eq!(
            self.names, other.names,
            "cannot merge breakdowns with different stage sets"
        );
        for (a, b) in self.nanos.iter_mut().zip(&other.nanos) {
            *a += b;
        }
    }

    /// JSON object `{stage_name: seconds}` (additive report field).
    pub fn to_json(&self) -> Json {
        obj(self
            .iter()
            .map(|(name, ns)| (name, Json::Num(ns as f64 / 1e9)))
            .collect())
    }

    /// Human table of the breakdown: seconds and share of the total.
    pub fn render_table(&self, title: &str) -> String {
        let total = self.total_ns().max(1) as f64;
        let mut t = Table::new(title, &["stage", "seconds", "share"]);
        for (name, ns) in self.iter() {
            t.row(vec![
                name.to_string(),
                f(ns as f64 / 1e9, 4),
                format!("{:.1}%", 100.0 * ns as f64 / total),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const STAGES: &[&str] = &["a", "b", "c"];

    #[test]
    fn laps_tile_elapsed_time() {
        let mut span = Span::start();
        let mut times = StageTimes::new(STAGES);
        let begin = Instant::now();
        for stage in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(2));
            times.add(stage, span.lap_ns());
        }
        let wall = begin.elapsed().as_nanos() as u64;
        let total = times.total_ns();
        assert!(total >= 3 * 1_000_000, "laps too small: {total}");
        // contiguous laps cover the wall time up to clock-read jitter
        assert!(
            wall.saturating_sub(total) < 2_000_000,
            "laps {total} vs wall {wall}"
        );
    }

    #[test]
    fn merge_adds_and_empty_adopts() {
        let mut a = StageTimes::new(STAGES);
        a.add(0, 10);
        a.add(2, 5);
        let mut b = StageTimes::new(STAGES);
        b.add(0, 1);
        b.add(1, 2);
        a.merge(&b);
        assert_eq!(a.get_ns(0), 11);
        assert_eq!(a.get_ns(1), 2);
        assert_eq!(a.get_ns(2), 5);
        assert_eq!(a.total_ns(), 18);

        let mut empty = StageTimes::default();
        assert!(empty.is_empty());
        empty.merge(&a);
        assert_eq!(empty, a);
        a.merge(&StageTimes::default()); // no-op
        assert_eq!(empty, a);
    }

    #[test]
    fn json_and_table_shapes() {
        let mut t = StageTimes::new(STAGES);
        t.add(0, 1_500_000_000);
        t.add(1, 500_000_000);
        let j = t.to_json();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("c").unwrap().as_f64(), Some(0.0));
        let table = t.render_table("stages");
        assert!(table.contains("== stages =="));
        assert!(table.contains("75.0%"));
    }

    /// Merging breakdowns built over different stage lists must panic:
    /// stage sets are fixed per pipeline, and silently zipping mismatched
    /// lists would attribute time to the wrong stage names.
    #[test]
    fn merge_rejects_disjoint_stage_sets() {
        const OTHER: &[&str] = &["x", "y"];
        let mut a = StageTimes::new(STAGES);
        a.add(0, 1);
        let mut b = StageTimes::new(OTHER);
        b.add(1, 2);
        let err = std::panic::catch_unwind(move || a.merge(&b));
        assert!(err.is_err(), "disjoint stage sets must not merge");
    }

    /// Both empty-adopt directions: an empty breakdown adopts its peer's
    /// stage list, and merging an empty peer leaves the target untouched
    /// (including its name list).
    #[test]
    fn merge_empty_adopts_in_both_directions() {
        let mut filled = StageTimes::new(STAGES);
        filled.add(1, 42);

        let mut empty = StageTimes::default();
        empty.merge(&filled);
        assert_eq!(empty.names(), STAGES);
        assert_eq!(empty.get_ns(1), 42);

        let mut target = filled.clone();
        target.merge(&StageTimes::default());
        assert_eq!(target, filled);

        let mut both = StageTimes::default();
        both.merge(&StageTimes::default());
        assert!(both.is_empty());
        assert_eq!(both.total_ns(), 0);
    }

    /// The share column must not divide by zero when no time has been
    /// recorded: an all-zero breakdown renders 0.0% shares, not NaN/inf.
    #[test]
    fn render_table_normalizes_shares_at_zero_total() {
        let t = StageTimes::new(STAGES);
        assert_eq!(t.total_ns(), 0);
        let table = t.render_table("empty");
        assert!(table.contains("== empty =="));
        assert!(table.contains("0.0%"));
        assert!(!table.contains("NaN"));
        assert!(!table.contains("inf"));
    }

    #[test]
    fn ensure_initialises_once() {
        let mut t = StageTimes::default();
        t.ensure(STAGES);
        t.add(1, 7);
        t.ensure(STAGES); // second call must not reset
        assert_eq!(t.get_ns(1), 7);
    }
}
