//! Observability: histograms, counters, stage timers, Prometheus text.
//!
//! Single home for everything the repo uses to see *where time goes*,
//! mirroring the paper's methodology: FULL-W2V's speedup claims rest on
//! per-stage accounting (Tables 4-6 attribute the win to quantified
//! reductions in memory traffic per pipeline stage), so the serving and
//! training hot paths here carry the same decomposition.
//!
//! Layout:
//!
//! | module       | provides                                              |
//! |--------------|-------------------------------------------------------|
//! | [`hist`]     | constant-memory log2-bucketed latency [`Histogram`]   |
//! | [`registry`] | process-global named atomic [`Counter`]s/[`Gauge`]s   |
//! | [`stage`]    | [`StageTimes`] accumulator + [`Span`] lap clock       |
//! | [`prom`]     | hand-rolled Prometheus text exposition ([`PromWriter`])|
//! | [`artifact`] | `BENCH_*.json` artifact emitter + reader + `benchdiff`|
//! | [`trace`]    | per-request span trees in a bounded [`trace::TraceRing`]|
//!
//! Everything is dependency-free (like `util::json`) and cheap enough to
//! stay on in production paths: the histogram is a fixed ~15 KB of
//! buckets, counters are single relaxed atomics, stage timers are two
//! monotonic-clock reads per section, and the trace ring holds a bounded
//! number of recent span trees (oldest evicted).

pub mod artifact;
pub mod hist;
pub mod prom;
pub mod registry;
pub mod stage;
pub mod trace;

pub use hist::Histogram;
pub use prom::PromWriter;
pub use registry::{Counter, Gauge};
pub use stage::{Span, StageTimes};
pub use trace::TraceRing;
