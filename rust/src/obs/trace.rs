//! Request-scoped trace spans: per-request span trees in a bounded,
//! lock-sharded ring of recent traces.
//!
//! The aggregate stage timers ([`super::stage`]) answer "where does the
//! engine spend time overall"; this module answers "where did *this
//! request* spend its time".  A trace is identified by a `u64` id —
//! minted by the connection layer where `req_id` originates, or adopted
//! from the `x-fullw2v-trace` request header so an upstream tier (the
//! planned scatter-gather router) can nest a worker's spans under its
//! own.  The serving engine records one span tree per traced request:
//! a `request` root covering enqueue-to-reply, with child spans that
//! reuse the `SERVE_STAGES` stage vocabulary and tile the request's
//! portion of its batch's stage laps — the same sum-reconciliation
//! contract the aggregate timers keep with `busy_seconds`.
//!
//! Storage is a process-global ring ([`global`]) of the most recent
//! [`TRACE_RING_CAP`] traces, sharded across several mutexes so the
//! engine's dispatcher and the HTTP export path never serialize on one
//! lock.  Memory is constant: each shard is a bounded `VecDeque` that
//! evicts its oldest trace on overflow.  Export is pull-based via
//! `GET /debug/traces` ([`to_json`] newest-first, or [`to_chrome`] in
//! the Chrome trace-event format loadable in `chrome://tracing` /
//! Perfetto).
//!
//! Timestamps are monotonic nanoseconds relative to the recording
//! engine's start epoch — meaningful for intra-trace arithmetic and
//! cross-trace ordering within one process, not wall-clock times.

use crate::util::json::{obj, Json};
use crate::util::sync::lock_unpoisoned;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Traces retained by the process-global ring (oldest evicted first).
pub const TRACE_RING_CAP: usize = 256;

/// Mutex shards in a ring; traces land round-robin so concurrent
/// recorders (engine dispatcher) and readers (`/debug/traces`) rarely
/// contend on the same lock.
const RING_SHARDS: usize = 8;

/// One span in a trace: a named interval with an optional parent
/// (index into the owning trace's span vector).  Names are `'static`
/// because every recorded span reuses the fixed stage vocabulary —
/// recording never allocates strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRec {
    pub name: &'static str,
    /// Index of the parent span within the same trace (`None` = root).
    pub parent: Option<u16>,
    /// Monotonic ns relative to the recording engine's start epoch.
    pub start_ns: u64,
    pub end_ns: u64,
}

impl SpanRec {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// One recorded trace: the request's id and its span tree (span 0 is
/// the root by convention — the engine records `request` first).
#[derive(Debug, Clone)]
pub struct Trace {
    pub id: u64,
    pub spans: Vec<SpanRec>,
    /// Global recording sequence number — the newest-first sort key
    /// across shards.
    seq: u64,
}

impl Trace {
    /// The root span, if the trace has any spans at all.
    pub fn root(&self) -> Option<&SpanRec> {
        self.spans.first()
    }
}

/// Bounded, lock-sharded ring of recent traces.
pub struct TraceRing {
    shards: Vec<Mutex<VecDeque<Trace>>>,
    per_shard: usize,
    seq: AtomicU64,
}

impl TraceRing {
    /// A ring retaining at most `cap` traces (rounded up to a multiple
    /// of the shard count so every shard gets an equal bound).
    pub fn with_capacity(cap: usize) -> TraceRing {
        let per_shard = cap.div_ceil(RING_SHARDS).max(1);
        TraceRing {
            shards: (0..RING_SHARDS)
                .map(|_| {
                    Mutex::new(VecDeque::with_capacity(per_shard))
                })
                .collect(),
            per_shard,
            seq: AtomicU64::new(0),
        }
    }

    /// Record one trace.  Constant memory: the target shard evicts its
    /// oldest trace when full.  The only allocation on this path is the
    /// span vector the caller already built.
    pub fn record(&self, id: u64, spans: Vec<SpanRec>) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        // round-robin placement keeps eviction age-uniform across
        // shards and spreads recorder contention
        let idx = (seq as usize) % self.shards.len();
        let Some(shard) = self.shards.get(idx) else { return };
        let mut q = lock_unpoisoned(shard);
        if q.len() >= self.per_shard {
            q.pop_front();
        }
        q.push_back(Trace { id, spans, seq });
    }

    /// Up to `n` most recent traces, newest first.
    pub fn snapshot(&self, n: usize) -> Vec<Trace> {
        let mut out: Vec<Trace> = Vec::new();
        for shard in &self.shards {
            out.extend(lock_unpoisoned(shard).iter().cloned());
        }
        out.sort_by(|a, b| b.seq.cmp(&a.seq));
        out.truncate(n);
        out
    }

    /// Traces currently retained (bounded by the ring capacity).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-global trace ring (what the engine records into and
/// `GET /debug/traces` serves).  Process-global for the same reason the
/// metric registry is: the recorder (engine dispatcher) and the
/// exporter (HTTP front-end) meet here without threading a handle
/// through every constructor.
pub fn global() -> &'static TraceRing {
    static RING: OnceLock<TraceRing> = OnceLock::new();
    RING.get_or_init(|| TraceRing::with_capacity(TRACE_RING_CAP))
}

fn span_json(s: &SpanRec) -> Json {
    obj(vec![
        ("name", Json::Str(s.name.to_string())),
        (
            "parent",
            s.parent
                .map(|p| Json::Num(p as f64))
                .unwrap_or(Json::Null),
        ),
        ("start_ns", Json::Num(s.start_ns as f64)),
        ("end_ns", Json::Num(s.end_ns as f64)),
        ("dur_ns", Json::Num(s.duration_ns() as f64)),
    ])
}

/// JSON export: `{"traces":[{trace_id, spans:[...]}, ...]}`, in the
/// order given (callers pass a newest-first [`TraceRing::snapshot`]).
/// Trace ids are emitted as decimal strings — a wire-adopted id can use
/// the full `u64` range, which `f64` JSON numbers cannot carry exactly.
pub fn to_json(traces: &[Trace]) -> Json {
    obj(vec![(
        "traces",
        Json::Arr(
            traces
                .iter()
                .map(|t| {
                    obj(vec![
                        ("trace_id", Json::Str(t.id.to_string())),
                        (
                            "spans",
                            Json::Arr(
                                t.spans.iter().map(span_json).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        ),
    )])
}

/// Chrome trace-event export: `{"traceEvents":[...]}` with one
/// complete (`ph:"X"`) event per span, `ts`/`dur` in microseconds —
/// the JSON object format `chrome://tracing` and Perfetto load
/// directly.  Each trace gets its own `tid` lane so concurrent
/// requests render as parallel tracks.
pub fn to_chrome(traces: &[Trace]) -> Json {
    let mut events = Vec::new();
    for (lane, t) in traces.iter().enumerate() {
        for s in &t.spans {
            events.push(obj(vec![
                ("name", Json::Str(s.name.to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(s.start_ns as f64 / 1e3)),
                ("dur", Json::Num(s.duration_ns() as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num((lane + 1) as f64)),
                (
                    "args",
                    obj(vec![(
                        "trace_id",
                        Json::Str(t.id.to_string()),
                    )]),
                ),
            ]));
        }
    }
    obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans(base: u64) -> Vec<SpanRec> {
        vec![
            SpanRec {
                name: "request",
                parent: None,
                start_ns: base,
                end_ns: base + 100,
            },
            SpanRec {
                name: "queue_wait",
                parent: Some(0),
                start_ns: base,
                end_ns: base + 40,
            },
            SpanRec {
                name: "shard_scan",
                parent: Some(0),
                start_ns: base + 40,
                end_ns: base + 100,
            },
        ]
    }

    #[test]
    fn ring_is_bounded_and_evicts_oldest() {
        let ring = TraceRing::with_capacity(16);
        for i in 0..50u64 {
            ring.record(i, spans(i * 1000));
        }
        // rounded-up per-shard bound: never more than cap + shard slack
        assert!(ring.len() <= 16, "len {} exceeds cap", ring.len());
        let snap = ring.snapshot(usize::MAX);
        assert_eq!(snap.len(), ring.len());
        // everything retained is from the newest recordings
        assert!(
            snap.iter().all(|t| t.id >= 50 - 16),
            "oldest traces must be evicted first"
        );
    }

    #[test]
    fn snapshot_is_newest_first_and_truncates() {
        let ring = TraceRing::with_capacity(64);
        for i in 0..20u64 {
            ring.record(i, spans(i));
        }
        let snap = ring.snapshot(5);
        assert_eq!(snap.len(), 5);
        let ids: Vec<u64> = snap.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![19, 18, 17, 16, 15]);
    }

    #[test]
    fn concurrent_recorders_never_exceed_the_bound() {
        let ring = std::sync::Arc::new(TraceRing::with_capacity(32));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let ring = ring.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        ring.record(t * 1000 + i, spans(i));
                    }
                });
            }
        });
        assert!(ring.len() <= 32);
        assert_eq!(ring.snapshot(usize::MAX).len(), ring.len());
    }

    #[test]
    fn json_export_round_trips_ids_and_span_tree() {
        let ring = TraceRing::with_capacity(8);
        ring.record(u64::MAX, spans(0)); // full-range id stays exact
        let j = to_json(&ring.snapshot(8));
        let text = j.to_string();
        let parsed = Json::parse(&text).expect("valid JSON");
        let traces = parsed
            .get("traces")
            .and_then(|t| t.as_arr())
            .expect("traces array");
        assert_eq!(traces.len(), 1);
        assert_eq!(
            traces[0].get("trace_id").and_then(|v| v.as_str()),
            Some(u64::MAX.to_string()).as_deref()
        );
        let spans = traces[0]
            .get("spans")
            .and_then(|s| s.as_arr())
            .expect("spans array");
        assert_eq!(spans.len(), 3);
        assert_eq!(
            spans[0].get("name").and_then(|v| v.as_str()),
            Some("request")
        );
        assert!(matches!(spans[0].get("parent"), Some(Json::Null)));
        assert_eq!(
            spans[1].get("parent").and_then(|v| v.as_f64()),
            Some(0.0)
        );
        assert_eq!(
            spans[2].get("dur_ns").and_then(|v| v.as_f64()),
            Some(60.0)
        );
    }

    #[test]
    fn chrome_export_emits_matched_complete_events() {
        let ring = TraceRing::with_capacity(8);
        ring.record(7, spans(2_000));
        ring.record(8, spans(3_000));
        let j = to_chrome(&ring.snapshot(8));
        let parsed = Json::parse(&j.to_string()).expect("valid JSON");
        let events = parsed
            .get("traceEvents")
            .and_then(|e| e.as_arr())
            .expect("traceEvents array");
        assert_eq!(events.len(), 6, "one X event per span");
        for e in events {
            assert_eq!(e.get("ph").and_then(|v| v.as_str()), Some("X"));
            let ts = e.get("ts").and_then(|v| v.as_f64()).expect("ts");
            let dur = e.get("dur").and_then(|v| v.as_f64()).expect("dur");
            assert!(ts >= 0.0 && dur >= 0.0);
            assert!(e.get("name").is_some() && e.get("tid").is_some());
        }
        // the two traces render on distinct lanes
        let tids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter_map(|e| e.get("tid").and_then(|v| v.as_f64()))
            .map(|t| t as u64)
            .collect();
        assert_eq!(tids.len(), 2);
    }

    #[test]
    fn global_ring_is_shared() {
        let before = global().len();
        global().record(0xDEAD_BEEF, spans(1));
        assert!(global().len() >= 1);
        assert!(global().len() >= before.min(TRACE_RING_CAP));
        assert!(global()
            .snapshot(TRACE_RING_CAP)
            .iter()
            .any(|t| t.id == 0xDEAD_BEEF));
    }
}
