//! Hand-rolled Prometheus text exposition (no deps, like `util::json`).
//!
//! Emits the subset of the text format scrapers actually require:
//! `# HELP` / `# TYPE` headers once per family, `name{labels} value`
//! samples, and histograms as cumulative `_bucket{le="..."}` series
//! terminated by `le="+Inf"` plus `_sum` and `_count`. Durations are
//! exported in seconds per Prometheus convention; callers pass a scale
//! factor to convert from their native nanoseconds.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use super::hist::Histogram;

/// Accumulates one exposition document.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
    seen: BTreeSet<String>,
}

/// Prometheus sample values: integers render without a fraction.
fn num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

impl PromWriter {
    pub fn new() -> Self {
        PromWriter::default()
    }

    fn head(&mut self, name: &str, help: &str, typ: &str) {
        if self.seen.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {typ}");
        }
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: &str) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                let _ = write!(self.out, "{k}=\"{}\"", escape(v));
            }
            self.out.push('}');
        }
        let _ = writeln!(self.out, " {value}");
    }

    pub fn counter(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        v: f64,
    ) {
        self.head(name, help, "counter");
        self.sample(name, labels, &num(v));
    }

    pub fn gauge(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        v: f64,
    ) {
        self.head(name, help, "gauge");
        self.sample(name, labels, &num(v));
    }

    /// Emit a histogram family from a nanosecond [`Histogram`].
    ///
    /// `scale` converts recorded nanoseconds into the exported unit
    /// (`1e-9` for seconds). Empty buckets are skipped — cumulative
    /// `le` series stay valid as long as bounds ascend and `+Inf` ends
    /// the list, which they do.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        hist: &Histogram,
        scale: f64,
    ) {
        self.head(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        let mut with_le = |le: &str, cum: u64| {
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", le));
            self.sample(&bucket, &ls, &num(cum as f64));
        };
        for (upper_ns, cum) in hist.cumulative_buckets() {
            with_le(&format!("{}", upper_ns as f64 * scale), cum);
        }
        with_le("+Inf", hist.count());
        self.sample(
            &format!("{name}_sum"),
            labels,
            &num(hist.sum_ns() as f64 * scale),
        );
        self.sample(
            &format!("{name}_count"),
            labels,
            &num(hist.count() as f64),
        );
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render() {
        let mut w = PromWriter::new();
        w.counter("x_total", "an x", &[], 3.0);
        w.counter("x_total", "an x", &[("a", "b")], 4.0);
        w.gauge("g", "a g", &[], 1.5);
        let t = w.finish();
        // header appears once even with two series in the family
        assert_eq!(t.matches("# TYPE x_total counter").count(), 1);
        assert!(t.contains("x_total 3\n"));
        assert!(t.contains("x_total{a=\"b\"} 4\n"));
        assert!(t.contains("# TYPE g gauge"));
        assert!(t.contains("g 1.5\n"));
    }

    #[test]
    fn label_values_escaped() {
        let mut w = PromWriter::new();
        w.counter("e_total", "h", &[("p", "a\"b\\c\nd")], 1.0);
        let t = w.finish();
        assert!(t.contains("e_total{p=\"a\\\"b\\\\c\\nd\"} 1"));
    }

    #[test]
    fn histogram_series_shape() {
        let mut h = Histogram::new();
        for ns in [500u64, 1_500, 1_500, 2_000_000] {
            h.record(ns);
        }
        let mut w = PromWriter::new();
        w.histogram("lat_seconds", "latency", &[("route", "nn")], &h, 1e-9);
        let t = w.finish();
        assert!(t.contains("# TYPE lat_seconds histogram"));
        assert!(t.contains("lat_seconds_bucket{route=\"nn\",le=\"+Inf\"} 4"));
        assert!(t.contains("lat_seconds_count{route=\"nn\"} 4"));
        assert!(t.contains("lat_seconds_sum{route=\"nn\"}"));
        // cumulative counts never decrease across the le series
        let mut last = 0u64;
        for line in t.lines().filter(|l| l.starts_with("lat_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone bucket series: {t}");
            last = v;
        }
    }
}
