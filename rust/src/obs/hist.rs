//! Constant-memory latency histogram with log2-spaced buckets.
//!
//! Replaces the unbounded sample vectors the metrics layer used to keep:
//! a `Histogram` is a fixed array of bucket counts (~15 KB), so recording
//! is O(1), memory never grows with traffic, and two histograms merge by
//! adding counts — which is what lets per-worker recorders fold into one
//! report without sharing a lock on the hot path.
//!
//! Bucketing is log-linear (the HdrHistogram scheme): each power-of-two
//! octave is split into [`SUB`] linear sub-buckets, so the relative width
//! of any bucket is at most `1/SUB` (~3%). Quantiles come from a
//! cumulative walk plus linear interpolation inside the final bucket;
//! the estimate always lands in the same bucket as the exact nearest-rank
//! value, so its error is bounded by one bucket's width. The all-time
//! `max` (and `sum`/`count`) are tracked exactly on the side, because
//! reports promise an exact maximum.

/// Sub-buckets per power-of-two octave (as a power of two).
const SUB_BITS: u32 = 5;
/// Sub-buckets per octave; bounds per-bucket relative width to `1/SUB`.
pub const SUB: usize = 1 << SUB_BITS;
/// Total bucket count covering the full `u64` nanosecond range.
pub const BUCKETS: usize = SUB * (64 - SUB_BITS as usize + 1);

/// Bucket index for a nanosecond value. Values below `2*SUB` map to
/// themselves (exact); above that, the top `SUB_BITS+1` significant bits
/// select the bucket.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let m = 63 - v.leading_zeros(); // m >= SUB_BITS
    let sub = ((v >> (m - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    (m - SUB_BITS + 1) as usize * SUB + sub
}

/// Inclusive lower bound of a bucket.
#[inline]
fn bucket_lower(idx: usize) -> u64 {
    if idx < 2 * SUB {
        return idx as u64;
    }
    let major = idx / SUB; // >= 2
    let sub = idx % SUB;
    ((SUB + sub) as u64) << (major - 1)
}

/// Inclusive upper bound of a bucket.
#[inline]
fn bucket_upper(idx: usize) -> u64 {
    if idx + 1 >= BUCKETS {
        u64::MAX
    } else {
        bucket_lower(idx + 1) - 1
    }
}

/// Mergeable log2-bucketed histogram of nanosecond durations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>, // len BUCKETS, fixed at construction
    count: u64,
    sum_ns: u64, // saturating; exact for < ~584 years of total latency
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }

    /// Record one duration in nanoseconds.
    pub fn record(&mut self, ns: u64) {
        self.counts[bucket_index(ns)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Fold another histogram into this one (bucket-wise add).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Exact all-time maximum recorded value.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Nearest-rank quantile estimate in nanoseconds, `q` in [0, 1].
    ///
    /// Walks the cumulative counts to the bucket holding rank
    /// `ceil(q * count)` and interpolates linearly inside it; the result
    /// is clamped to the exact maximum so `quantile(1.0) == max_ns`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &n) in self.counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if cum + n >= rank {
                let lo = bucket_lower(idx) as f64;
                let hi = bucket_upper(idx) as f64 + 1.0; // exclusive end
                let pos = (rank - cum) as f64 / n as f64;
                return (lo + (hi - lo) * pos).min(self.max_ns as f64);
            }
            cum += n;
        }
        self.max_ns as f64
    }

    /// Non-empty buckets as `(inclusive_upper_ns, cumulative_count)`,
    /// ascending — the shape Prometheus `_bucket{le=...}` series need.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (idx, &n) in self.counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            cum += n;
            out.push((bucket_upper(idx), cum));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn bucket_index_boundaries() {
        // 0 and 1ns land in their own exact buckets; u64::MAX in the last.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // every bucket's bounds roundtrip through the index
        for idx in 0..BUCKETS {
            assert_eq!(bucket_index(bucket_lower(idx)), idx, "lower of {idx}");
            assert_eq!(bucket_index(bucket_upper(idx)), idx, "upper of {idx}");
        }
        // buckets tile the range with no gaps
        for idx in 0..BUCKETS - 1 {
            assert_eq!(bucket_upper(idx) + 1, bucket_lower(idx + 1));
        }
        assert_eq!(bucket_upper(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn records_extremes_exactly() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(1);
        h.record(u64::MAX);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_ns(), u64::MAX);
        assert_eq!(h.sum_ns(), u64::MAX); // saturated
        assert!(h.quantile(0.0) <= 1.0); // rank 1 interpolates inside [0,0]
        assert!(h.quantile(0.34) <= 2.0);
        assert_eq!(h.quantile(1.0), u64::MAX as f64);
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        let mk = |seed: u64, n: usize| {
            let mut rng = Pcg32::new(seed);
            let mut h = Histogram::new();
            for _ in 0..n {
                h.record(rng.next_u64() >> (rng.next_u32() % 40));
            }
            h
        };
        let (a, b, c) = (mk(1, 500), mk(2, 300), mk(3, 700));

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must commute");

        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge must associate");
        assert_eq!(ab_c.count(), 1500);
    }

    /// Merging histograms whose samples occupy disjoint octaves must
    /// keep both populations intact: counts add, the max comes from the
    /// high histogram, and quantiles straddle the gap correctly.
    #[test]
    fn merge_across_disjoint_octave_ranges() {
        // low: 1000 samples in the exact/linear range (< 2*SUB)
        let mut low = Histogram::new();
        for i in 0..1000u64 {
            low.record(i % (2 * SUB as u64));
        }
        // high: 1000 samples many octaves up (~1ms .. ~2ms)
        let mut high = Histogram::new();
        for i in 0..1000u64 {
            high.record(1_000_000 + i * 1_000);
        }
        assert_eq!(low.max_ns(), 2 * SUB as u64 - 1);
        assert!(high.quantile(0.01) >= 1_000_000.0);

        let mut merged = low.clone();
        merged.merge(&high);
        assert_eq!(merged.count(), 2000);
        assert_eq!(merged.max_ns(), high.max_ns());
        assert_eq!(
            merged.sum_ns(),
            low.sum_ns() + high.sum_ns(),
            "disjoint octaves must not collide in any bucket"
        );
        // the median sits at the boundary between the two populations:
        // p49 still in the low range, p51 already in the high range
        assert!(merged.quantile(0.49) < 2.0 * 2.0 * SUB as f64);
        assert!(merged.quantile(0.51) >= 1_000_000.0);
        // cumulative buckets cover both clusters and end at the total
        let b = merged.cumulative_buckets();
        assert_eq!(b.last().unwrap().1, 2000);
        assert!(b.iter().any(|&(upper, _)| upper < 2 * SUB as u64));
        assert!(b.iter().any(|&(upper, _)| upper >= 1_000_000));
    }

    #[test]
    fn quantiles_are_monotone_in_q() {
        let mut rng = Pcg32::new(7);
        let mut h = Histogram::new();
        for _ in 0..2000 {
            h.record(1 + rng.next_u64() % 5_000_000);
        }
        let mut prev = -1.0;
        for i in 0..=100 {
            let q = i as f64 / 100.0;
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn quantiles_agree_with_nearest_rank_within_one_bucket() {
        for seed in [11u64, 12, 13] {
            let mut rng = Pcg32::new(seed);
            let mut h = Histogram::new();
            let mut samples = Vec::new();
            for _ in 0..1000 {
                // spread over several octaves: 1ns .. ~16ms
                let v = 1 + (rng.next_u64() % (1u64 << (4 + rng.next_u32() % 20)));
                samples.push(v);
                h.record(v);
            }
            samples.sort_unstable();
            for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
                let rank = ((q * samples.len() as f64).ceil() as usize)
                    .clamp(1, samples.len());
                let exact = samples[rank - 1] as f64;
                let est = h.quantile(q);
                // one bucket's width: relative 1/SUB above the linear
                // range, absolute 1 below it (+1 for the exclusive end)
                let tol = (exact / SUB as f64).max(1.0) + 1.0;
                assert!(
                    (est - exact).abs() <= tol,
                    "seed {seed} q {q}: est {est} exact {exact} tol {tol}"
                );
            }
        }
    }

    #[test]
    fn cumulative_buckets_end_at_count() {
        let mut h = Histogram::new();
        for v in [5u64, 5, 70, 4096, 1_000_000] {
            h.record(v);
        }
        let b = h.cumulative_buckets();
        assert!(!b.is_empty());
        assert_eq!(b.last().unwrap().1, h.count());
        // cumulative counts and upper bounds both strictly ascend
        for w in b.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }
}
