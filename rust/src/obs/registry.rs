//! Process-global registry of named atomic counters and gauges.
//!
//! Names and label sets are `&'static str`, so the registry is bounded by
//! the set of metric sites compiled into the binary — no per-request
//! allocation, no cardinality explosions. Handles are `Arc<AtomicU64>`
//! wrappers: registering the same `(name, labels)` twice returns the same
//! underlying cell, so call sites can re-register cheaply instead of
//! caching handles through plumbing.
//!
//! The registry renders itself into the Prometheus exposition via
//! [`render`]; histograms live outside it (they are owned by their
//! subsystems and snapshotted at scrape time).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::prom::PromWriter;

/// Fixed label set attached at registration; `&[]` for none.
pub type LabelSet = &'static [(&'static str, &'static str)];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
}

struct Entry {
    kind: Kind,
    help: &'static str,
    value: Arc<AtomicU64>,
}

type Map = BTreeMap<(&'static str, LabelSet), Entry>;

fn registry() -> &'static Mutex<Map> {
    static REGISTRY: OnceLock<Mutex<Map>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn register(
    name: &'static str,
    help: &'static str,
    labels: LabelSet,
    kind: Kind,
) -> Arc<AtomicU64> {
    let mut map = registry().lock().unwrap();
    let entry = map.entry((name, labels)).or_insert_with(|| Entry {
        kind,
        help,
        value: Arc::new(AtomicU64::new(0)),
    });
    assert_eq!(
        entry.kind, kind,
        "metric {name} re-registered with a different kind"
    );
    Arc::clone(&entry.value)
}

/// Monotonically increasing counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        // ORDERING: Relaxed — an independent monotone counter; no other
        // memory is published through it, only the value itself.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ORDERING: Relaxed — scrape-time read of a statistic; staleness
        // by a few increments is fine and orders nothing.
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous non-negative value.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        // ORDERING: Relaxed — last-writer-wins telemetry value; nothing
        // synchronizes on a gauge.
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        // ORDERING: Relaxed — independent statistic, same as Counter::add.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: u64) {
        // saturating decrement: gauges never wrap below zero
        // ORDERING: Relaxed (both) — the RMW itself is atomic, which is
        // all saturation needs; gauges guard no other state.
        let _ = self.0.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(n)),
        );
    }

    pub fn get(&self) -> u64 {
        // ORDERING: Relaxed — scrape-time read, same as Counter::get.
        self.0.load(Ordering::Relaxed)
    }
}

/// Get-or-register an unlabelled counter.
pub fn counter(name: &'static str, help: &'static str) -> Counter {
    counter_with(name, help, &[])
}

/// Get-or-register a counter with a fixed label set.
pub fn counter_with(
    name: &'static str,
    help: &'static str,
    labels: LabelSet,
) -> Counter {
    Counter(register(name, help, labels, Kind::Counter))
}

/// Get-or-register an unlabelled gauge.
pub fn gauge(name: &'static str, help: &'static str) -> Gauge {
    gauge_with(name, help, &[])
}

/// Get-or-register a gauge with a fixed label set.
pub fn gauge_with(
    name: &'static str,
    help: &'static str,
    labels: LabelSet,
) -> Gauge {
    Gauge(register(name, help, labels, Kind::Gauge))
}

/// Refresh the `process_rss_bytes` / `process_threads` self-metrics
/// from `/proc/self` (linux; a graceful no-op elsewhere).  Called by
/// the `/metrics` handler before rendering, so every scrape samples the
/// process fresh without a background thread.  The RSS gauge is what
/// makes the mmap cold tier observable: `bytes_mapped` counts mapped
/// shard bytes, this counts what the kernel actually keeps resident.
pub fn refresh_process_metrics() {
    if let Some((rss_bytes, threads)) = sample_proc_self() {
        gauge(
            "process_rss_bytes",
            "resident set size sampled from /proc/self/statm",
        )
        .set(rss_bytes);
        gauge(
            "process_threads",
            "kernel thread count sampled from /proc/self/stat",
        )
        .set(threads);
    }
}

/// `(rss_bytes, num_threads)` for this process, or `None` off-linux /
/// on any parse surprise (telemetry must never fail the scrape).
#[cfg(target_os = "linux")]
fn sample_proc_self() -> Option<(u64, u64)> {
    // statm field 2 is resident pages; the kernel reports pages of
    // PAGE_SIZE, which is 4096 on every platform this tree targets (no
    // libc to ask at runtime — an observability-grade assumption)
    const PAGE_SIZE: u64 = 4096;
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let rss_pages: u64 =
        statm.split_whitespace().nth(1)?.parse().ok()?;
    // stat field 20 is num_threads, but the comm field (2) is an
    // arbitrary parenthesized string — parse from after the LAST ')'
    // so a comm containing ')' cannot shift the field offsets
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    let after_comm = stat.rsplit_once(')')?.1;
    // fields after the comm start at state (3), so num_threads (20) is
    // the 18th whitespace-separated token here (index 17)
    let threads: u64 =
        after_comm.split_whitespace().nth(17)?.parse().ok()?;
    Some((rss_pages * PAGE_SIZE, threads))
}

#[cfg(not(target_os = "linux"))]
fn sample_proc_self() -> Option<(u64, u64)> {
    None
}

/// Render every registered metric into a Prometheus exposition writer.
pub fn render(w: &mut PromWriter) {
    let map = registry().lock().unwrap();
    for ((name, labels), entry) in map.iter() {
        // ORDERING: Relaxed — exposition snapshot; each series is read
        // independently and tear-free per cell, which is all Prometheus
        // semantics ask for.
        let v = entry.value.load(Ordering::Relaxed) as f64;
        match entry.kind {
            Kind::Counter => w.counter(name, entry.help, labels, v),
            Kind::Gauge => w.gauge(name, entry.help, labels, v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_returns_same_cell() {
        let a = counter("obs_test_requests_total", "test counter");
        let b = counter("obs_test_requests_total", "test counter");
        let before = a.get();
        b.add(3);
        a.inc();
        assert_eq!(a.get(), before + 4);
        assert_eq!(b.get(), a.get());
    }

    #[test]
    fn labels_distinguish_series() {
        let x = counter_with(
            "obs_test_labeled_total",
            "test",
            &[("route", "x")],
        );
        let y = counter_with(
            "obs_test_labeled_total",
            "test",
            &[("route", "y")],
        );
        let (bx, by) = (x.get(), y.get());
        x.inc();
        assert_eq!(x.get(), bx + 1);
        assert_eq!(y.get(), by);
    }

    #[test]
    fn gauge_saturates_at_zero() {
        let g = gauge("obs_test_gauge", "test gauge");
        g.set(2);
        g.sub(5);
        assert_eq!(g.get(), 0);
        g.add(7);
        assert_eq!(g.get(), 7);
        g.set(0);
    }

    /// On linux the process self-metrics sample to plausible values and
    /// render as gauges; elsewhere the refresh is a silent no-op.
    #[test]
    fn process_metrics_refresh_and_render() {
        refresh_process_metrics();
        if cfg!(target_os = "linux") {
            let rss = gauge(
                "process_rss_bytes",
                "resident set size sampled from /proc/self/statm",
            );
            let threads = gauge(
                "process_threads",
                "kernel thread count sampled from /proc/self/stat",
            );
            // a running test binary is at least a page resident and at
            // least one thread; absurd values mean misparsed fields
            assert!(rss.get() >= 4096, "rss {}", rss.get());
            assert!(
                (1..100_000).contains(&threads.get()),
                "threads {}",
                threads.get()
            );
            let mut w = PromWriter::new();
            render(&mut w);
            let text = w.finish();
            assert!(text.contains("# TYPE process_rss_bytes gauge"));
            assert!(text.contains("# TYPE process_threads gauge"));
        }
    }

    #[test]
    fn renders_registered_series() {
        let c = counter_with(
            "obs_test_render_total",
            "render help",
            &[("kind", "unit")],
        );
        c.inc();
        let mut w = PromWriter::new();
        render(&mut w);
        let text = w.finish();
        assert!(text.contains("# HELP obs_test_render_total render help"));
        assert!(text.contains("# TYPE obs_test_render_total counter"));
        assert!(text.contains("obs_test_render_total{kind=\"unit\"}"));
    }
}
