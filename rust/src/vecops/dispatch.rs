//! Runtime SIMD dispatch: one table of kernel pointers, selected once
//! at startup by feature detection (or forced via `--simd` /
//! `FULLW2V_SIMD`).
//!
//! The contract: [`super::scalar`] is the semantic definition of every
//! kernel, and each SIMD backend must be **bit-identical** to it — not
//! merely close — so that dispatch level is unobservable to callers
//! (rankings, ties, stored scores, reproducible training runs).  That
//! holds because all backends share the scalar accumulation *shape*:
//! 8-lane f32 chunk accumulators reduced by the one shared
//! `scalar::reduce`, no FMA anywhere (a fused multiply-add rounds once
//! instead of twice and would diverge), and widening conversions
//! (i8 -> f32, f32 -> f64) that are exact by IEEE-754.
//!
//! Selection order: `--simd` flag > `FULLW2V_SIMD` env > runtime
//! detection (best of AVX-512 > AVX2 > NEON > scalar).  Forcing a level
//! the host lacks is a hard error; because every level is bit-identical,
//! re-forcing mid-process (benches and tests do this) is safe.

use std::sync::atomic::{AtomicU8, Ordering};

use super::{scalar, Q_TILE};

/// A dispatchable kernel level.  All variants exist on every
/// architecture (so CLI/env parsing is portable); availability is a
/// runtime property of the host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// The unrolled scalar reference kernels (always available).
    Scalar,
    /// x86-64 AVX2: 8-lane f32, widening int8 dot.
    Avx2,
    /// x86-64 AVX-512F: AVX2 dot bodies (the single-accumulator chain
    /// pins the width), 16-lane `axpy`, query-paired 512-bit tiles.
    Avx512,
    /// aarch64 NEON: 2x4-lane f32 (lane halves mirror the scalar
    /// accumulator array), widening int8 dot.
    Neon,
}

impl SimdLevel {
    pub const ALL: [SimdLevel; 4] = [
        SimdLevel::Scalar,
        SimdLevel::Avx2,
        SimdLevel::Avx512,
        SimdLevel::Neon,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Neon => "neon",
        }
    }

    /// Parse a `--simd` / `FULLW2V_SIMD` value.  `auto` means "detect"
    /// and parses to `None`.
    pub fn parse(s: &str) -> Result<Option<SimdLevel>, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(None),
            "scalar" => Ok(Some(SimdLevel::Scalar)),
            "avx2" => Ok(Some(SimdLevel::Avx2)),
            "avx512" => Ok(Some(SimdLevel::Avx512)),
            "neon" => Ok(Some(SimdLevel::Neon)),
            other => Err(format!(
                "unknown simd level '{other}' (expected auto|scalar|avx2|avx512|neon)"
            )),
        }
    }

    /// Whether this host can run the level (compile target + runtime
    /// CPUID/auxv feature detection).
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
            _ => false,
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            _ => false,
        }
    }

    /// f32 lanes per vector register at this level — the ISA width the
    /// CPU roofline model derives peak FLOP/s from.  Scalar is 1 by
    /// definition (the model scores *explicit* vector paths; the
    /// compiler may still autovectorize the scalar bodies, so a
    /// scalar-forced run can exceed its nominal ceiling).
    pub fn f32_lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 8,
            SimdLevel::Avx512 => 16,
            SimdLevel::Neon => 4,
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Best level this host supports.
pub fn detect_level() -> SimdLevel {
    for l in [SimdLevel::Avx512, SimdLevel::Avx2, SimdLevel::Neon] {
        if l.available() {
            return l;
        }
    }
    SimdLevel::Scalar
}

/// Every level this host supports, scalar first.
pub fn available_levels() -> Vec<SimdLevel> {
    SimdLevel::ALL.iter().copied().filter(|l| l.available()).collect()
}

type DotFn = unsafe fn(&[f32], &[f32]) -> f32;
type DotI8Fn = unsafe fn(&[i8], f32, &[f32]) -> f32;
type DotF64Fn = unsafe fn(&[f32], &[f32]) -> f64;
type AxpyFn = unsafe fn(f32, &[f32], &mut [f32]);
type Dot4Fn = unsafe fn(&[f32], [&[f32]; Q_TILE]) -> [f32; Q_TILE];
type Dot4I8Fn = unsafe fn(&[i8], f32, [&[f32]; Q_TILE]) -> [f32; Q_TILE];

// Scalar entries in the table: trivial unsafe shims so every slot has
// the same `unsafe fn` pointer type as the `#[target_feature]` paths.
// SAFETY: wraps a safe fn; `unsafe` only matches the pointer type.
unsafe fn scalar_dot(a: &[f32], b: &[f32]) -> f32 {
    scalar::dot(a, b)
}
// SAFETY: wraps a safe fn; `unsafe` only matches the pointer type.
unsafe fn scalar_dot_i8(codes: &[i8], scale: f32, x: &[f32]) -> f32 {
    scalar::dot_i8(codes, scale, x)
}
// SAFETY: wraps a safe fn; `unsafe` only matches the pointer type.
unsafe fn scalar_dot_f64(a: &[f32], b: &[f32]) -> f64 {
    scalar::dot_f64(a, b)
}
// SAFETY: wraps a safe fn; `unsafe` only matches the pointer type.
unsafe fn scalar_axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    scalar::axpy(alpha, x, y)
}
// SAFETY: wraps a safe fn; `unsafe` only matches the pointer type.
unsafe fn scalar_dot4(a: &[f32], b: [&[f32]; Q_TILE]) -> [f32; Q_TILE] {
    scalar::dot4(a, b)
}
// SAFETY: wraps a safe fn; `unsafe` only matches the pointer type.
unsafe fn scalar_dot4_i8(
    codes: &[i8],
    scale: f32,
    b: [&[f32]; Q_TILE],
) -> [f32; Q_TILE] {
    scalar::dot4_i8(codes, scale, b)
}

/// A resolved kernel table.  Obtainable only through [`active`] /
/// [`Dispatch::for_level`], both of which verify the level is available
/// on this host — that check is the safety argument for every call
/// through the `unsafe fn` pointers below.
#[derive(Clone, Copy)]
pub struct Dispatch {
    level: SimdLevel,
    dot: DotFn,
    dot_i8: DotI8Fn,
    dot_f64: DotF64Fn,
    axpy: AxpyFn,
    dot4: Dot4Fn,
    dot4_i8: Dot4I8Fn,
}

fn table(level: SimdLevel) -> Dispatch {
    let scalar_table = Dispatch {
        level: SimdLevel::Scalar,
        dot: scalar_dot,
        dot_i8: scalar_dot_i8,
        dot_f64: scalar_dot_f64,
        axpy: scalar_axpy,
        dot4: scalar_dot4,
        dot4_i8: scalar_dot4_i8,
    };
    match level {
        SimdLevel::Scalar => scalar_table,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => Dispatch {
            level: SimdLevel::Avx2,
            dot: super::simd_x86::dot_avx2,
            dot_i8: super::simd_x86::dot_i8_avx2,
            dot_f64: super::simd_x86::dot_f64_avx2,
            axpy: super::simd_x86::axpy_avx2,
            dot4: super::simd_x86::dot4_avx2,
            dot4_i8: super::simd_x86::dot4_i8_avx2,
        },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx512 => Dispatch {
            level: SimdLevel::Avx512,
            // The dot kernels keep their AVX2 bodies: the scalar
            // contract's single 8-lane accumulator chain pins the
            // vector width (a 16-lane or dual-accumulator dot would
            // change the summation order).  Only the width-agnostic
            // kernels go wider: 16-lane axpy, query-paired dot4.
            dot: super::simd_x86::dot_avx2,
            dot_i8: super::simd_x86::dot_i8_avx2,
            dot_f64: super::simd_x86::dot_f64_avx2,
            axpy: super::simd_x86::axpy_avx512,
            dot4: super::simd_x86::dot4_avx512,
            dot4_i8: super::simd_x86::dot4_i8_avx512,
        },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => Dispatch {
            level: SimdLevel::Neon,
            dot: super::simd_neon::dot_neon,
            dot_i8: super::simd_neon::dot_i8_neon,
            dot_f64: super::simd_neon::dot_f64_neon,
            axpy: super::simd_neon::axpy_neon,
            dot4: super::simd_neon::dot4_neon,
            dot4_i8: super::simd_neon::dot4_i8_neon,
        },
        // Level unavailable at this compile target; unreachable because
        // availability is checked before any table lookup.
        #[allow(unreachable_patterns)]
        _ => scalar_table,
    }
}

fn unavailable(level: SimdLevel) -> String {
    format!(
        "simd level '{}' is not available on this host (arch {}, available: {})",
        level.name(),
        std::env::consts::ARCH,
        available_levels()
            .iter()
            .map(|l| l.name())
            .collect::<Vec<_>>()
            .join("|"),
    )
}

impl Dispatch {
    /// The table for an explicit level, for benches and tests that
    /// compare levels directly.  Errors if the host lacks the level.
    pub fn for_level(level: SimdLevel) -> Result<Dispatch, String> {
        if !level.available() {
            return Err(unavailable(level));
        }
        Ok(table(level))
    }

    pub fn level(&self) -> SimdLevel {
        self.level
    }

    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "dot length mismatch");
        // SAFETY: equal lengths checked; the table only holds pointers
        // whose ISA level was verified available at construction.
        unsafe { (self.dot)(a, b) }
    }

    #[inline]
    pub fn dot_i8(&self, codes: &[i8], scale: f32, x: &[f32]) -> f32 {
        assert_eq!(codes.len(), x.len(), "dot_i8 length mismatch");
        // SAFETY: as in `dot`.
        unsafe { (self.dot_i8)(codes, scale, x) }
    }

    #[inline]
    pub fn dot_f64(&self, a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len(), "dot_f64 length mismatch");
        // SAFETY: as in `dot`.
        unsafe { (self.dot_f64)(a, b) }
    }

    #[inline]
    pub fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        // SAFETY: as in `dot`.
        unsafe { (self.axpy)(alpha, x, y) }
    }

    #[inline]
    pub fn dot4(&self, a: &[f32], b: [&[f32]; Q_TILE]) -> [f32; Q_TILE] {
        for bt in &b {
            assert_eq!(a.len(), bt.len(), "dot4 length mismatch");
        }
        // SAFETY: as in `dot`.
        unsafe { (self.dot4)(a, b) }
    }

    #[inline]
    pub fn dot4_i8(
        &self,
        codes: &[i8],
        scale: f32,
        b: [&[f32]; Q_TILE],
    ) -> [f32; Q_TILE] {
        for bt in &b {
            assert_eq!(codes.len(), bt.len(), "dot4_i8 length mismatch");
        }
        // SAFETY: as in `dot`.
        unsafe { (self.dot4_i8)(codes, scale, b) }
    }

    /// See [`super::dot_block`].
    pub fn dot_block(&self, rows: &[f32], dim: usize, x: &[f32], out: &mut [f32]) {
        assert!(dim > 0, "dot_block needs a positive dim");
        assert_eq!(rows.len() % dim, 0, "rows not a whole row count");
        let n_rows = rows.len() / dim;
        assert_eq!(out.len(), n_rows, "output size");
        assert_eq!(x.len(), dim, "x width mismatch");
        let mut r = 0;
        while r + Q_TILE <= n_rows {
            let s = self.dot4(
                x,
                [
                    &rows[r * dim..(r + 1) * dim],
                    &rows[(r + 1) * dim..(r + 2) * dim],
                    &rows[(r + 2) * dim..(r + 3) * dim],
                    &rows[(r + 3) * dim..(r + 4) * dim],
                ],
            );
            out[r..r + Q_TILE].copy_from_slice(&s);
            r += Q_TILE;
        }
        while r < n_rows {
            out[r] = self.dot(&rows[r * dim..(r + 1) * dim], x);
            r += 1;
        }
    }

    /// See [`super::axpy_block`].
    pub fn axpy_block(
        &self,
        alphas: &[f32],
        x: &[f32],
        rows: &mut [f32],
        dim: usize,
    ) {
        assert!(dim > 0, "axpy_block needs a positive dim");
        assert_eq!(rows.len() % dim, 0, "rows not a whole row count");
        assert_eq!(rows.len() / dim, alphas.len(), "one alpha per row");
        assert_eq!(x.len(), dim, "x width mismatch");
        for (row, &a) in rows.chunks_exact_mut(dim).zip(alphas) {
            self.axpy(a, x, row);
        }
    }

    /// See [`super::tile_scores_f32`].
    pub fn tile_scores_f32(
        &self,
        rows: &[f32],
        dim: usize,
        queries: &[&[f32]],
        out: &mut [f32],
    ) {
        assert_eq!(rows.len() % dim.max(1), 0, "rows not a whole row count");
        let n_rows = rows.len() / dim.max(1);
        check_tile_args(n_rows, dim, queries, out);
        for (r, row) in rows.chunks_exact(dim).enumerate() {
            let mut qi = 0;
            while qi + Q_TILE <= queries.len() {
                let s = self.dot4(
                    row,
                    [
                        queries[qi],
                        queries[qi + 1],
                        queries[qi + 2],
                        queries[qi + 3],
                    ],
                );
                for (t, v) in s.into_iter().enumerate() {
                    out[(qi + t) * n_rows + r] = v;
                }
                qi += Q_TILE;
            }
            while qi < queries.len() {
                out[qi * n_rows + r] = self.dot(row, queries[qi]);
                qi += 1;
            }
        }
    }

    /// See [`super::tile_scores_i8`].
    pub fn tile_scores_i8(
        &self,
        codes: &[i8],
        scales: &[f32],
        dim: usize,
        queries: &[&[f32]],
        out: &mut [f32],
    ) {
        assert_eq!(codes.len() % dim.max(1), 0, "codes not a whole row count");
        let n_rows = codes.len() / dim.max(1);
        assert_eq!(scales.len(), n_rows, "one scale per row");
        check_tile_args(n_rows, dim, queries, out);
        for (r, row) in codes.chunks_exact(dim).enumerate() {
            let scale = scales[r];
            let mut qi = 0;
            while qi + Q_TILE <= queries.len() {
                let s = self.dot4_i8(
                    row,
                    scale,
                    [
                        queries[qi],
                        queries[qi + 1],
                        queries[qi + 2],
                        queries[qi + 3],
                    ],
                );
                for (t, v) in s.into_iter().enumerate() {
                    out[(qi + t) * n_rows + r] = v;
                }
                qi += Q_TILE;
            }
            while qi < queries.len() {
                out[qi * n_rows + r] = self.dot_i8(row, scale, queries[qi]);
                qi += 1;
            }
        }
    }
}

fn check_tile_args(n_rows: usize, dim: usize, queries: &[&[f32]], out: &[f32]) {
    assert!(dim > 0, "tile kernel needs a positive dim");
    assert_eq!(out.len(), n_rows * queries.len(), "scores buffer size");
    for q in queries {
        assert_eq!(q.len(), dim, "query width mismatch");
    }
}

// The process-wide selection.  0 = not yet selected; otherwise
// `SimdLevel as u8 + 1`.  Levels are bit-identical by contract, so a
// benign race (two threads initializing, a bench re-forcing) cannot
// change any result — only which equally-correct code path runs.
static ACTIVE: AtomicU8 = AtomicU8::new(0);
static SOURCE: AtomicU8 = AtomicU8::new(SOURCE_AUTO);

const SOURCE_AUTO: u8 = 0;
const SOURCE_ENV: u8 = 1;
const SOURCE_CLI: u8 = 2;

fn encode(l: SimdLevel) -> u8 {
    match l {
        SimdLevel::Scalar => 1,
        SimdLevel::Avx2 => 2,
        SimdLevel::Avx512 => 3,
        SimdLevel::Neon => 4,
    }
}

fn decode(v: u8) -> SimdLevel {
    match v {
        1 => SimdLevel::Scalar,
        2 => SimdLevel::Avx2,
        3 => SimdLevel::Avx512,
        4 => SimdLevel::Neon,
        _ => unreachable!("corrupt simd level encoding"),
    }
}

/// The active kernel table.  First use selects a level:
/// `FULLW2V_SIMD` if set (panics on an invalid or unavailable value —
/// the CLI pre-validates via [`select_simd`] to turn that into a clean
/// error), otherwise the best detected level.
#[inline]
pub fn active() -> Dispatch {
    let v = ACTIVE.load(Ordering::Relaxed);
    let level = if v == 0 { init_from_env() } else { decode(v) };
    table(level)
}

#[cold]
fn init_from_env() -> SimdLevel {
    let (level, source) = match env_level() {
        Ok(Some(l)) => (l, SOURCE_ENV),
        Ok(None) => (detect_level(), SOURCE_AUTO),
        Err(e) => panic!("FULLW2V_SIMD: {e}"),
    };
    SOURCE.store(source, Ordering::Relaxed);
    ACTIVE.store(encode(level), Ordering::Relaxed);
    level
}

fn env_level() -> Result<Option<SimdLevel>, String> {
    let raw = match std::env::var("FULLW2V_SIMD") {
        Ok(v) if !v.trim().is_empty() => v,
        _ => return Ok(None),
    };
    let level = match SimdLevel::parse(&raw)? {
        Some(l) => l,
        None => detect_level(), // "auto"
    };
    if !level.available() {
        return Err(unavailable(level));
    }
    Ok(Some(level))
}

/// Force the dispatch level (all levels are bit-identical, so this is
/// safe at any point in the process lifetime).  Errors if the host
/// lacks the level.
pub fn force_level(level: SimdLevel) -> Result<(), String> {
    if !level.available() {
        return Err(unavailable(level));
    }
    ACTIVE.store(encode(level), Ordering::Relaxed);
    Ok(())
}

/// How the active level was chosen, for logs and bench artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimdSelection {
    pub level: SimdLevel,
    /// `"--simd"`, `"FULLW2V_SIMD"`, or `"detected"`.
    pub source: &'static str,
}

/// The current selection (initializing it if nothing ran yet).
pub fn simd_selection() -> SimdSelection {
    let level = active().level;
    let source = match SOURCE.load(Ordering::Relaxed) {
        SOURCE_CLI => "--simd",
        SOURCE_ENV => "FULLW2V_SIMD",
        _ => "detected",
    };
    SimdSelection { level, source }
}

/// Resolve the startup selection with CLI-grade errors.
/// Precedence: `--simd` flag value > `FULLW2V_SIMD` > auto-detect.
pub fn select_simd(cli_flag: Option<&str>) -> Result<SimdSelection, String> {
    if let Some(s) = cli_flag {
        let level = match SimdLevel::parse(s)? {
            Some(l) => l,
            None => detect_level(), // `--simd auto`
        };
        force_level(level)?;
        SOURCE.store(SOURCE_CLI, Ordering::Relaxed);
        return Ok(SimdSelection { level, source: "--simd" });
    }
    if let Some(level) = env_level()? {
        force_level(level)?;
        SOURCE.store(SOURCE_ENV, Ordering::Relaxed);
        return Ok(SimdSelection { level, source: "FULLW2V_SIMD" });
    }
    Ok(SimdSelection { level: active().level, source: "detected" })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_names_and_auto() {
        assert_eq!(SimdLevel::parse("auto").unwrap(), None);
        assert_eq!(SimdLevel::parse("AUTO").unwrap(), None);
        for l in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(l.name()).unwrap(), Some(l));
        }
        assert!(SimdLevel::parse("sse9").is_err());
        assert!(SimdLevel::parse("").is_err());
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(SimdLevel::Scalar.available());
        assert_eq!(available_levels()[0], SimdLevel::Scalar);
        assert!(available_levels().contains(&detect_level()));
    }

    #[test]
    fn unavailable_levels_are_rejected() {
        for l in SimdLevel::ALL {
            if !l.available() {
                let err = Dispatch::for_level(l).err().unwrap();
                assert!(err.contains(l.name()), "{err}");
                assert!(force_level(l).is_err());
            }
        }
    }

    /// Quick in-lib smoke of the cross-level contract (the exhaustive
    /// property tests live in `rust/tests/simd_dispatch.rs`).
    #[test]
    fn every_available_level_matches_scalar_on_a_smoke_case() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.31).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.17).cos()).collect();
        let codes: Vec<i8> = (0..37).map(|i| (i * 13 % 251 - 125) as i8).collect();
        let want = Dispatch::for_level(SimdLevel::Scalar).unwrap();
        for l in available_levels() {
            let d = Dispatch::for_level(l).unwrap();
            assert_eq!(
                d.dot(&a, &b).to_bits(),
                want.dot(&a, &b).to_bits(),
                "dot {l}"
            );
            assert_eq!(
                d.dot_i8(&codes, 0.02, &b).to_bits(),
                want.dot_i8(&codes, 0.02, &b).to_bits(),
                "dot_i8 {l}"
            );
            assert_eq!(
                d.dot_f64(&a, &b).to_bits(),
                want.dot_f64(&a, &b).to_bits(),
                "dot_f64 {l}"
            );
        }
    }

    /// Forcing any available level succeeds.  No assertions on
    /// `active()` here: lib tests share the process-wide selection and
    /// run concurrently (the serialized force/active semantics are
    /// pinned in `rust/tests/simd_dispatch.rs`).  Restores the prior
    /// level so a `FULLW2V_SIMD`-forced run stays forced.
    #[test]
    fn force_level_accepts_available_levels() {
        let before = active().level;
        for l in available_levels() {
            assert!(force_level(l).is_ok(), "{l}");
        }
        force_level(before).unwrap();
    }
}
