//! Shared vector kernels: the single home of the f32/int8 hot loops.
//!
//! FULL-W2V's central claim is that W2V is memory-bound and the wins
//! come from loading each vector **once** and reusing it across many
//! interactions.  Every layer that scores or updates embedding rows —
//! the serving scan (`serve::store` / `serve::ann`), the CPU training
//! baselines (`cpu_baseline`), evaluation — funnels through this module,
//! so there is exactly one implementation of each kernel to tune.
//!
//! Three kinds of kernel live here:
//!
//! * scalar-pair kernels: [`dot`] (8-lane accumulation), [`dot_i8`]
//!   (fused int8 widening dot — the dequantize round-trip is folded
//!   into the accumulation, one multiply by the row scale at the end),
//!   [`dot_f64`] (4-lane f64 accumulation for evaluation), and
//!   [`axpy`].
//! * block kernels: [`dot_block`] / [`axpy_block`] run one vector
//!   against every row of a row block (scores, then gradient scatter)
//!   with the shared vector held hot — the training-side reuse shape
//!   the FULL-W2V CPU trainer uses against its chunk-lifetime negative
//!   block and sliding window block.
//! * tile kernels: [`tile_scores_f32`] / [`tile_scores_i8`] score a
//!   block of Q query vectors against a block of R store rows.  Rows
//!   stream through the kernel once; each loaded row element feeds
//!   [`Q_TILE`] query accumulators held in registers, so memory traffic
//!   is `O(R)` row loads with Q-way reuse instead of `O(Q x R)` — the
//!   serving analogue of the paper's context-window reuse.
//!
//! The SGNS activation math ([`SigmoidTable`], exact [`sigmoid`],
//! [`softplus`]) lives here too (`sigmoid` submodule), shared by every
//! trainer.
//!
//! # Dispatch contract
//!
//! Every kernel has one **scalar reference body** (`scalar`
//! submodule) and optional explicit SIMD backends (`simd_x86`: AVX2 +
//! AVX-512F; `simd_neon`: aarch64 NEON).  The public functions here
//! route through a process-wide [`Dispatch`] table selected once by
//! runtime feature detection — overridable with `--simd` or
//! `FULLW2V_SIMD` (see [`select_simd`]) — so serve, trainer,
//! cpu_baseline, and eval pick up the fast paths with zero call-site
//! changes.
//!
//! **The scalar body is the semantic definition.**  A SIMD path must
//! produce *bit-identical* results — not merely close — for every
//! input: same 8-lane accumulation order, shared `reduce` epilogue,
//! separate multiply and add (never FMA, which rounds once instead of
//! twice), exact widening conversions.  This makes the dispatch level
//! unobservable: rankings, ties, stored scores, and single-threaded
//! training runs are reproducible across hosts and `--simd` settings.
//! `rust/tests/simd_dispatch.rs` property-tests every available level
//! against scalar (odd lengths, unaligned sub-slices, subnormal and
//! extreme magnitudes); the tile/block bitwise tests below pin the
//! tile-vs-scalar contract on whatever level is active.
//!
//! # Bit-identity across kernel shapes
//!
//! For the same row and query, the tile kernels produce bit-identical
//! scores to [`dot`] / [`dot_i8`]: each query lane inside the tile
//! accumulates in exactly the order the scalar kernel uses, and
//! IEEE-754 ops are deterministic, so batched and per-query scans rank
//! identically — ties and all.  The `tile_matches_dot_bitwise` test
//! pins this down; the batched-vs-per-query identity test in
//! `rust/tests/serve_integration.rs` relies on it end to end.

mod dispatch;
mod scalar;
mod sigmoid;
#[cfg(target_arch = "aarch64")]
mod simd_neon;
#[cfg(target_arch = "x86_64")]
mod simd_x86;

pub use dispatch::{
    active, available_levels, detect_level, force_level, select_simd,
    simd_selection, Dispatch, SimdLevel, SimdSelection,
};
pub use sigmoid::{sigmoid, softplus, SigmoidTable};

/// Queries scored per row pass inside the tile kernels (the register
/// blocking factor).
pub const Q_TILE: usize = 4;

/// Rows per tile in batched shard scans: bounds the score scratch
/// buffer (batch-size x `ROW_TILE` f32) while keeping the row block
/// well past a cache line.
pub const ROW_TILE: usize = 32;

/// f32 dot product (8-lane accumulation; see the dispatch contract).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    active().dot(a, b)
}

/// Fused int8 widening dot: `scale * sum(codes[i] * x[i])`.  Skips the
/// dequantize round-trip — codes widen to f32 inside the accumulation
/// and the per-row scale is applied once at the end.
#[inline]
pub fn dot_i8(codes: &[i8], scale: f32, x: &[f32]) -> f32 {
    active().dot_i8(codes, scale, x)
}

/// f64-accumulating dot over f32 slices (4-lane accumulation), for
/// evaluation paths where cancellation matters more than speed.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    active().dot_f64(a, b)
}

/// `y += alpha * x` (elementwise, so every dispatch width is trivially
/// bit-identical).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    active().axpy(alpha, x, y)
}

/// One vector dotted against every `dim`-wide row of a row block:
/// `out[r] = dot(row_r, x)`, each result **bit-identical** to [`dot`].
///
/// `x` is the reused operand: inside the 4-row tile dot its elements
/// are loaded once per [`Q_TILE`] rows and feed all four row
/// accumulators (f32 multiplication is commutative, so swapping the
/// streamed/held roles preserves every intermediate bit).  This is the
/// training-side shape of the reuse axis: the FULL-W2V trainer scores
/// one cached context row against the whole chunk-lifetime negative
/// block in one call.
pub fn dot_block(rows: &[f32], dim: usize, x: &[f32], out: &mut [f32]) {
    active().dot_block(rows, dim, x, out)
}

/// Per-row axpy over a row block: `row_r += alphas[r] * x`, each row
/// **bit-identical** to [`axpy`] with the same alpha.  `x` stays hot
/// across the whole block — the update-side sibling of [`dot_block`]
/// (the FULL-W2V trainer scatters one gradient column into every cached
/// window row in one call).
pub fn axpy_block(alphas: &[f32], x: &[f32], rows: &mut [f32], dim: usize) {
    active().axpy_block(alphas, x, rows, dim)
}

/// Score a Q x R tile: every query in `queries` against every row of
/// `rows` (R rows, row-major, `dim` wide).  `out[q * R + r]` receives
/// `dot(row_r, query_q)`, bit-identical to the scalar kernel.
///
/// Rows are the streaming operand: each row is read once per
/// [`Q_TILE`] queries with its elements held in registers across the
/// query accumulators, so a batch of Q queries costs `O(R)` row loads
/// instead of `O(Q x R)`.
pub fn tile_scores_f32(
    rows: &[f32],
    dim: usize,
    queries: &[&[f32]],
    out: &mut [f32],
) {
    active().tile_scores_f32(rows, dim, queries, out)
}

/// Int8 tile kernel: rows are `codes` (R x `dim` int8) with one f32
/// scale per row; scores are bit-identical to [`dot_i8`].  Same reuse
/// shape as [`tile_scores_f32`], at a quarter of the row traffic.
pub fn tile_scores_i8(
    codes: &[i8],
    scales: &[f32],
    dim: usize,
    queries: &[&[f32]],
    out: &mut [f32],
) {
    active().tile_scores_i8(codes, scales, dim, queries, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    #[test]
    fn dot_matches_naive() {
        for n in [0usize, 1, 3, 7, 8, 9, 19, 64, 65] {
            let a = seq(n, |i| (i as f32 * 0.37).sin());
            let b = seq(n, |i| ((n - i) as f32 * 0.21).cos());
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (dot(&a, &b) - naive).abs() < 1e-4,
                "n={n}: {} vs {naive}",
                dot(&a, &b)
            );
        }
    }

    #[test]
    fn dot_i8_matches_dequantized_dot() {
        for n in [1usize, 7, 8, 17, 64] {
            let codes: Vec<i8> =
                (0..n).map(|i| ((i * 37 + 11) % 255) as i8).collect();
            let scale = 0.013f32;
            let x = seq(n, |i| (i as f32 * 0.11).sin());
            let deq: Vec<f32> =
                codes.iter().map(|&c| c as f32 * scale).collect();
            let want = dot(&deq, &x);
            let got = dot_i8(&codes, scale, &x);
            assert!(
                (got - want).abs() <= want.abs() * 1e-5 + 1e-5,
                "n={n}: fused {got} vs dequantized {want}"
            );
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0usize, 1, 3, 4, 5, 13] {
            let x = seq(n, |i| i as f32 + 1.0);
            let mut y = seq(n, |i| -(i as f32));
            let mut want = y.clone();
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += 0.5 * xv;
            }
            axpy(0.5, &x, &mut y);
            assert_eq!(y, want, "n={n}");
        }
    }

    /// The contract the batched scan path stands on: tile scores are
    /// bit-identical to the scalar kernels, for every query count mod
    /// Q_TILE and for dims around the unroll width.  Runs on whatever
    /// dispatch level is active (CI also runs the whole suite with
    /// `FULLW2V_SIMD=scalar`).
    #[test]
    fn tile_matches_dot_bitwise() {
        for dim in [1usize, 5, 8, 16, 19] {
            for nq in 1..=6usize {
                let n_rows = 7;
                let rows =
                    seq(n_rows * dim, |i| ((i * 29 % 97) as f32) * 0.021 - 1.0);
                let queries: Vec<Vec<f32>> = (0..nq)
                    .map(|q| seq(dim, |i| ((q * 31 + i * 7) as f32).sin()))
                    .collect();
                let qrefs: Vec<&[f32]> =
                    queries.iter().map(|q| q.as_slice()).collect();
                let mut out = vec![0.0f32; nq * n_rows];
                tile_scores_f32(&rows, dim, &qrefs, &mut out);
                for (qi, q) in qrefs.iter().enumerate() {
                    for (r, row) in rows.chunks_exact(dim).enumerate() {
                        let want = dot(row, q);
                        let got = out[qi * n_rows + r];
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "dim={dim} nq={nq} q={qi} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tile_i8_matches_dot_i8_bitwise() {
        for dim in [1usize, 8, 11, 16] {
            for nq in 1..=5usize {
                let n_rows = 6;
                let codes: Vec<i8> = (0..n_rows * dim)
                    .map(|i| ((i * 53 + 7) % 255) as i8)
                    .collect();
                let scales = seq(n_rows, |r| 0.002 + r as f32 * 0.001);
                let queries: Vec<Vec<f32>> = (0..nq)
                    .map(|q| seq(dim, |i| ((q + 2 * i) as f32 * 0.3).cos()))
                    .collect();
                let qrefs: Vec<&[f32]> =
                    queries.iter().map(|q| q.as_slice()).collect();
                let mut out = vec![0.0f32; nq * n_rows];
                tile_scores_i8(&codes, &scales, dim, &qrefs, &mut out);
                for (qi, q) in qrefs.iter().enumerate() {
                    for (r, row) in codes.chunks_exact(dim).enumerate() {
                        let want = dot_i8(row, scales[r], q);
                        let got = out[qi * n_rows + r];
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "dim={dim} nq={nq} q={qi} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tile_handles_empty_rows_and_queries() {
        let mut out: Vec<f32> = Vec::new();
        tile_scores_f32(&[], 4, &[], &mut out);
        let q: &[f32] = &[1.0, 0.0, 0.0, 0.0];
        tile_scores_f32(&[], 4, &[q], &mut out);
        tile_scores_i8(&[], &[], 4, &[q], &mut out);
    }

    /// The contract the FULL-W2V trainer's negative-block scoring stands
    /// on: block results are bit-identical to the scalar kernel, for row
    /// counts around the Q_TILE boundary and dims around the unroll
    /// width.
    #[test]
    fn dot_block_matches_dot_bitwise() {
        for dim in [1usize, 5, 8, 16, 19] {
            for n_rows in [0usize, 1, 3, 4, 5, 8, 9] {
                let rows =
                    seq(n_rows * dim, |i| ((i * 31 % 89) as f32) * 0.017 - 0.7);
                let x = seq(dim, |i| ((i * 13 + 3) as f32 * 0.23).sin());
                let mut out = vec![0.0f32; n_rows];
                dot_block(&rows, dim, &x, &mut out);
                for (r, row) in rows.chunks_exact(dim).enumerate() {
                    let want = dot(row, x.as_slice());
                    assert_eq!(
                        out[r].to_bits(),
                        want.to_bits(),
                        "dim={dim} n_rows={n_rows} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn axpy_block_matches_axpy_bitwise() {
        for dim in [1usize, 4, 8, 11] {
            for n_rows in [0usize, 1, 2, 5] {
                let alphas = seq(n_rows, |r| (r as f32 - 1.3) * 0.4);
                let x = seq(dim, |i| (i as f32 * 0.7).cos());
                let init =
                    seq(n_rows * dim, |i| ((i * 7 % 23) as f32) * 0.05 - 0.4);
                let mut rows = init.clone();
                axpy_block(&alphas, &x, &mut rows, dim);
                let mut want = init;
                for (row, &a) in want.chunks_exact_mut(dim).zip(&alphas) {
                    axpy(a, &x, row);
                }
                for (got, want) in rows.iter().zip(&want) {
                    assert_eq!(got.to_bits(), want.to_bits());
                }
            }
        }
    }

    #[test]
    fn dot_f64_matches_naive() {
        let a = seq(9, |i| i as f32 * 0.5);
        let b = seq(9, |i| (9 - i) as f32);
        let naive: f64 =
            a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        assert!((dot_f64(&a, &b) - naive).abs() < 1e-12);
    }

    /// The unrolled f64 dot stays a faithful dot product on inputs
    /// where the 4-lane regrouping actually changes the add order.
    #[test]
    fn dot_f64_unrolled_close_to_sequential() {
        for n in [0usize, 1, 3, 4, 5, 11, 64, 67] {
            let a = seq(n, |i| (i as f32 * 0.61).sin() * 3.0);
            let b = seq(n, |i| ((n - i) as f32 * 0.29).cos() * 2.0);
            let seqsum: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| *x as f64 * *y as f64)
                .sum();
            let got = dot_f64(&a, &b);
            assert!(
                (got - seqsum).abs() <= seqsum.abs() * 1e-14 + 1e-14,
                "n={n}: {got} vs {seqsum}"
            );
        }
    }
}
