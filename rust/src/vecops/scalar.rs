//! Scalar reference kernels: the semantic definition of every vecops
//! kernel.
//!
//! The bodies here are the bit-exact contract the SIMD paths in
//! `simd_x86` / `simd_neon` must reproduce: one 8-lane f32 accumulator
//! array per dot (4 lanes for the f64 variant), chunk-sequential
//! accumulation, and a single shared [`reduce`] / [`reduce_f64`] at the
//! end.  Any change to an accumulation order here is a change to the
//! crate-wide bit-identity contract and must be mirrored in every SIMD
//! backend (the `simd_dispatch` integration tests will catch a mismatch
//! on the first run).

use super::Q_TILE;

/// f32 accumulator lanes per chunk.  This is the unroll width of the
/// scalar kernels *and* the vector width of the AVX2/AVX-512 dot paths
/// (one 8-lane register accumulator), which is what makes them
/// bit-identical: both walk the input in 8-wide chunks with one
/// sequential add per lane per chunk.
pub(crate) const LANES: usize = 8;

/// f64 accumulator lanes for [`dot_f64`] (4 doubles = one 256-bit
/// register on AVX2, two 128-bit registers on NEON).
pub(crate) const F64_LANES: usize = 4;

/// Reduce one kernel's lane accumulators plus the unrolled tail.
/// Shared by every f32/int8 kernel — scalar and SIMD — so their
/// rounding is identical: SIMD paths store their register lanes to a
/// `[f32; LANES]` and call this exact function.
#[inline(always)]
pub(crate) fn reduce(acc: &[f32; LANES], tail: impl Iterator<Item = f32>) -> f32 {
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for t in tail {
        s += t;
    }
    s
}

/// f64 sibling of [`reduce`] for [`dot_f64`]'s 4-lane accumulator.
#[inline(always)]
pub(crate) fn reduce_f64(
    acc: &[f64; F64_LANES],
    tail: impl Iterator<Item = f64>,
) -> f64 {
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for t in tail {
        s += t;
    }
    s
}

/// 8-way unrolled f32 dot product.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            acc[l] += a[j + l] * b[j + l];
        }
    }
    let base = chunks * LANES;
    reduce(&acc, (base..a.len()).map(|j| a[j] * b[j]))
}

/// Fused int8 widening dot: `scale * sum(codes[i] * x[i])`.  Codes
/// widen to f32 inside the accumulation (i8 -> f32 is exact) and the
/// per-row scale is applied once at the end.
#[inline]
pub(crate) fn dot_i8(codes: &[i8], scale: f32, x: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let chunks = codes.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            acc[l] += codes[j + l] as f32 * x[j + l];
        }
    }
    let base = chunks * LANES;
    reduce(&acc, (base..codes.len()).map(|j| codes[j] as f32 * x[j])) * scale
}

/// f64-accumulating dot over f32 slices, 4-way unrolled (the same
/// treatment as [`dot`], at the f64 register width).  Evaluation paths
/// route through this where cancellation matters more than speed.
#[inline]
pub(crate) fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = [0.0f64; F64_LANES];
    let chunks = a.len() / F64_LANES;
    for i in 0..chunks {
        let j = i * F64_LANES;
        for l in 0..F64_LANES {
            acc[l] += a[j + l] as f64 * b[j + l] as f64;
        }
    }
    let base = chunks * F64_LANES;
    reduce_f64(&acc, (base..a.len()).map(|j| a[j] as f64 * b[j] as f64))
}

/// `y += alpha * x`, 4-way unrolled.  Purely elementwise, so any
/// vector width reproduces it bit-for-bit — this is the one kernel the
/// AVX-512 backend runs 16 lanes wide.
#[inline]
pub(crate) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        y[j] += alpha * x[j];
        y[j + 1] += alpha * x[j + 1];
        y[j + 2] += alpha * x[j + 2];
        y[j + 3] += alpha * x[j + 3];
    }
    for j in chunks * 4..x.len() {
        y[j] += alpha * x[j];
    }
}

/// Four dots sharing one pass over `a`: each element of `a` is loaded
/// once and feeds all four query accumulators.  Every query lane
/// accumulates in exactly [`dot`]'s order, so each result is
/// bit-identical to `dot(a, b_t)`.
#[inline]
pub(crate) fn dot4(a: &[f32], b: [&[f32]; Q_TILE]) -> [f32; Q_TILE] {
    let mut acc = [[0.0f32; LANES]; Q_TILE];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let x = a[j + l];
            for (t, bt) in b.iter().enumerate() {
                acc[t][l] += x * bt[j + l];
            }
        }
    }
    let base = chunks * LANES;
    let mut out = [0.0f32; Q_TILE];
    for t in 0..Q_TILE {
        out[t] = reduce(&acc[t], (base..a.len()).map(|j| a[j] * b[t][j]));
    }
    out
}

/// Int8 sibling of [`dot4`]: each result is bit-identical to
/// `dot_i8(codes, scale, b_t)`.
#[inline]
pub(crate) fn dot4_i8(
    codes: &[i8],
    scale: f32,
    b: [&[f32]; Q_TILE],
) -> [f32; Q_TILE] {
    let mut acc = [[0.0f32; LANES]; Q_TILE];
    let chunks = codes.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let x = codes[j + l] as f32;
            for (t, bt) in b.iter().enumerate() {
                acc[t][l] += x * bt[j + l];
            }
        }
    }
    let base = chunks * LANES;
    let mut out = [0.0f32; Q_TILE];
    for t in 0..Q_TILE {
        out[t] = reduce(
            &acc[t],
            (base..codes.len()).map(|j| codes[j] as f32 * b[t][j]),
        ) * scale;
    }
    out
}
