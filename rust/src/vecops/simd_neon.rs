//! Explicit aarch64 NEON kernels, bit-identical to [`super::scalar`].
//!
//! The scalar contract's 8-lane f32 accumulator array maps onto two
//! `float32x4_t` registers (lanes 0-3 and 4-7); each gets one
//! `vaddq_f32(acc, vmulq_f32(a, b))` per chunk — the same per-lane
//! IEEE-754 op sequence as the scalar kernel.  `vmlaq_f32`/`vfmaq_f32`
//! are deliberately *not* used: on aarch64 they lower to FMLA, which
//! fuses the multiply-add into a single rounding and would break
//! bit-identity.  Lanes are stored back to a `[f32; LANES]` and reduced
//! by the shared `scalar::reduce`, exactly like the x86 backend.
//!
//! Widening is exact: int8 codes go `vmovl_s8` -> `vmovl_s16` ->
//! `vcvtq_f32_s32` (i8 -> f32, exact), the f64 dot goes
//! `vcvt_f64_f32` / `vcvt_high_f64_f32` (f32 -> f64, exact).
//!
//! Callers reach these only through the dispatch table, which verified
//! NEON support at construction.

use core::arch::aarch64::*;

use super::scalar::{reduce, reduce_f64, F64_LANES, LANES};
use super::Q_TILE;

// SAFETY: reached only through the dispatch table, which verified NEON
// at construction; 4-lane loads stop below a.len() == b.len() (caller
// contract).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc_lo = vdupq_n_f32(0.0);
    let mut acc_hi = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let j = i * LANES;
        acc_lo = vaddq_f32(
            acc_lo,
            vmulq_f32(vld1q_f32(ap.add(j)), vld1q_f32(bp.add(j))),
        );
        acc_hi = vaddq_f32(
            acc_hi,
            vmulq_f32(vld1q_f32(ap.add(j + 4)), vld1q_f32(bp.add(j + 4))),
        );
    }
    let mut acc = [0.0f32; LANES];
    vst1q_f32(acc.as_mut_ptr(), acc_lo);
    vst1q_f32(acc.as_mut_ptr().add(4), acc_hi);
    let base = chunks * LANES;
    reduce(&acc, (base..n).map(|j| a[j] * b[j]))
}

/// Widen 8 int8 codes to two f32x4 registers (exact conversion).
// SAFETY: callers are NEON target-feature fns and pass a pointer with
// at least 8 readable codes (chunk loop bound).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn widen8(p: *const i8) -> (float32x4_t, float32x4_t) {
    let c16 = vmovl_s8(vld1_s8(p));
    let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(c16)));
    let hi = vcvtq_f32_s32(vmovl_high_s16(c16));
    (lo, hi)
}

// SAFETY: dispatch verified NEON; code and f32 loads stop below
// codes.len(), which the caller keeps == x.len().
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_i8_neon(codes: &[i8], scale: f32, x: &[f32]) -> f32 {
    let n = codes.len();
    let chunks = n / LANES;
    let cp = codes.as_ptr();
    let xp = x.as_ptr();
    let mut acc_lo = vdupq_n_f32(0.0);
    let mut acc_hi = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let j = i * LANES;
        let (c_lo, c_hi) = widen8(cp.add(j));
        acc_lo = vaddq_f32(acc_lo, vmulq_f32(c_lo, vld1q_f32(xp.add(j))));
        acc_hi = vaddq_f32(acc_hi, vmulq_f32(c_hi, vld1q_f32(xp.add(j + 4))));
    }
    let mut acc = [0.0f32; LANES];
    vst1q_f32(acc.as_mut_ptr(), acc_lo);
    vst1q_f32(acc.as_mut_ptr().add(4), acc_hi);
    let base = chunks * LANES;
    reduce(&acc, (base..n).map(|j| codes[j] as f32 * x[j])) * scale
}

// SAFETY: dispatch verified NEON; 4-lane loads stop below a.len() ==
// b.len() (caller contract), and the f64 stores land in the local
// 4-wide accumulator array.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_f64_neon(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / F64_LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for i in 0..chunks {
        let j = i * F64_LANES;
        let a4 = vld1q_f32(ap.add(j));
        let b4 = vld1q_f32(bp.add(j));
        acc01 = vaddq_f64(
            acc01,
            vmulq_f64(vcvt_f64_f32(vget_low_f32(a4)), vcvt_f64_f32(vget_low_f32(b4))),
        );
        acc23 = vaddq_f64(
            acc23,
            vmulq_f64(vcvt_high_f64_f32(a4), vcvt_high_f64_f32(b4)),
        );
    }
    let mut acc = [0.0f64; F64_LANES];
    vst1q_f64(acc.as_mut_ptr(), acc01);
    vst1q_f64(acc.as_mut_ptr().add(2), acc23);
    let base = chunks * F64_LANES;
    reduce_f64(&acc, (base..n).map(|j| a[j] as f64 * b[j] as f64))
}

// SAFETY: dispatch verified NEON; loads/stores through the raw y
// pointer stop below x.len(), and the caller keeps y.len() == x.len().
#[target_feature(enable = "neon")]
pub(crate) unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
    const W: usize = 4;
    let n = x.len();
    let chunks = n / W;
    let av = vdupq_n_f32(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for i in 0..chunks {
        let j = i * W;
        let yv = vld1q_f32(yp.add(j));
        vst1q_f32(yp.add(j), vaddq_f32(yv, vmulq_f32(av, vld1q_f32(xp.add(j)))));
    }
    for j in chunks * W..n {
        y[j] += alpha * x[j];
    }
}

// SAFETY: dispatch verified NEON; all four query rows are kept at
// a.len() by the tile caller, so every load is in bounds.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot4_neon(a: &[f32], b: [&[f32]; Q_TILE]) -> [f32; Q_TILE] {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
    let mut lo = [vdupq_n_f32(0.0); Q_TILE];
    let mut hi = [vdupq_n_f32(0.0); Q_TILE];
    for i in 0..chunks {
        let j = i * LANES;
        let x_lo = vld1q_f32(ap.add(j));
        let x_hi = vld1q_f32(ap.add(j + 4));
        for t in 0..Q_TILE {
            lo[t] = vaddq_f32(lo[t], vmulq_f32(x_lo, vld1q_f32(bp[t].add(j))));
            hi[t] = vaddq_f32(hi[t], vmulq_f32(x_hi, vld1q_f32(bp[t].add(j + 4))));
        }
    }
    let base = chunks * LANES;
    let mut out = [0.0f32; Q_TILE];
    for t in 0..Q_TILE {
        let mut acc = [0.0f32; LANES];
        vst1q_f32(acc.as_mut_ptr(), lo[t]);
        vst1q_f32(acc.as_mut_ptr().add(4), hi[t]);
        out[t] = reduce(&acc, (base..n).map(|j| a[j] * b[t][j]));
    }
    out
}

// SAFETY: dispatch verified NEON; code loads and the four query-row
// loads stop below codes.len(), which the tile caller keeps equal to
// every b[t].len().
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot4_i8_neon(
    codes: &[i8],
    scale: f32,
    b: [&[f32]; Q_TILE],
) -> [f32; Q_TILE] {
    let n = codes.len();
    let chunks = n / LANES;
    let cp = codes.as_ptr();
    let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
    let mut lo = [vdupq_n_f32(0.0); Q_TILE];
    let mut hi = [vdupq_n_f32(0.0); Q_TILE];
    for i in 0..chunks {
        let j = i * LANES;
        let (x_lo, x_hi) = widen8(cp.add(j));
        for t in 0..Q_TILE {
            lo[t] = vaddq_f32(lo[t], vmulq_f32(x_lo, vld1q_f32(bp[t].add(j))));
            hi[t] = vaddq_f32(hi[t], vmulq_f32(x_hi, vld1q_f32(bp[t].add(j + 4))));
        }
    }
    let base = chunks * LANES;
    let mut out = [0.0f32; Q_TILE];
    for t in 0..Q_TILE {
        let mut acc = [0.0f32; LANES];
        vst1q_f32(acc.as_mut_ptr(), lo[t]);
        vst1q_f32(acc.as_mut_ptr().add(4), hi[t]);
        out[t] = reduce(&acc, (base..n).map(|j| codes[j] as f32 * b[t][j])) * scale;
    }
    out
}
