//! Shared SGNS activation math: the word2vec.c EXP_TABLE sigmoid, the
//! exact sigmoid, and the numerically-stable softplus used for loss
//! reporting.  Moved here from `cpu_baseline::math` so every trainer —
//! serial baselines, the Hogwild shard kernels, and the FULL-W2V
//! reference trainer — shares a single implementation, exactly like the
//! dot/axpy hot loops before it.

/// word2vec.c's EXP_TABLE: sigmoid precomputed over [-MAX_EXP, MAX_EXP]
/// in EXP_TABLE_SIZE buckets, saturating outside.
pub struct SigmoidTable {
    table: Vec<f32>,
    max_exp: f32,
}

impl SigmoidTable {
    pub const EXP_TABLE_SIZE: usize = 1000;
    pub const MAX_EXP: f32 = 6.0;

    pub fn new() -> Self {
        let n = Self::EXP_TABLE_SIZE;
        let table = (0..n)
            .map(|i| {
                let x = (i as f32 / n as f32 * 2.0 - 1.0) * Self::MAX_EXP;
                let e = x.exp();
                e / (e + 1.0)
            })
            .collect();
        SigmoidTable { table, max_exp: Self::MAX_EXP }
    }

    /// Table lookup, saturating to {0, 1} outside ±MAX_EXP exactly like
    /// word2vec.c (which skips the update when |x| > MAX_EXP for the
    /// positive label path; we return the saturated value instead, which
    /// zeroes the gradient for label-matched pairs).
    ///
    /// The index *rounds* to the nearest grid point rather than
    /// truncating: table entry `i` is the sigmoid sampled at
    /// `x_i = (i/N * 2 - 1) * MAX_EXP`, so rounding makes an input that
    /// lands exactly on a grid point read its own entry (a truncating
    /// cast could fall one bucket short of the edge when
    /// `(x + MAX_EXP) * N / (2 * MAX_EXP)` rounds down in f32), and
    /// halves the worst-case quantization error while restoring the
    /// `sigmoid(x) + sigmoid(-x) = 1` symmetry across bucket edges.
    #[inline]
    pub fn sigmoid(&self, x: f32) -> f32 {
        if x >= self.max_exp {
            1.0
        } else if x <= -self.max_exp {
            0.0
        } else {
            let idx = ((x + self.max_exp)
                * (Self::EXP_TABLE_SIZE as f32 / (2.0 * self.max_exp)))
                .round() as usize;
            self.table[idx.min(Self::EXP_TABLE_SIZE - 1)]
        }
    }
}

impl Default for SigmoidTable {
    fn default() -> Self {
        Self::new()
    }
}

/// Exact sigmoid (used by the matrix baselines; numerically stable).
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable softplus log(1+e^x), for loss reporting.
#[inline]
pub fn softplus(x: f32) -> f64 {
    let x = x as f64;
    if x > 30.0 {
        x
    } else if x < -30.0 {
        0.0
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_tracks_exact_sigmoid() {
        let t = SigmoidTable::new();
        for i in -50..=50 {
            let x = i as f32 * 0.1;
            let err = (t.sigmoid(x) - sigmoid(x)).abs();
            assert!(err < 0.01, "x={x} err={err}");
        }
    }

    #[test]
    fn table_saturates() {
        let t = SigmoidTable::new();
        assert_eq!(t.sigmoid(100.0), 1.0);
        assert_eq!(t.sigmoid(-100.0), 0.0);
        assert_eq!(t.sigmoid(6.0), 1.0);
        assert_eq!(t.sigmoid(-6.0), 0.0);
    }

    /// Regression for the truncating index cast: an input sitting exactly
    /// on a table grid point must read its own entry, not the neighbor a
    /// rounded-down f32 product would select.
    #[test]
    fn grid_points_read_their_own_bucket() {
        let t = SigmoidTable::new();
        let n = SigmoidTable::EXP_TABLE_SIZE;
        for i in (1..n).step_by(7) {
            let x = (i as f32 / n as f32 * 2.0 - 1.0) * SigmoidTable::MAX_EXP;
            if x.abs() >= SigmoidTable::MAX_EXP {
                continue;
            }
            let err = (t.sigmoid(x) - sigmoid(x)).abs();
            assert!(err < 1e-4, "grid i={i} x={x} err={err}");
        }
    }

    /// Rounding restores the sigmoid symmetry across bucket edges.
    #[test]
    fn table_is_symmetric() {
        let t = SigmoidTable::new();
        for i in 0..400 {
            let x = i as f32 * 0.0137;
            let s = t.sigmoid(x) + t.sigmoid(-x);
            assert!((s - 1.0).abs() < 2e-3, "x={x} sum={s}");
        }
    }

    #[test]
    fn exact_sigmoid_properties() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((sigmoid(5.0) + sigmoid(-5.0) - 1.0).abs() < 1e-6);
        assert!(sigmoid(-80.0) >= 0.0 && sigmoid(80.0) <= 1.0);
    }

    #[test]
    fn softplus_stable() {
        assert!((softplus(0.0) - 2f64.ln()).abs() < 1e-12);
        assert_eq!(softplus(100.0), 100.0);
        assert_eq!(softplus(-100.0), 0.0);
    }
}
