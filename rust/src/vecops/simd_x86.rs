//! Explicit x86-64 SIMD kernels (AVX2 + AVX-512F), bit-identical to
//! [`super::scalar`].
//!
//! How bit-identity is engineered rather than hoped for:
//!
//! * **Same accumulator shape.**  The scalar dot kernels keep one
//!   8-lane accumulator array with one sequential add per lane per
//!   chunk; here that array *is* one `__m256` register updated with
//!   `add(acc, mul(a, b))` per chunk — the identical per-lane sequence
//!   of IEEE-754 ops.
//! * **No FMA.**  `vfmadd` rounds once where `mul` + `add` round twice;
//!   a fused path would differ in the last bit.  Every kernel here uses
//!   separate multiply and add.
//! * **One reduction.**  Register lanes are stored to a `[f32; LANES]`
//!   and handed to the shared `scalar::reduce` together with the scalar
//!   tail products, so the horizontal sum and remainder handling are
//!   literally the same code the scalar kernel runs.
//! * **Exact conversions.**  The int8 path widens codes with
//!   `vpmovsxbd` + `vcvtdq2ps` (i8 -> i32 -> f32, exact for |v| <= 127,
//!   mirroring `code as f32`); the f64 dot widens with `vcvtps2pd`
//!   (every f32 is exactly representable as f64).
//!
//! AVX-512 note: the dot kernels deliberately stay 8 lanes wide — the
//! scalar contract's single loop-carried accumulator pins the width, so
//! a 16-lane dot would change the summation order.  AVX-512 instead
//! widens the kernels whose semantics are width-agnostic: `axpy`
//! (elementwise) runs 16 lanes, and the 4-query tile dot packs two
//! 8-lane query accumulators per `zmm` register.
//!
//! Callers reach these only through the dispatch table, which verified
//! the features at construction — that is the safety contract for every
//! `#[target_feature]` fn here.

use core::arch::x86_64::*;

use super::scalar::{reduce, reduce_f64, F64_LANES, LANES};
use super::Q_TILE;

// SAFETY: reached only through the dispatch table, which verified avx2
// at construction; unaligned loads (`loadu`) stop below a.len(), and
// the caller contract (dispatch) guarantees b.len() == a.len().
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut accv = _mm256_setzero_ps();
    for i in 0..chunks {
        let j = i * LANES;
        accv = _mm256_add_ps(
            accv,
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j))),
        );
    }
    let mut acc = [0.0f32; LANES];
    _mm256_storeu_ps(acc.as_mut_ptr(), accv);
    let base = chunks * LANES;
    reduce(&acc, (base..n).map(|j| a[j] * b[j]))
}

// SAFETY: dispatch verified avx2; the 8-byte code load and the f32
// loads stop below codes.len(), which the caller keeps == x.len().
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_i8_avx2(codes: &[i8], scale: f32, x: &[f32]) -> f32 {
    let n = codes.len();
    let chunks = n / LANES;
    let cp = codes.as_ptr();
    let xp = x.as_ptr();
    let mut accv = _mm256_setzero_ps();
    for i in 0..chunks {
        let j = i * LANES;
        // 8 codes -> sign-extend to i32 -> exact convert to f32.
        let c8 = _mm_loadl_epi64(cp.add(j) as *const __m128i);
        let cf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(c8));
        accv = _mm256_add_ps(accv, _mm256_mul_ps(cf, _mm256_loadu_ps(xp.add(j))));
    }
    let mut acc = [0.0f32; LANES];
    _mm256_storeu_ps(acc.as_mut_ptr(), accv);
    let base = chunks * LANES;
    reduce(&acc, (base..n).map(|j| codes[j] as f32 * x[j])) * scale
}

// SAFETY: dispatch verified avx2; each 4-lane f32 load stays below
// a.len() == b.len() (caller contract).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_f64_avx2(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / F64_LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut accv = _mm256_setzero_pd();
    for i in 0..chunks {
        let j = i * F64_LANES;
        let av = _mm256_cvtps_pd(_mm_loadu_ps(ap.add(j)));
        let bv = _mm256_cvtps_pd(_mm_loadu_ps(bp.add(j)));
        accv = _mm256_add_pd(accv, _mm256_mul_pd(av, bv));
    }
    let mut acc = [0.0f64; F64_LANES];
    _mm256_storeu_pd(acc.as_mut_ptr(), accv);
    let base = chunks * F64_LANES;
    reduce_f64(&acc, (base..n).map(|j| a[j] as f64 * b[j] as f64))
}

// SAFETY: dispatch verified avx2; loads/stores through the raw y
// pointer stop below x.len(), and the caller keeps y.len() == x.len().
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let chunks = n / LANES;
    let av = _mm256_set1_ps(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for i in 0..chunks {
        let j = i * LANES;
        let yv = _mm256_loadu_ps(yp.add(j));
        _mm256_storeu_ps(
            yp.add(j),
            _mm256_add_ps(yv, _mm256_mul_ps(av, _mm256_loadu_ps(xp.add(j)))),
        );
    }
    for j in chunks * LANES..n {
        y[j] += alpha * x[j];
    }
}

// SAFETY: dispatch verified avx2; all four query rows are kept at
// a.len() by the tile caller, so every unaligned load is in bounds.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot4_avx2(a: &[f32], b: [&[f32]; Q_TILE]) -> [f32; Q_TILE] {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    for i in 0..chunks {
        let j = i * LANES;
        // The streamed operand is loaded once and feeds all four
        // accumulators — four guaranteed-resident ymm registers.
        let xv = _mm256_loadu_ps(ap.add(j));
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[0].add(j))));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[1].add(j))));
        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[2].add(j))));
        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[3].add(j))));
    }
    let mut lanes = [[0.0f32; LANES]; Q_TILE];
    _mm256_storeu_ps(lanes[0].as_mut_ptr(), acc0);
    _mm256_storeu_ps(lanes[1].as_mut_ptr(), acc1);
    _mm256_storeu_ps(lanes[2].as_mut_ptr(), acc2);
    _mm256_storeu_ps(lanes[3].as_mut_ptr(), acc3);
    finish4(a.len(), chunks * LANES, &lanes, |j, t| a[j] * b[t][j])
}

// SAFETY: dispatch verified avx2; code loads and the four query-row
// loads stop below codes.len(), which the tile caller keeps equal to
// every b[t].len().
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot4_i8_avx2(
    codes: &[i8],
    scale: f32,
    b: [&[f32]; Q_TILE],
) -> [f32; Q_TILE] {
    let n = codes.len();
    let chunks = n / LANES;
    let cp = codes.as_ptr();
    let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    for i in 0..chunks {
        let j = i * LANES;
        let c8 = _mm_loadl_epi64(cp.add(j) as *const __m128i);
        let xv = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(c8));
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[0].add(j))));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[1].add(j))));
        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[2].add(j))));
        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(xv, _mm256_loadu_ps(bp[3].add(j))));
    }
    let mut lanes = [[0.0f32; LANES]; Q_TILE];
    _mm256_storeu_ps(lanes[0].as_mut_ptr(), acc0);
    _mm256_storeu_ps(lanes[1].as_mut_ptr(), acc1);
    _mm256_storeu_ps(lanes[2].as_mut_ptr(), acc2);
    _mm256_storeu_ps(lanes[3].as_mut_ptr(), acc3);
    let out = finish4(n, chunks * LANES, &lanes, |j, t| {
        codes[j] as f32 * b[t][j]
    });
    [out[0] * scale, out[1] * scale, out[2] * scale, out[3] * scale]
}

/// Shared tail + reduction for the 4-query kernels: exactly the scalar
/// `dot4` epilogue (per-query `reduce` over lane accumulators plus
/// per-element tail products).
#[inline(always)]
fn finish4(
    n: usize,
    base: usize,
    lanes: &[[f32; LANES]; Q_TILE],
    tail: impl Fn(usize, usize) -> f32,
) -> [f32; Q_TILE] {
    let mut out = [0.0f32; Q_TILE];
    for (t, out_t) in out.iter_mut().enumerate() {
        *out_t = reduce(&lanes[t], (base..n).map(|j| tail(j, t)));
    }
    out
}

// SAFETY: dispatch verified avx512f; 16-lane loads/stores stop below
// x.len(), and the caller keeps y.len() == x.len().
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn axpy_avx512(alpha: f32, x: &[f32], y: &mut [f32]) {
    const W: usize = 16;
    let n = x.len();
    let chunks = n / W;
    let av = _mm512_set1_ps(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for i in 0..chunks {
        let j = i * W;
        let yv = _mm512_loadu_ps(yp.add(j));
        _mm512_storeu_ps(
            yp.add(j),
            _mm512_add_ps(yv, _mm512_mul_ps(av, _mm512_loadu_ps(xp.add(j)))),
        );
    }
    for j in chunks * W..n {
        y[j] += alpha * x[j];
    }
}

/// Broadcast a ymm into both 256-bit halves of a zmm using only
/// AVX512F ops (`vshuff32x4` with an identity-pair mask).
// SAFETY: register-only shuffle; callers are themselves avx512f
// target-feature fns, so the feature is already established.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn pair512(lo: __m256, hi: __m256) -> __m512 {
    let a = _mm512_castps256_ps512(lo);
    let b = _mm512_castps256_ps512(hi);
    // imm 0b01_00_01_00: lanes [a.0, a.1, b.0, b.1] = [lo(256), hi(256)]
    _mm512_shuffle_f32x4::<0x44>(a, b)
}

// SAFETY: dispatch verified avx512f; 8-lane loads stop below a.len()
// (== every b[t].len()), and the zmm stores land inside the 4x8 lanes
// array whose pointer they are derived from.
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn dot4_avx512(a: &[f32], b: [&[f32]; Q_TILE]) -> [f32; Q_TILE] {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
    // Two zmm accumulators, each holding two independent 8-lane query
    // accumulators: lanes 0-7 = query t, lanes 8-15 = query t+1.  Each
    // 8-lane half follows exactly the scalar accumulation order.
    let mut acc01 = _mm512_setzero_ps();
    let mut acc23 = _mm512_setzero_ps();
    for i in 0..chunks {
        let j = i * LANES;
        let x8 = _mm256_loadu_ps(ap.add(j));
        let xv = pair512(x8, x8);
        let b01 = pair512(
            _mm256_loadu_ps(bp[0].add(j)),
            _mm256_loadu_ps(bp[1].add(j)),
        );
        let b23 = pair512(
            _mm256_loadu_ps(bp[2].add(j)),
            _mm256_loadu_ps(bp[3].add(j)),
        );
        acc01 = _mm512_add_ps(acc01, _mm512_mul_ps(xv, b01));
        acc23 = _mm512_add_ps(acc23, _mm512_mul_ps(xv, b23));
    }
    let mut lanes = [[0.0f32; LANES]; Q_TILE];
    // One zmm store covers two query accumulators; the pointer is
    // derived from the whole 4x8 array so both halves are in bounds.
    let lp = lanes.as_mut_ptr() as *mut f32;
    _mm512_storeu_ps(lp, acc01);
    _mm512_storeu_ps(lp.add(2 * LANES), acc23);
    finish4(n, chunks * LANES, &lanes, |j, t| a[j] * b[t][j])
}

// SAFETY: dispatch verified avx512f; code and query-row loads stop
// below codes.len() (== every b[t].len()), and the zmm stores land
// inside the 4x8 lanes array.
#[target_feature(enable = "avx512f")]
pub(crate) unsafe fn dot4_i8_avx512(
    codes: &[i8],
    scale: f32,
    b: [&[f32]; Q_TILE],
) -> [f32; Q_TILE] {
    let n = codes.len();
    let chunks = n / LANES;
    let cp = codes.as_ptr();
    let bp = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
    let mut acc01 = _mm512_setzero_ps();
    let mut acc23 = _mm512_setzero_ps();
    for i in 0..chunks {
        let j = i * LANES;
        let c8 = _mm_loadl_epi64(cp.add(j) as *const __m128i);
        let x8 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(c8));
        let xv = pair512(x8, x8);
        let b01 = pair512(
            _mm256_loadu_ps(bp[0].add(j)),
            _mm256_loadu_ps(bp[1].add(j)),
        );
        let b23 = pair512(
            _mm256_loadu_ps(bp[2].add(j)),
            _mm256_loadu_ps(bp[3].add(j)),
        );
        acc01 = _mm512_add_ps(acc01, _mm512_mul_ps(xv, b01));
        acc23 = _mm512_add_ps(acc23, _mm512_mul_ps(xv, b23));
    }
    let mut lanes = [[0.0f32; LANES]; Q_TILE];
    let lp = lanes.as_mut_ptr() as *mut f32;
    _mm512_storeu_ps(lp, acc01);
    _mm512_storeu_ps(lp.add(2 * LANES), acc23);
    let out = finish4(n, chunks * LANES, &lanes, |j, t| {
        codes[j] as f32 * b[t][j]
    });
    [out[0] * scale, out[1] * scale, out[2] * scale, out[3] * scale]
}
