//! `analysis/` — dependency-free static analysis over the repo's own
//! sources, in the same hand-rolled style as `util/json` and
//! `net/http`.
//!
//! PRs 2–7 created conventions that are load-bearing for both the perf
//! story and the bit-reproducibility tests, but nothing enforced them
//! mechanically.  This module turns them into checked facts.  The
//! pipeline is [`walk`] (enumerate tracked `.rs` sources, minus
//! `vendor/` and `fixtures/`), [`lexer`] (a comment/string/raw-string
//! aware tokenizer, property-tested so sealed contexts can never
//! desync a lint), and [`lints`] (the [`lints::Lint`] trait plus the
//! five shipped repo-invariant lints):
//!
//! | lint | invariant |
//! |------|-----------|
//! | `unsafe-audit` | every `unsafe` carries `// SAFETY:` and its file is in `unsafe_budget.txt` with an exact site count |
//! | `kernel-purity` | no manual f32/f64 multiply-accumulate loops or map-multiply reductions outside `vecops/` |
//! | `simd-contract` | `std::arch` only inside the two SIMD backends, only allowlisted intrinsics, FMA families banned outright |
//! | `panic-path` | no `unwrap`/`expect`/`panic!`-family/range-index on the `net/`+`serve/` request paths |
//! | `ordering-annotation` | every atomic `Ordering::*` in the audited files carries `// ORDERING:` |
//!
//! The gate is self-hosting: `rust/tests/lint_repo.rs` runs the suite
//! over this repo inside tier-1 `cargo test`, and `fullw2v lint
//! [--json]` runs it from the CLI.
//!
//! ## Extending
//!
//! A new lint is a struct implementing [`lints::Lint`] (`check` per
//! file, optional `finish` for cross-file accounting) added to
//! [`lints::default_lints`], plus a positive + negative fixture under
//! `rust/tests/fixtures/lint/` proving it fires and stays quiet.
//!
//! ## Allowlists are the reviewable artifact
//!
//! Suppressions are deliberately diff-visible, never config-file
//! toggles:
//!
//! * a site waiver is a `// LINT: allow(<lint>): <reason>` comment on
//!   or above the offending statement;
//! * new `unsafe` edits `unsafe_budget.txt` (path + exact count);
//! * a new intrinsic edits `X86_ALLOW` / `NEON_ALLOW` in `lints.rs`;
//! * the FMA-family ban and the unsafe budget itself have **no**
//!   waiver — those contracts are the point.

pub mod lexer;
pub mod lints;
pub mod walk;

use crate::util::json::{obj, Json};
use std::path::Path;

pub use lints::{Finding, Lint};
pub use walk::SourceFile;

/// The checked-in unsafe inventory, compiled into the binary so the
/// linter needs no runtime lookup of its own config.
pub const UNSAFE_BUDGET: &str = include_str!("unsafe_budget.txt");

/// Outcome of a lint run: all findings plus how many files were seen
/// (so "0 findings over 0 files" can't masquerade as a clean run).
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Lint a repo checkout rooted at `root` with the shipped lint set and
/// the checked-in unsafe budget.
pub fn run(root: &Path) -> Result<Report, String> {
    let files = walk::walk_repo(root)
        .map_err(|e| format!("walking {}: {e}", root.display()))?;
    run_files(&files, UNSAFE_BUDGET)
}

/// Lint an explicit file set with an explicit budget — the injection
/// point fixtures and tests use to exercise lints on synthetic paths.
pub fn run_files(files: &[SourceFile], budget: &str) -> Result<Report, String> {
    let mut lints = lints::default_lints(budget)?;
    let mut findings = Vec::new();
    for f in files {
        let ctx = lints::FileCtx::new(&f.path, &f.text);
        for l in lints.iter_mut() {
            l.check(&ctx, &mut findings);
        }
    }
    for l in lints.iter_mut() {
        l.finish(&mut findings);
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint).cmp(&(b.file.as_str(), b.line, b.lint))
    });
    Ok(Report { findings, files: files.len() })
}

/// Human-readable rendering: one `file:line: [lint] msg` block per
/// finding with its fix hint, then a summary line.
pub fn render_text(r: &Report) -> String {
    let mut s = String::new();
    for f in &r.findings {
        s.push_str(&format!(
            "{}:{}: [{}] {}\n    fix: {}\n",
            f.file, f.line, f.lint, f.msg, f.hint
        ));
    }
    s.push_str(&format!(
        "{} finding(s) across {} file(s)\n",
        r.findings.len(),
        r.files
    ));
    s
}

/// Machine-readable rendering via the crate's own JSON layer.
pub fn render_json(r: &Report) -> String {
    let findings = r
        .findings
        .iter()
        .map(|f| {
            obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("lint", Json::Str(f.lint.to_string())),
                ("msg", Json::Str(f.msg.clone())),
                ("hint", Json::Str(f.hint.to_string())),
            ])
        })
        .collect();
    obj(vec![
        ("files", Json::Num(r.files as f64)),
        ("findings", Json::Arr(findings)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_text_parses_and_run_files_sorts() {
        // the checked-in budget must always parse
        lints::default_lints(UNSAFE_BUDGET).expect("budget parses");
        let files = vec![
            SourceFile {
                path: "rust/src/net/zzz.rs".to_string(),
                text: "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n".to_string(),
            },
            SourceFile {
                path: "rust/src/net/aaa.rs".to_string(),
                text: "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n".to_string(),
            },
        ];
        let rep = run_files(&files, "").expect("run");
        assert_eq!(rep.files, 2);
        assert_eq!(rep.findings.len(), 2);
        assert!(rep.findings[0].file < rep.findings[1].file);
        assert!(!rep.clean());
    }

    #[test]
    fn renderings_carry_location_and_lint_name() {
        let files = vec![SourceFile {
            path: "rust/src/serve/z.rs".to_string(),
            text: "fn f() { panic!(\"boom\") }\n".to_string(),
        }];
        let rep = run_files(&files, "").expect("run");
        let text = render_text(&rep);
        assert!(text.contains("rust/src/serve/z.rs:1: [panic-path]"), "{text}");
        let json = render_json(&rep);
        assert!(json.contains("\"lint\":\"panic-path\""), "{json}");
        assert!(json.contains("\"files\":1"), "{json}");
    }
}
