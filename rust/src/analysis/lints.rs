//! Repo-invariant lints over the [`super::lexer`] token stream.
//!
//! Every lint implements [`Lint`] and reports [`Finding`]s anchored to
//! `file:line`.  The five shipped lints pin the load-bearing conventions
//! PRs 2–7 created (see the module docs on [`super`] for the catalogue
//! and the waiver workflow).  Matching is structural over tokens — the
//! lexer has already sealed strings and comments, so a `panic!` inside a
//! string literal or an `unsafe` in a doc comment can never fire a lint.
//!
//! ## Waivers
//!
//! A finding is suppressed by a `// LINT: allow(<lint-name>): <reason>`
//! comment either trailing the offending line or attached above the
//! statement (contiguous comment block, no code lines in between).  The
//! comment *is* the reviewable artifact: adding one shows up in the
//! diff next to the code it excuses.  Two things are deliberately not
//! waivable: the FMA-intrinsic ban (the bit-identity contract has no
//! exceptions) and the unsafe budget (new unsafe must edit
//! `unsafe_budget.txt` instead).

use super::lexer::{lex, TokKind, Token};
use std::collections::{BTreeMap, BTreeSet};

/// Repo-relative path of the checked-in unsafe allowlist.
pub const BUDGET_PATH: &str = "rust/src/analysis/unsafe_budget.txt";

/// One diagnostic: where, which lint, what, and how to fix it.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub lint: &'static str,
    pub msg: String,
    pub hint: &'static str,
}

/// An extensible repo lint.  `check` runs once per source file;
/// `finish` runs once after all files (for cross-file accounting like
/// the unsafe budget).
pub trait Lint {
    fn name(&self) -> &'static str;
    fn check(&mut self, ctx: &FileCtx<'_>, out: &mut Vec<Finding>);
    fn finish(&mut self, _out: &mut Vec<Finding>) {}
}

/// Identifiers that read like operands but are keywords — a `*` after
/// one of these is a dereference or pointer type, never multiplication.
const NON_OPERAND_KEYWORDS: &[&str] = &[
    "as", "if", "in", "return", "match", "while", "let", "else", "move",
    "mut", "ref", "loop", "break", "continue", "unsafe", "where", "const",
];

const INT_SUFFIXES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64",
    "i128", "isize",
];

/// Per-file token stream plus the derived maps every lint shares.
pub struct FileCtx<'a> {
    pub path: &'a str,
    toks: Vec<Token>,
    /// Indices into `toks` of the non-comment tokens, in order.
    code: Vec<usize>,
    comments_by_line: BTreeMap<u32, Vec<usize>>,
    code_lines: BTreeSet<u32>,
    /// `#[cfg(test)]` item spans, as ranges over code positions.
    test_spans: Vec<(usize, usize)>,
}

impl<'a> FileCtx<'a> {
    pub fn new(path: &'a str, text: &str) -> Self {
        let toks = lex(text);
        let mut code = Vec::new();
        let mut comments_by_line: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
        let mut code_lines = BTreeSet::new();
        for (i, t) in toks.iter().enumerate() {
            if t.kind == TokKind::Comment {
                comments_by_line.entry(t.line).or_default().push(i);
            } else {
                code.push(i);
                code_lines.insert(t.line);
            }
        }
        let mut ctx = FileCtx {
            path,
            toks,
            code,
            comments_by_line,
            code_lines,
            test_spans: Vec::new(),
        };
        ctx.test_spans = ctx.find_test_spans();
        ctx
    }

    pub fn code_len(&self) -> usize {
        self.code.len()
    }

    fn tok(&self, p: usize) -> &Token {
        &self.toks[self.code[p]]
    }

    pub fn line(&self, p: usize) -> u32 {
        self.tok(p).line
    }

    /// Ident text at code position `p`, if it is an ident.
    pub fn ident(&self, p: usize) -> Option<&str> {
        if p < self.code.len() && self.tok(p).kind == TokKind::Ident {
            Some(self.tok(p).text.as_str())
        } else {
            None
        }
    }

    /// Punct text at code position `p`, if it is punctuation.
    pub fn punct(&self, p: usize) -> Option<&str> {
        if p < self.code.len() && self.tok(p).kind == TokKind::Punct {
            Some(self.tok(p).text.as_str())
        } else {
            None
        }
    }

    fn is_punct(&self, p: usize, s: &str) -> bool {
        self.punct(p) == Some(s)
    }

    fn is_ident(&self, p: usize, s: &str) -> bool {
        self.ident(p) == Some(s)
    }

    /// Position just past the delimiter that matches the opener at `p`.
    fn match_delim(&self, p: usize, open: &str, close: &str) -> usize {
        let mut depth = 0usize;
        let mut q = p;
        while q < self.code.len() {
            if self.is_punct(q, open) {
                depth += 1;
            } else if self.is_punct(q, close) {
                depth -= 1;
                if depth == 0 {
                    return q + 1;
                }
            }
            q += 1;
        }
        self.code.len()
    }

    /// Spans (code positions) of items under a `#[cfg(test)]` attribute:
    /// the attribute through either the item's matched `{ .. }` body or
    /// its terminating `;`.
    fn find_test_spans(&self) -> Vec<(usize, usize)> {
        let mut spans = Vec::new();
        let mut p = 0usize;
        while p + 6 < self.code.len() {
            let is_attr = self.is_punct(p, "#")
                && self.is_punct(p + 1, "[")
                && self.is_ident(p + 2, "cfg")
                && self.is_punct(p + 3, "(")
                && self.is_ident(p + 4, "test")
                && self.is_punct(p + 5, ")")
                && self.is_punct(p + 6, "]");
            if !is_attr {
                p += 1;
                continue;
            }
            let mut q = p + 7;
            let mut end = self.code.len();
            while q < self.code.len() {
                if self.is_punct(q, ";") {
                    end = q + 1;
                    break;
                }
                if self.is_punct(q, "{") {
                    end = self.match_delim(q, "{", "}");
                    break;
                }
                q += 1;
            }
            spans.push((p, end));
            p = end;
        }
        spans
    }

    pub fn in_test(&self, p: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= p && p < b)
    }

    /// First code position of the statement containing `p` (statements
    /// bound by `;`, `{`, `}` — match arms and struct fields fold into
    /// their enclosing statement, which is what the comment-attachment
    /// rules want).
    fn stmt_start(&self, p: usize) -> usize {
        let mut q = p;
        while q > 0 {
            if matches!(self.punct(q - 1), Some(";") | Some("{") | Some("}")) {
                break;
            }
            q -= 1;
        }
        q
    }

    fn comment_on_line_contains(&self, line: u32, marker: &str) -> bool {
        self.comments_by_line
            .get(&line)
            .is_some_and(|idxs| {
                idxs.iter().any(|&i| self.toks[i].text.contains(marker))
            })
    }

    /// Comment-only lines directly above `line` (stopping at the first
    /// code or blank line) containing `marker`?
    fn comment_block_above_contains(&self, line: u32, marker: &str) -> bool {
        let mut l = line.saturating_sub(1);
        while l >= 1 {
            if self.code_lines.contains(&l)
                || !self.comments_by_line.contains_key(&l)
            {
                return false;
            }
            if self.comment_on_line_contains(l, marker) {
                return true;
            }
            l -= 1;
        }
        false
    }

    /// Is `marker` present in a comment trailing the token's line, in
    /// the contiguous comment block directly above it (match arms), on
    /// the statement's first line, or in the block above the statement?
    pub fn has_marker(&self, p: usize, marker: &str) -> bool {
        let line = self.line(p);
        if self.comment_on_line_contains(line, marker)
            || self.comment_block_above_contains(line, marker)
        {
            return true;
        }
        let stmt_line = self.line(self.stmt_start(p));
        stmt_line != line
            && (self.comment_on_line_contains(stmt_line, marker)
                || self.comment_block_above_contains(stmt_line, marker))
    }

    /// Inline waiver: `// LINT: allow(<lint>): reason`.
    pub fn waived(&self, p: usize, lint: &str) -> bool {
        self.has_marker(p, &format!("LINT: allow({lint})"))
    }

    /// `*` at `p` used as binary multiplication (the previous token is
    /// an operand: a number, a closing delimiter, or a non-keyword
    /// ident) rather than a deref / raw-pointer sigil.
    fn is_binary_star(&self, p: usize) -> bool {
        if !self.is_punct(p, "*") || p == 0 {
            return false;
        }
        let prev = self.tok(p - 1);
        match prev.kind {
            TokKind::Num => true,
            TokKind::Ident => {
                !NON_OPERAND_KEYWORDS.contains(&prev.text.as_str())
            }
            TokKind::Punct => prev.text == ")" || prev.text == "]",
            _ => false,
        }
    }

    /// Statement boundaries as ranges over code positions.
    fn statements(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut start = 0usize;
        for p in 0..self.code.len() {
            if matches!(self.punct(p), Some(";") | Some("{") | Some("}")) {
                if p > start {
                    out.push((start, p));
                }
                start = p + 1;
            }
        }
        if self.code.len() > start {
            out.push((start, self.code.len()));
        }
        out
    }

    /// Body spans of `for` / `while` / `loop` loops.
    fn loop_spans(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for p in 0..self.code.len() {
            if matches!(self.ident(p), Some("for") | Some("while") | Some("loop"))
            {
                let mut q = p + 1;
                while q < self.code.len() && !self.is_punct(q, "{") {
                    q += 1;
                }
                if q < self.code.len() {
                    out.push((q, self.match_delim(q, "{", "}")));
                }
            }
        }
        out
    }
}

fn finding(
    ctx: &FileCtx<'_>,
    p: usize,
    lint: &'static str,
    msg: String,
    hint: &'static str,
) -> Finding {
    Finding { file: ctx.path.to_string(), line: ctx.line(p), lint, msg, hint }
}

// ---------------------------------------------------------------------
// L1: unsafe-audit
// ---------------------------------------------------------------------

/// Every `unsafe` site carries a `// SAFETY:` comment and its file
/// appears in `unsafe_budget.txt` with the exact site count — so any
/// new unsafe is a two-line reviewable diff (the comment and the budget
/// bump).  `unsafe fn(..)` *types* (fn-pointer aliases) are not sites.
pub struct UnsafeAudit {
    budget: BTreeMap<String, usize>,
    counted: BTreeMap<String, (usize, u32)>,
}

impl UnsafeAudit {
    pub fn new(budget_text: &str) -> Result<Self, String> {
        let mut budget = BTreeMap::new();
        for (i, raw) in budget_text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (path, count) = (parts.next(), parts.next());
            match (path, count, parts.next()) {
                (Some(p), Some(c), None) => {
                    let c: usize = c.parse().map_err(|_| {
                        format!("{BUDGET_PATH}:{}: bad count {c:?}", i + 1)
                    })?;
                    budget.insert(p.to_string(), c);
                }
                _ => {
                    return Err(format!(
                        "{BUDGET_PATH}:{}: expected `<path> <count>`",
                        i + 1
                    ))
                }
            }
        }
        Ok(UnsafeAudit { budget, counted: BTreeMap::new() })
    }
}

impl Lint for UnsafeAudit {
    fn name(&self) -> &'static str {
        "unsafe-audit"
    }

    fn check(&mut self, ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
        for p in 0..ctx.code_len() {
            if !ctx.is_ident(p, "unsafe") {
                continue;
            }
            // `unsafe fn(` is a fn-pointer *type*, not an unsafe site
            if ctx.is_ident(p + 1, "fn") && ctx.is_punct(p + 2, "(") {
                continue;
            }
            let entry = self
                .counted
                .entry(ctx.path.to_string())
                .or_insert((0, ctx.line(p)));
            entry.0 += 1;
            if !ctx.has_marker(p, "SAFETY:") {
                out.push(finding(
                    ctx,
                    p,
                    self.name(),
                    "unsafe site without a `// SAFETY:` comment".to_string(),
                    "state the invariant that makes this sound, on or \
                     directly above the statement",
                ));
            }
        }
    }

    fn finish(&mut self, out: &mut Vec<Finding>) {
        for (path, (count, first_line)) in &self.counted {
            match self.budget.get(path) {
                None => out.push(Finding {
                    file: path.clone(),
                    line: *first_line,
                    lint: self.name(),
                    msg: format!(
                        "{count} unsafe site(s) but the file is not in the \
                         unsafe budget"
                    ),
                    hint: "add `<path> <count>` to \
                           rust/src/analysis/unsafe_budget.txt — the budget \
                           edit is the reviewable artifact",
                }),
                Some(b) if *b != *count => out.push(Finding {
                    file: path.clone(),
                    line: *first_line,
                    lint: self.name(),
                    msg: format!(
                        "{count} unsafe site(s) but the budget says {b}"
                    ),
                    hint: "update the count in \
                           rust/src/analysis/unsafe_budget.txt to match the \
                           audited inventory",
                }),
                Some(_) => {}
            }
        }
        for (path, b) in &self.budget {
            if !self.counted.contains_key(path) {
                out.push(Finding {
                    file: BUDGET_PATH.to_string(),
                    line: 1,
                    lint: self.name(),
                    msg: format!(
                        "stale budget entry: {path} ({b}) has no unsafe sites"
                    ),
                    hint: "remove the entry so the budget stays an exact \
                           inventory",
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// L2: kernel-purity
// ---------------------------------------------------------------------

/// No hand-rolled f32/f64 reduction loops outside `vecops/` — the PR 2
/// "single kernel home" invariant.  Two shapes are flagged: a float
/// compound-accumulate with a multiply inside a loop body (`acc += a *
/// b` — a manual dot/axpy), and an iterator reduction whose `map`
/// closure multiplies (`.map(|x| x * y).sum()`).  Integer accounting
/// (`n += (a * b) as u64`) is not a kernel and is skipped.
pub struct KernelPurity;

impl KernelPurity {
    fn in_scope(path: &str) -> bool {
        path.starts_with("rust/src/")
            && !path.starts_with("rust/src/vecops/")
    }

    fn stmt_has_cast_to(
        ctx: &FileCtx<'_>,
        stmt: (usize, usize),
        types: &[&str],
    ) -> bool {
        (stmt.0..stmt.1).any(|p| {
            ctx.is_ident(p, "as")
                && ctx.ident(p + 1).is_some_and(|t| types.contains(&t))
        })
    }

    fn stmt_has_float_evidence(ctx: &FileCtx<'_>, stmt: (usize, usize)) -> bool {
        if Self::stmt_has_cast_to(ctx, stmt, &["f32", "f64"]) {
            return true;
        }
        (stmt.0..stmt.1).any(|p| {
            let t = ctx.tok(p);
            t.kind == TokKind::Num && {
                let s = t.text.as_str();
                let hex = s.starts_with("0x") || s.starts_with("0X");
                s.contains('.') || (!hex && (s.contains('e') || s.contains('E')))
            }
        })
    }
}

impl Lint for KernelPurity {
    fn name(&self) -> &'static str {
        "kernel-purity"
    }

    fn check(&mut self, ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
        if !Self::in_scope(ctx.path) {
            return;
        }
        let loops = ctx.loop_spans();
        for stmt in ctx.statements() {
            if ctx.in_test(stmt.0) {
                continue;
            }
            let int_only = Self::stmt_has_cast_to(ctx, stmt, INT_SUFFIXES)
                && !Self::stmt_has_float_evidence(ctx, stmt);

            // shape 1: `acc += a * b` (or -=) inside a loop body
            let compound = (stmt.0..stmt.1.saturating_sub(1)).find(|&p| {
                matches!(ctx.punct(p), Some("+") | Some("-"))
                    && ctx.is_punct(p + 1, "=")
            });
            if let Some(p) = compound {
                let in_loop = loops.iter().any(|&(a, b)| a <= p && p < b);
                let has_mul = (stmt.0..stmt.1).any(|q| ctx.is_binary_star(q));
                if in_loop && has_mul && !int_only && !ctx.waived(p, self.name())
                {
                    out.push(finding(
                        ctx,
                        p,
                        self.name(),
                        "manual multiply-accumulate loop outside vecops/"
                            .to_string(),
                        "route the reduction through crate::vecops (dot / \
                         dot_f64 / axpy / the tile kernels) or waive with \
                         `// LINT: allow(kernel-purity): <why>`",
                    ));
                }
            }

            // shape 2: `.map(|..| .. * ..)` feeding `.sum()` / `.fold()`
            let has_reduce = (stmt.0..stmt.1).any(|p| {
                p > stmt.0
                    && ctx.is_punct(p - 1, ".")
                    && matches!(ctx.ident(p), Some("sum") | Some("fold"))
            });
            if !has_reduce || int_only {
                continue;
            }
            for p in stmt.0..stmt.1 {
                let is_map = p > stmt.0
                    && ctx.is_punct(p - 1, ".")
                    && ctx.is_ident(p, "map")
                    && ctx.is_punct(p + 1, "(");
                if !is_map {
                    continue;
                }
                let close = ctx.match_delim(p + 1, "(", ")");
                let mul_inside =
                    (p + 2..close).any(|q| ctx.is_binary_star(q));
                if mul_inside && !ctx.waived(p, self.name()) {
                    out.push(finding(
                        ctx,
                        p,
                        self.name(),
                        "map-multiply reduction outside vecops/".to_string(),
                        "route the inner product through crate::vecops::\
                         dot_f64 (or waive with `// LINT: \
                         allow(kernel-purity): <why>` if the element op \
                         differs from the shared kernels)",
                    ));
                    break;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// L3: simd-contract
// ---------------------------------------------------------------------

/// The audited intrinsics each backend may use.  Deliberately exact:
/// the no-FMA bit-identity contract (PR 7) means a *new* intrinsic is a
/// reviewed allowlist edit, and the fmadd/fmsub families can never be
/// added because the family check below runs first and is not waivable.
const X86_ALLOW: &[&str] = &[
    // AVX2 f32 kernels
    "_mm256_setzero_ps",
    "_mm256_add_ps",
    "_mm256_mul_ps",
    "_mm256_loadu_ps",
    "_mm256_storeu_ps",
    "_mm256_set1_ps",
    // int8 widening (exact i8 -> i32 -> f32)
    "_mm_loadl_epi64",
    "_mm256_cvtepi8_epi32",
    "_mm256_cvtepi32_ps",
    // f64 dot (exact f32 -> f64 widening)
    "_mm256_setzero_pd",
    "_mm256_cvtps_pd",
    "_mm_loadu_ps",
    "_mm256_add_pd",
    "_mm256_mul_pd",
    "_mm256_storeu_pd",
    // AVX-512F
    "_mm512_set1_ps",
    "_mm512_setzero_ps",
    "_mm512_loadu_ps",
    "_mm512_storeu_ps",
    "_mm512_add_ps",
    "_mm512_mul_ps",
    "_mm512_castps256_ps512",
    "_mm512_shuffle_f32x4",
    // vector types
    "__m128i",
    "__m256",
    "__m512",
];

const NEON_ALLOW: &[&str] = &[
    "vdupq_n_f32",
    "vaddq_f32",
    "vmulq_f32",
    "vld1q_f32",
    "vst1q_f32",
    // int8 widening chain (exact)
    "vld1_s8",
    "vmovl_s8",
    "vmovl_s16",
    "vmovl_high_s16",
    "vget_low_s16",
    "vcvtq_f32_s32",
    // f64 dot (exact f32 -> f64 widening)
    "vdupq_n_f64",
    "vaddq_f64",
    "vmulq_f64",
    "vcvt_f64_f32",
    "vcvt_high_f64_f32",
    "vget_low_f32",
    "vst1q_f64",
    // vector types
    "float32x4_t",
];

/// Ident prefixes that mark an x86 intrinsic or vector type.
fn x86_intrinsic_like(s: &str) -> bool {
    s.starts_with("_mm") || s.starts_with("__m")
}

/// Ident prefixes that mark a NEON intrinsic or vector type.  Only
/// applied *inside* the NEON backend (outside it, short `v`-prefixed
/// names are ordinary variables); the exact-allowlist and FMA-family
/// checks cover leakage elsewhere.
fn neon_intrinsic_like(s: &str) -> bool {
    const PREFIXES: &[&str] =
        &["vld", "vst", "vdup", "vadd", "vmul", "vmov", "vcvt", "vget"];
    PREFIXES.iter().any(|p| s.starts_with(p))
        || s.ends_with("x2_t")
        || s.ends_with("x4_t")
        || s.ends_with("x8_t")
        || s.ends_with("x16_t")
}

/// The fused multiply-add families, on both ISAs.  A single fused
/// rounding breaks bit-identity with the scalar reference, so these are
/// banned everywhere — including the backends — with no waiver.
fn fma_family(s: &str) -> bool {
    let l = s.to_ascii_lowercase();
    ["fmadd", "fmsub", "fnmadd", "fnmsub"].iter().any(|f| l.contains(f))
        || ["vfma", "vfms", "vmla", "vmls"].iter().any(|f| l.starts_with(f))
}

#[derive(Clone, Copy, PartialEq)]
enum Backend {
    X86,
    Neon,
}

pub struct SimdContract;

impl SimdContract {
    fn backend(path: &str) -> Option<Backend> {
        match path {
            "rust/src/vecops/simd_x86.rs" => Some(Backend::X86),
            "rust/src/vecops/simd_neon.rs" => Some(Backend::Neon),
            _ => None,
        }
    }
}

impl Lint for SimdContract {
    fn name(&self) -> &'static str {
        "simd-contract"
    }

    fn check(&mut self, ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
        let backend = Self::backend(ctx.path);
        for p in 0..ctx.code_len() {
            let Some(id) = ctx.ident(p) else { continue };

            // FMA families: banned everywhere, never waivable.
            if fma_family(id) {
                out.push(finding(
                    ctx,
                    p,
                    self.name(),
                    format!("fused multiply-add `{id}` breaks the scalar \
                             bit-identity contract"),
                    "use separate mul + add (see the backend module docs); \
                     this check has no waiver",
                ));
                continue;
            }
            if id == "mul_add" && ctx.path.starts_with("rust/src/vecops/") {
                out.push(finding(
                    ctx,
                    p,
                    self.name(),
                    "`mul_add` fuses the rounding inside the kernel home"
                        .to_string(),
                    "use separate mul + add; this check has no waiver",
                ));
                continue;
            }

            // `std::arch` / `core::arch` paths outside the backends
            // (runtime feature *detection* is allowed anywhere).
            if (id == "std" || id == "core")
                && ctx.is_punct(p + 1, ":")
                && ctx.is_punct(p + 2, ":")
                && ctx.is_ident(p + 3, "arch")
                && backend.is_none()
            {
                let detection = ctx.is_punct(p + 4, ":")
                    && ctx.is_punct(p + 5, ":")
                    && matches!(
                        ctx.ident(p + 6),
                        Some("is_x86_feature_detected")
                            | Some("is_aarch64_feature_detected")
                    );
                if !detection && !ctx.waived(p, self.name()) {
                    out.push(finding(
                        ctx,
                        p,
                        self.name(),
                        "std::arch use outside the SIMD backends".to_string(),
                        "intrinsics live only in vecops/simd_x86.rs and \
                         vecops/simd_neon.rs behind the dispatch table",
                    ));
                }
                continue;
            }

            match backend {
                Some(Backend::X86) => {
                    if x86_intrinsic_like(id) && !X86_ALLOW.contains(&id) {
                        out.push(finding(
                            ctx,
                            p,
                            self.name(),
                            format!("intrinsic `{id}` is not in the audited \
                                     x86 allowlist"),
                            "extend X86_ALLOW in analysis/lints.rs in the \
                             same change — the allowlist edit is the \
                             reviewable artifact",
                        ));
                    }
                }
                Some(Backend::Neon) => {
                    if neon_intrinsic_like(id) && !NEON_ALLOW.contains(&id) {
                        out.push(finding(
                            ctx,
                            p,
                            self.name(),
                            format!("intrinsic `{id}` is not in the audited \
                                     NEON allowlist"),
                            "extend NEON_ALLOW in analysis/lints.rs in the \
                             same change — the allowlist edit is the \
                             reviewable artifact",
                        ));
                    }
                }
                None => {
                    if (x86_intrinsic_like(id)
                        || X86_ALLOW.contains(&id)
                        || NEON_ALLOW.contains(&id))
                        && !ctx.waived(p, self.name())
                    {
                        out.push(finding(
                            ctx,
                            p,
                            self.name(),
                            format!("SIMD intrinsic `{id}` outside the \
                                     backends"),
                            "go through the vecops dispatch API; raw \
                             intrinsics live only in the two backend files",
                        ));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// L4: panic-path
// ---------------------------------------------------------------------

/// The `net/` and `serve/` request paths must never panic on
/// adversarial input: no `unwrap` / `expect` / `panic!`-family macros,
/// and (in `net/`, which handles raw wire bytes) no range indexing —
/// use `get(..)` or checked arithmetic and answer 400/500 instead.
/// Init-time and invariant-panic sites carry explicit waivers.
pub struct PanicPath;

impl PanicPath {
    fn in_scope(path: &str) -> bool {
        path.starts_with("rust/src/net/") || path.starts_with("rust/src/serve/")
    }
}

impl Lint for PanicPath {
    fn name(&self) -> &'static str {
        "panic-path"
    }

    fn check(&mut self, ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
        if !Self::in_scope(ctx.path) {
            return;
        }
        let index_scope = ctx.path.starts_with("rust/src/net/");
        for p in 0..ctx.code_len() {
            if ctx.in_test(p) {
                continue;
            }
            if let Some(id) = ctx.ident(p) {
                let method_panic = (id == "unwrap" || id == "expect")
                    && p > 0
                    && ctx.is_punct(p - 1, ".");
                let macro_panic = matches!(
                    id,
                    "panic" | "unreachable" | "todo" | "unimplemented"
                ) && ctx.is_punct(p + 1, "!");
                if (method_panic || macro_panic) && !ctx.waived(p, self.name())
                {
                    out.push(finding(
                        ctx,
                        p,
                        self.name(),
                        format!("`{id}` on a request path"),
                        "return a 4xx/5xx response (or recover) instead; \
                         init-time code may waive with `// LINT: \
                         allow(panic-path): <why>`",
                    ));
                }
                continue;
            }
            if index_scope && ctx.is_punct(p, "[") && p > 0 {
                let prev = ctx.tok(p - 1);
                let indexes = match prev.kind {
                    TokKind::Ident => {
                        !NON_OPERAND_KEYWORDS.contains(&prev.text.as_str())
                    }
                    TokKind::Punct => prev.text == ")" || prev.text == "]",
                    _ => false,
                };
                if !indexes {
                    continue;
                }
                let close = ctx.match_delim(p, "[", "]");
                let has_range = (p + 1..close.saturating_sub(1))
                    .any(|q| ctx.is_punct(q, ".") && ctx.is_punct(q + 1, "."));
                if has_range && !ctx.waived(p, self.name()) {
                    out.push(finding(
                        ctx,
                        p,
                        self.name(),
                        "range index on wire-facing data can panic".to_string(),
                        "use .get(range) with an error response, or waive \
                         with the bound-check justification",
                    ));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// L5: ordering-annotation
// ---------------------------------------------------------------------

/// Every atomic `Ordering::*` use in the files where ordering is
/// load-bearing (the Hogwild model wrapper, the metrics registry, and
/// the admission gauge) carries a `// ORDERING:` justification.
pub struct OrderingAnnotation;

const L5_FILES: &[&str] = &[
    "rust/src/model/shared.rs",
    "rust/src/obs/registry.rs",
    "rust/src/net/shed.rs",
];

const ORDERING_LEVELS: &[&str] =
    &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

impl Lint for OrderingAnnotation {
    fn name(&self) -> &'static str {
        "ordering-annotation"
    }

    fn check(&mut self, ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
        if !L5_FILES.contains(&ctx.path) {
            return;
        }
        for p in 0..ctx.code_len() {
            if ctx.in_test(p) {
                continue;
            }
            let is_use = ctx.is_ident(p, "Ordering")
                && ctx.is_punct(p + 1, ":")
                && ctx.is_punct(p + 2, ":")
                && ctx.ident(p + 3).is_some_and(|l| ORDERING_LEVELS.contains(&l));
            if is_use
                && !ctx.has_marker(p, "ORDERING:")
                && !ctx.waived(p, self.name())
            {
                out.push(finding(
                    ctx,
                    p,
                    self.name(),
                    format!(
                        "Ordering::{} without an `// ORDERING:` justification",
                        ctx.ident(p + 3).unwrap_or("?")
                    ),
                    "say why this ordering is sufficient, on the line or \
                     above the statement",
                ));
            }
        }
    }
}

/// The shipped lint set, in L1..L5 order, sharing one budget text.
pub fn default_lints(
    budget_text: &str,
) -> Result<Vec<Box<dyn Lint>>, String> {
    Ok(vec![
        Box::new(UnsafeAudit::new(budget_text)?),
        Box::new(KernelPurity),
        Box::new(SimdContract),
        Box::new(PanicPath),
        Box::new(OrderingAnnotation),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(
        lint: &mut dyn Lint,
        path: &str,
        src: &str,
    ) -> Vec<Finding> {
        let ctx = FileCtx::new(path, src);
        let mut out = Vec::new();
        lint.check(&ctx, &mut out);
        lint.finish(&mut out);
        out
    }

    #[test]
    fn unsafe_fn_pointer_types_are_not_sites() {
        let src = "type F = unsafe fn(&[f32]) -> f32;\n";
        let mut l = UnsafeAudit::new("").unwrap();
        assert!(run_one(&mut l, "rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_needs_safety_and_budget() {
        let src = "fn f() { unsafe { g() } }\n";
        let mut l = UnsafeAudit::new("").unwrap();
        let out = run_one(&mut l, "rust/src/x.rs", src);
        // one finding for the missing SAFETY, one for the missing budget
        assert_eq!(out.len(), 2, "{out:?}");
        let src_ok = "fn f() {\n    // SAFETY: g is sound here.\n    unsafe { g() }\n}\n";
        let mut l = UnsafeAudit::new("rust/src/x.rs 1\n").unwrap();
        assert!(run_one(&mut l, "rust/src/x.rs", src_ok).is_empty());
    }

    #[test]
    fn budget_mismatch_and_stale_entries_fire() {
        let src = "// SAFETY: fine.\nunsafe impl Send for X {}\n";
        let mut l =
            UnsafeAudit::new("rust/src/x.rs 2\nrust/src/gone.rs 1\n").unwrap();
        let out = run_one(&mut l, "rust/src/x.rs", src);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().any(|f| f.msg.contains("budget says 2")));
        assert!(out.iter().any(|f| f.msg.contains("stale budget entry")));
    }

    #[test]
    fn kernel_purity_flags_loop_mac_but_not_integer_accounting() {
        let bad = "fn f(a: &[f32], b: &[f32]) -> f32 {\n    let mut acc = 0.0;\n    for i in 0..a.len() {\n        acc += a[i] * b[i];\n    }\n    acc\n}\n";
        let out = run_one(&mut KernelPurity, "rust/src/x.rs", bad);
        assert_eq!(out.len(), 1, "{out:?}");
        let ints = "fn f(m: usize, n: usize) -> u64 {\n    let mut acc = 0u64;\n    for _ in 0..3 {\n        acc += (m * n) as u64;\n    }\n    acc\n}\n";
        assert!(run_one(&mut KernelPurity, "rust/src/x.rs", ints).is_empty());
        // vecops itself is the kernel home
        assert!(run_one(&mut KernelPurity, "rust/src/vecops/x.rs", bad)
            .is_empty());
    }

    #[test]
    fn kernel_purity_flags_map_mul_sum_and_honors_waiver() {
        let bad = "fn n(v: &[f32]) -> f64 {\n    v.iter().map(|x| (x * x) as f64).sum::<f64>()\n}\n";
        let out = run_one(&mut KernelPurity, "rust/src/x.rs", bad);
        assert_eq!(out.len(), 1, "{out:?}");
        let waived = "fn n(v: &[f32]) -> f64 {\n    // LINT: allow(kernel-purity): frozen gold definition.\n    v.iter().map(|x| (x * x) as f64).sum::<f64>()\n}\n";
        assert!(run_one(&mut KernelPurity, "rust/src/x.rs", waived).is_empty());
    }

    #[test]
    fn simd_contract_bans_fma_everywhere() {
        let src = "fn f() { let x = _mm256_fmadd_ps(a, b, c); }\n";
        let out =
            run_one(&mut SimdContract, "rust/src/vecops/simd_x86.rs", src);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("fmadd"));
    }

    #[test]
    fn simd_contract_scopes_intrinsics_to_backends() {
        let outside = "fn f() { let v = _mm256_add_ps(a, b); }\n";
        let out = run_one(&mut SimdContract, "rust/src/serve/x.rs", outside);
        assert_eq!(out.len(), 1, "{out:?}");
        // detection macro is fine anywhere
        let detect = "fn f() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
        assert!(run_one(&mut SimdContract, "rust/src/x.rs", detect).is_empty());
        // unknown intrinsic inside a backend must be allowlisted
        let unknown = "fn f() { let v = _mm256_hadd_ps(a, b); }\n";
        let out = run_one(
            &mut SimdContract,
            "rust/src/vecops/simd_x86.rs",
            unknown,
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("allowlist"));
    }

    #[test]
    fn panic_path_flags_request_code_not_tests() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n#[cfg(test)]\nmod tests {\n    fn g(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        let out = run_one(&mut PanicPath, "rust/src/net/x.rs", src);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 1);
        // out of scope entirely
        assert!(run_one(&mut PanicPath, "rust/src/obs/x.rs", src).is_empty());
    }

    #[test]
    fn panic_path_flags_range_indexing_in_net_only() {
        let src = "fn f(b: &[u8], n: usize) -> &[u8] { &b[..n] }\n";
        let out = run_one(&mut PanicPath, "rust/src/net/x.rs", src);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(run_one(&mut PanicPath, "rust/src/serve/x.rs", src).is_empty());
        let waived = "fn f(b: &[u8], n: usize) -> &[u8] {\n    // LINT: allow(panic-path): n <= b.len() by construction.\n    &b[..n]\n}\n";
        assert!(run_one(&mut PanicPath, "rust/src/net/x.rs", waived).is_empty());
    }

    #[test]
    fn ordering_annotation_requires_justification() {
        let src = "fn f(a: &AtomicU64) -> u64 { a.load(Ordering::Relaxed) }\n";
        let out = run_one(&mut OrderingAnnotation, "rust/src/net/shed.rs", src);
        assert_eq!(out.len(), 1, "{out:?}");
        let ok = "fn f(a: &AtomicU64) -> u64 {\n    a.load(Ordering::Relaxed) // ORDERING: independent counter.\n}\n";
        assert!(run_one(&mut OrderingAnnotation, "rust/src/net/shed.rs", ok)
            .is_empty());
        // not one of the audited files
        assert!(run_one(&mut OrderingAnnotation, "rust/src/obs/hist.rs", src)
            .is_empty());
    }

    #[test]
    fn marker_attaches_above_a_match_arm() {
        // the waiver sits on the arm, not on the match statement's head
        let src = "fn f(r: Result<usize, ()>, b: &[u8]) -> &[u8] {\n    match r {\n        Err(_) => b,\n        // LINT: allow(panic-path): n <= b.len() by contract.\n        Ok(n) => &b[..n],\n    }\n}\n";
        assert!(run_one(&mut PanicPath, "rust/src/net/x.rs", src).is_empty());
        // without the waiver the same arm fires
        let bare = "fn f(r: Result<usize, ()>, b: &[u8]) -> &[u8] {\n    match r {\n        Err(_) => b,\n        Ok(n) => &b[..n],\n    }\n}\n";
        assert_eq!(run_one(&mut PanicPath, "rust/src/net/x.rs", bare).len(), 1);
    }

    #[test]
    fn marker_attaches_through_attributes_and_statements() {
        // SAFETY above a #[target_feature] attribute still attaches
        let src = "// SAFETY: dispatch checked avx2.\n#[target_feature(enable = \"avx2\")]\npub unsafe fn f() {}\n";
        let mut l = UnsafeAudit::new("rust/src/x.rs 1\n").unwrap();
        assert!(run_one(&mut l, "rust/src/x.rs", src).is_empty());
        // one comment above a multi-line call covers both Ordering args
        let src2 = "fn f(a: &AtomicU64) {\n    // ORDERING: saturating counter, no ordered state.\n    let _ = a.fetch_update(\n        Ordering::Relaxed,\n        Ordering::Relaxed,\n        |v| Some(v + 1),\n    );\n}\n";
        assert!(run_one(&mut OrderingAnnotation, "rust/src/net/shed.rs", src2)
            .is_empty());
    }
}
