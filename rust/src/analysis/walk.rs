//! Source enumeration for the repo lints.
//!
//! Walks the crate's own source roots (`rust/src`, `rust/tests`,
//! `rust/benches`, `examples`) collecting `.rs` files, with two carve-
//! outs: `rust/vendor/` (third-party shims are not held to the repo
//! invariants) and any `fixtures/` directory (lint fixtures *violate*
//! the invariants on purpose — that is what proves each lint fires).
//!
//! Paths come back repo-relative with `/` separators regardless of
//! platform, sorted, so findings and the unsafe budget are stable
//! across machines.

use std::fs;
use std::io;
use std::path::Path;

/// One source file: repo-relative path (`/`-separated) plus content.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// Directories (relative to the repo root) the lints cover.
pub const SOURCE_ROOTS: &[&str] =
    &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Directory names excluded wherever they appear under a source root.
const EXCLUDED_DIRS: &[&str] = &["vendor", "fixtures"];

/// Enumerate every lintable `.rs` file under `root` (a repo checkout).
/// Missing source roots are skipped, not errors, so the walker also
/// works on partial trees (fixtures in tests).
pub fn walk_repo(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    for sub in SOURCE_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect(&dir, sub, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}

fn collect(dir: &Path, rel: &str, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let path = entry.path();
        if path.is_dir() {
            if EXCLUDED_DIRS.contains(&name.as_ref()) {
                continue;
            }
            collect(&path, &format!("{rel}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            let text = fs::read_to_string(&path)?;
            out.push(SourceFile { path: format!("{rel}/{name}"), text });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walks_this_crate_and_excludes_vendor_and_fixtures() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = walk_repo(root).expect("walk");
        let paths: Vec<&str> =
            files.iter().map(|f| f.path.as_str()).collect();
        assert!(paths.contains(&"rust/src/lib.rs"));
        assert!(paths.contains(&"rust/src/analysis/walk.rs"));
        assert!(paths.iter().all(|p| !p.contains("/vendor/")));
        assert!(paths.iter().all(|p| !p.contains("/fixtures/")));
        // sorted and unique
        let mut sorted = paths.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(paths, sorted);
    }
}
