//! A comment/string/raw-string-aware Rust tokenizer for the repo lints.
//!
//! This is *not* a Rust parser — the lints only need a token stream that
//! never desyncs: an `unsafe` inside a string literal, a `*` inside a
//! nested block comment, or a `"` inside a raw string must not leak into
//! the code tokens the lints match on.  The hard cases are exactly the
//! ones the property tests in this module hammer:
//!
//! * nested block comments (`/* /* */ */` — Rust nests them, C does not)
//! * raw strings with arbitrary `#` counts (`r##"..."##`, `br#"..."#`)
//! * byte strings / byte chars (`b"..."`, `b'x'`) with escapes
//! * lifetime ticks vs char literals (`'a` vs `'a'` vs `'\n'`)
//! * raw identifiers (`r#match` is an ident, `r#"` opens a raw string)
//! * float literals vs range expressions (`1.5e-3` vs `0..10`)
//!
//! Everything the lints match structurally (idents, punctuation) comes
//! out as one token per ident / one token per punct char; multi-char
//! operators like `+=` and `::` are recognized by the lints as adjacent
//! `Punct` tokens.  Comments are kept (with their text) because the
//! lints look for `// SAFETY:` / `// ORDERING:` / `// LINT: allow(..)`
//! markers; strings are kept as opaque tokens so their *content* can
//! never match a code pattern.

/// Token class.  `Str` covers plain and byte strings, `RawStr` covers
/// raw and raw-byte strings; the lints only care that their content is
/// sealed off from the code stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Char,
    Str,
    RawStr,
    Num,
    Punct,
    Comment,
}

/// One token with its 1-based source line (multi-line tokens carry the
/// line they start on).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`.  Never fails: unterminated literals consume to end of
/// input (the lints run on code that already compiles, so this only
/// matters for not panicking on fixtures).
pub fn lex(src: &str) -> Vec<Token> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;

    let text = |a: usize, b: usize, cs: &[char]| -> String {
        cs[a..b].iter().collect()
    };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comments (incl. `///` and `//!` doc comments)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            toks.push(Token {
                kind: TokKind::Comment,
                text: text(start, i, &cs),
                line,
            });
            continue;
        }
        // block comments, nested per Rust's grammar
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Token {
                kind: TokKind::Comment,
                text: text(start, i, &cs),
                line: start_line,
            });
            continue;
        }
        // r / b prefixes: raw strings, byte strings, byte chars, raw
        // idents — all before the generic ident path so `r#"` cannot be
        // read as ident `r` + punct `#` + string.
        if c == 'r' || c == 'b' {
            let mut j = i;
            let mut byte = false;
            if cs[j] == 'b' {
                byte = true;
                j += 1;
            }
            let raw = j < n && cs[j] == 'r';
            if raw {
                j += 1;
            }
            let mut hashes = 0usize;
            while raw && j < n && cs[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if raw && j < n && cs[j] == '"' {
                // raw (byte) string: scan to `"` + `hashes` hashes
                let start = i;
                let start_line = line;
                j += 1;
                'scan: while j < n {
                    if cs[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if cs[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n && cs[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'scan;
                        }
                    }
                    j += 1;
                }
                i = j;
                toks.push(Token {
                    kind: TokKind::RawStr,
                    text: text(start, i, &cs),
                    line: start_line,
                });
                continue;
            }
            if raw && hashes == 1 && j < n && is_ident_start(cs[j]) {
                // raw identifier r#match — lexes as one Ident token
                let start = i;
                while j < n && is_ident_continue(cs[j]) {
                    j += 1;
                }
                i = j;
                toks.push(Token {
                    kind: TokKind::Ident,
                    text: text(start, i, &cs),
                    line,
                });
                continue;
            }
            if byte && !raw && j < n && (cs[j] == '"' || cs[j] == '\'') {
                // b"..." / b'x' with escapes
                let quote = cs[j];
                let start = i;
                let start_line = line;
                j += 1;
                while j < n {
                    if cs[j] == '\\' {
                        j += 2;
                    } else if cs[j] == quote {
                        j += 1;
                        break;
                    } else {
                        if cs[j] == '\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                }
                i = j;
                toks.push(Token {
                    kind: if quote == '"' { TokKind::Str } else { TokKind::Char },
                    text: text(start, i, &cs),
                    line: start_line,
                });
                continue;
            }
            // plain ident starting with r/b — fall through
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(cs[i]) {
                i += 1;
            }
            toks.push(Token {
                kind: TokKind::Ident,
                text: text(start, i, &cs),
                line,
            });
            continue;
        }
        // strings with escapes
        if c == '"' {
            let start = i;
            let start_line = line;
            i += 1;
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                } else if cs[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Token {
                kind: TokKind::Str,
                text: text(start, i, &cs),
                line: start_line,
            });
            continue;
        }
        // `'` opens either a lifetime or a char literal.  `'a'` is a
        // char (tick, one ident-start char, tick); `'abc` / `'static`
        // are lifetimes; `'\n'`, `'('`, `'\u{1F600}'` are chars.
        if c == '\'' {
            if i + 1 < n
                && is_ident_start(cs[i + 1])
                && !(i + 2 < n && cs[i + 2] == '\'')
            {
                let start = i;
                i += 2;
                while i < n && is_ident_continue(cs[i]) {
                    i += 1;
                }
                toks.push(Token {
                    kind: TokKind::Lifetime,
                    text: text(start, i, &cs),
                    line,
                });
                continue;
            }
            let start = i;
            i += 1;
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                } else if cs[i] == '\'' {
                    i += 1;
                    break;
                } else if cs[i] == '\n' {
                    // not a valid char literal; bail so a stray tick
                    // cannot swallow the rest of the file
                    break;
                } else {
                    i += 1;
                }
            }
            toks.push(Token {
                kind: TokKind::Char,
                text: text(start, i, &cs),
                line,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let hex = c == '0' && i + 1 < n && (cs[i + 1] == 'x' || cs[i + 1] == 'X');
            let mut seen_dot = false;
            i += 1;
            while i < n {
                let ch = cs[i];
                if is_ident_continue(ch) {
                    i += 1;
                } else if (ch == '+' || ch == '-')
                    && !hex
                    && matches!(cs[i - 1], 'e' | 'E')
                {
                    // exponent sign inside `1.5e-3` — but never inside
                    // hex (`0x1E` must not eat a following `+ 2`)
                    i += 1;
                } else if ch == '.'
                    && !seen_dot
                    && i + 1 < n
                    && cs[i + 1].is_ascii_digit()
                {
                    // `1.5` continues the number; `0..10` does not
                    // (the next char is `.`), `1.max(2)` does not
                    // (the next char is alphabetic)
                    seen_dot = true;
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Token {
                kind: TokKind::Num,
                text: text(start, i, &cs),
                line,
            });
            continue;
        }
        toks.push(Token {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn idents(src: &str) -> Vec<(String, u32)> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| (t.text, t.line))
            .collect()
    }

    #[test]
    fn nested_block_comments_do_not_desync() {
        let src = "a /* x /* unsafe */ y */ b";
        let ids = idents(src);
        assert_eq!(ids, vec![("a".into(), 1), ("b".into(), 1)]);
    }

    #[test]
    fn raw_strings_with_hashes_seal_their_content() {
        let src = r####"let s = r##"quote " and "# inside unsafe"##; done"####;
        let ids: Vec<String> = idents(src).into_iter().map(|p| p.0).collect();
        assert_eq!(ids, vec!["let", "s", "done"]);
        let raw: Vec<Token> = lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::RawStr)
            .collect();
        assert_eq!(raw.len(), 1);
        assert!(raw[0].text.contains("unsafe"));
    }

    #[test]
    fn raw_byte_strings_and_byte_chars() {
        let src = r#"p.push(&br"GET /"[..]); let q = b'\''; let s = b"a\"b"; t"#;
        let ids: Vec<String> = idents(src).into_iter().map(|p| p.0).collect();
        assert_eq!(ids, vec!["p", "push", "let", "q", "let", "s", "t"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) { let c = 'a'; let nl = '\\n'; let p = '('; }";
        let toks = lex(src);
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let chars: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(chars, vec!["'a'", "'\\n'", "'('"]);
    }

    #[test]
    fn static_lifetime_and_labels() {
        let src = "x: &'static str; 'outer: loop { break 'outer; }";
        let toks = lex(src);
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'static", "'outer", "'outer"]);
    }

    #[test]
    fn numbers_vs_ranges_and_methods() {
        let src = "let a = 1.5e-3; for i in 0..10 {} let b = 2.0f64; let h = 0x1F; let c = 1.max(2);";
        let toks = lex(src);
        let nums: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5e-3", "0", "10", "2.0f64", "0x1F", "1", "2"]);
    }

    #[test]
    fn hex_number_does_not_eat_a_plus() {
        let src = "let x = 0x1E + 2;";
        let nums: Vec<String> = lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text)
            .collect();
        assert_eq!(nums, vec!["0x1E", "2"]);
    }

    #[test]
    fn raw_identifiers_are_idents() {
        let src = "let r#match = r#move; s";
        let ids: Vec<String> = idents(src).into_iter().map(|p| p.0).collect();
        assert_eq!(ids, vec!["let", "r#match", "r#move", "s"]);
    }

    #[test]
    fn line_numbers_survive_multiline_tokens() {
        let src = "a\n/* one\ntwo */\nb \"x\ny\" c";
        let ids = idents(src);
        assert_eq!(ids, vec![("a".into(), 1), ("b".into(), 4), ("c".into(), 5)]);
    }

    /// Property test: interleave "sealed" snippets (comments, strings,
    /// raw strings, chars — all containing the decoy word) with real
    /// planted idents, in a random order, and check that exactly the
    /// planted idents come back out, each on its computed line.  This is
    /// the desync property the lints rely on: a lexer bug that lets any
    /// sealed context bleed changes the ident count or the line map.
    #[test]
    fn property_sealed_contexts_never_leak_idents() {
        // snippets whose DECOY occurrences must never surface as idents
        const SEALED: &[&str] = &[
            "// line DECOY comment\n",
            "/* block DECOY */",
            "/* outer /* DECOY nested */ still */",
            "/* multi\nline DECOY\ncomment */",
            "\"str DECOY lit\"",
            "\"esc \\\" DECOY\"",
            "\"multi\nline DECOY\"",
            "r\"raw DECOY\"",
            "r#\"raw # DECOY \" quote\"#",
            "r##\"deeper \"# DECOY\"##",
            "b\"byte DECOY\"",
            "br#\"rawbyte DECOY\"#",
            "'D'",
            "'\\''",
            "b'\\\\'",
        ];
        const FILLER: &[&str] = &["+", "{", "}", "(", ")", ";", ",", "= 42", "0..7", "1.5e-3", "&'a str"];
        let mut rng = Pcg32::new(0x5EED_1E3A);
        for _ in 0..200 {
            let mut src = String::new();
            let mut planted: Vec<(u32, u32)> = Vec::new(); // (ordinal, line)
            let mut next_ord = 0u32;
            let pieces = 3 + rng.next_bounded(30);
            for _ in 0..pieces {
                let line = 1 + src.matches('\n').count() as u32;
                match rng.next_bounded(4) {
                    0 => {
                        // plant a real ident the lexer must surface
                        src.push_str(&format!("DECOY{next_ord} "));
                        planted.push((next_ord, line));
                        next_ord += 1;
                    }
                    1 => {
                        let s = SEALED[rng.next_bounded(SEALED.len() as u32) as usize];
                        src.push_str(s);
                        src.push(' ');
                    }
                    _ => {
                        let s = FILLER[rng.next_bounded(FILLER.len() as u32) as usize];
                        src.push_str(s);
                        src.push(' ');
                    }
                }
                if rng.next_bounded(3) == 0 {
                    src.push('\n');
                }
            }
            let got: Vec<(u32, u32)> = lex(&src)
                .into_iter()
                .filter(|t| t.kind == TokKind::Ident && t.text.starts_with("DECOY"))
                .map(|t| {
                    let ord: u32 = t.text["DECOY".len()..].parse().unwrap_or(u32::MAX);
                    (ord, t.line)
                })
                .collect();
            assert_eq!(got, planted, "desync on source:\n{src}");
        }
    }
}
