//! Batch assembly: the CPU side of the paper's CPU/GPU coordination
//! (Section 4.1).
//!
//! The batcher performs *all* indirection on the CPU — subsampling,
//! sentence chunking, negative sampling — and hands the training step a
//! fixed-shape index batch.  The coordinator then gathers embedding rows
//! into contiguous buffers (the HBM-fetch analogue) and scatter-adds the
//! returned deltas (Hogwild-style, duplicates sum).
//!
//! `naive` contains the window-expansion batcher the baselines (Wombat /
//! accSGNS style) use, which Table 1 compares against.

pub mod naive;
pub mod pipeline;

use crate::config::TrainConfig;
use crate::corpus::subsample::Subsampler;
use crate::model::EmbeddingModel;
use crate::runtime::{StepInputs, StepOutputs};
use crate::sampler::unigram::UnigramTable;
use crate::util::rng::Pcg32;

/// Padding sentinel for unused word slots.
pub const PAD: u32 = u32::MAX;

/// A fixed-shape index batch matching one AOT executable's (B, S, N).
#[derive(Debug, Clone, PartialEq)]
pub struct IndexBatch {
    pub b: usize,
    pub s: usize,
    pub n: usize,
    /// Word ids, row-major [B, S]; `PAD` beyond each sentence's length.
    pub words: Vec<u32>,
    /// True sentence lengths [B].
    pub lens: Vec<i32>,
    /// Negative word ids, row-major [B, S, N]; arbitrary beyond length
    /// (the kernel masks windows past the sentence end).
    pub negs: Vec<u32>,
    /// Total real words in the batch.
    pub word_count: usize,
}

impl IndexBatch {
    pub fn empty(b: usize, s: usize, n: usize) -> Self {
        IndexBatch {
            b,
            s,
            n,
            words: vec![PAD; b * s],
            lens: vec![0; b],
            negs: vec![0; b * s * n],
            word_count: 0,
        }
    }

    /// Word id at (sentence, position).
    #[inline]
    pub fn word(&self, bi: usize, si: usize) -> u32 {
        self.words[bi * self.s + si]
    }

    /// Negative id at (sentence, position, k).
    #[inline]
    pub fn neg(&self, bi: usize, si: usize, k: usize) -> u32 {
        self.negs[(bi * self.s + si) * self.n + k]
    }

    /// Structural invariants (used by tests and debug assertions).
    pub fn check(&self, vocab_size: usize) -> Result<(), String> {
        if self.words.len() != self.b * self.s
            || self.lens.len() != self.b
            || self.negs.len() != self.b * self.s * self.n
        {
            return Err("buffer sizes inconsistent".into());
        }
        for bi in 0..self.b {
            let len = self.lens[bi] as usize;
            if len > self.s {
                return Err(format!("sentence {bi} length {len} > S"));
            }
            for si in 0..self.s {
                let w = self.word(bi, si);
                if si < len {
                    if w == PAD {
                        return Err(format!("PAD inside sentence {bi}@{si}"));
                    }
                    if (w as usize) >= vocab_size {
                        return Err(format!("word id {w} out of range"));
                    }
                    for k in 0..self.n {
                        let g = self.neg(bi, si, k);
                        if (g as usize) >= vocab_size {
                            return Err(format!("neg id {g} out of range"));
                        }
                    }
                } else if w != PAD {
                    return Err(format!("non-PAD past length {bi}@{si}"));
                }
            }
        }
        Ok(())
    }
}

/// Incremental batch builder: feed sentences, emit batches when full.
pub struct BatchBuilder {
    b: usize,
    s: usize,
    n: usize,
    subsampler: Subsampler,
    negatives: UnigramTable,
    rng: Pcg32,
    current: IndexBatch,
    fill: usize,
}

impl BatchBuilder {
    pub fn new(
        cfg: &TrainConfig,
        subsampler: Subsampler,
        negatives: UnigramTable,
        rng: Pcg32,
    ) -> Self {
        let (b, s, n) =
            (cfg.batch_sentences, cfg.sentence_chunk, cfg.negatives);
        BatchBuilder {
            b,
            s,
            n,
            subsampler,
            negatives,
            rng,
            current: IndexBatch::empty(b, s, n),
            fill: 0,
        }
    }

    /// Feed one sentence; returns completed batches (possibly several when
    /// a long sentence splits into many chunks).
    pub fn push_sentence(&mut self, sentence: &[u32]) -> Vec<IndexBatch> {
        let mut kept: Vec<u32> = sentence.to_vec();
        self.subsampler.filter(&mut kept, &mut self.rng);
        let mut done = Vec::new();
        for chunk in kept.chunks(self.s) {
            // single-word chunks generate no training pairs; skip them
            if chunk.len() < 2 {
                continue;
            }
            self.place_chunk(chunk);
            if self.fill == self.b {
                done.push(self.take_batch());
            }
        }
        done
    }

    fn place_chunk(&mut self, chunk: &[u32]) {
        let bi = self.fill;
        let base = bi * self.s;
        for (si, &w) in chunk.iter().enumerate() {
            self.current.words[base + si] = w;
            // per-window shared negatives, avoiding the center word
            let negbase = (base + si) * self.n;
            self.negatives.fill(
                &mut self.rng,
                w,
                &mut self.current.negs[negbase..negbase + self.n],
            );
        }
        self.current.lens[bi] = chunk.len() as i32;
        self.current.word_count += chunk.len();
        self.fill += 1;
    }

    fn take_batch(&mut self) -> IndexBatch {
        self.fill = 0;
        std::mem::replace(
            &mut self.current,
            IndexBatch::empty(self.b, self.s, self.n),
        )
    }

    /// Flush a final partial batch (remaining slots stay empty: len=0,
    /// which the kernel treats as a no-op).
    pub fn flush(&mut self) -> Option<IndexBatch> {
        if self.fill == 0 {
            None
        } else {
            Some(self.take_batch())
        }
    }
}

/// Gather embedding rows for a batch into step inputs.
/// Padded word slots gather row 0 — harmless since their deltas are zero.
pub fn gather(model: &EmbeddingModel, batch: &IndexBatch, inp: &mut StepInputs) {
    let d = model.dim;
    debug_assert_eq!(inp.syn0.len(), batch.b * batch.s * d);
    for bi in 0..batch.b {
        let len = batch.lens[bi] as usize;
        for si in 0..batch.s {
            let row = (bi * batch.s + si) * d;
            if si < len {
                let w = batch.word(bi, si);
                inp.syn0[row..row + d].copy_from_slice(model.syn0_row(w));
                inp.syn1[row..row + d].copy_from_slice(model.syn1_row(w));
                for k in 0..batch.n {
                    let g = batch.neg(bi, si, k);
                    let nrow = ((bi * batch.s + si) * batch.n + k) * d;
                    inp.neg[nrow..nrow + d]
                        .copy_from_slice(model.syn1_row(g));
                }
            } else {
                inp.syn0[row..row + d].fill(0.0);
                inp.syn1[row..row + d].fill(0.0);
                let nrow = (bi * batch.s + si) * batch.n * d;
                inp.neg[nrow..nrow + batch.n * d].fill(0.0);
            }
        }
        inp.lens[bi] = batch.lens[bi];
    }
}

/// Scatter-add step deltas back into the model (Hogwild-style: duplicate
/// rows within a batch simply sum, like unsynchronized threads would).
pub fn scatter(model: &mut EmbeddingModel, batch: &IndexBatch, out: &StepOutputs) {
    let d = model.dim;
    for bi in 0..batch.b {
        let len = batch.lens[bi] as usize;
        for si in 0..len {
            let row = (bi * batch.s + si) * d;
            let w = batch.word(bi, si);
            {
                let dst = model.syn0_row_mut(w);
                for (x, g) in dst.iter_mut().zip(&out.d_syn0[row..row + d]) {
                    *x += g;
                }
            }
            {
                let dst = model.syn1_row_mut(w);
                for (x, g) in dst.iter_mut().zip(&out.d_syn1[row..row + d]) {
                    *x += g;
                }
            }
            for k in 0..batch.n {
                let g_id = batch.neg(bi, si, k);
                let nrow = ((bi * batch.s + si) * batch.n + k) * d;
                let dst = model.syn1_row_mut(g_id);
                for (x, g) in dst.iter_mut().zip(&out.d_neg[nrow..nrow + d]) {
                    *x += g;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;

    fn vocab(n: usize) -> Vocab {
        Vocab::from_counts(
            (0..n).map(|i| (format!("w{i}"), (n - i) as u64 * 10)),
            1,
        )
    }

    fn cfg(b: usize, s: usize, n: usize) -> TrainConfig {
        TrainConfig {
            batch_sentences: b,
            sentence_chunk: s,
            negatives: n,
            subsample: 0.0,
            ..TrainConfig::default()
        }
    }

    fn builder(b: usize, s: usize, n: usize, v: &Vocab) -> BatchBuilder {
        let c = cfg(b, s, n);
        BatchBuilder::new(
            &c,
            Subsampler::new(v, 0.0),
            UnigramTable::new(v, 0.75),
            Pcg32::new(1),
        )
    }

    #[test]
    fn fills_batches_in_order() {
        let v = vocab(50);
        let mut bb = builder(2, 8, 3, &v);
        assert!(bb.push_sentence(&[1, 2, 3]).is_empty());
        let done = bb.push_sentence(&[4, 5, 6, 7]);
        assert_eq!(done.len(), 1);
        let batch = &done[0];
        batch.check(50).unwrap();
        assert_eq!(batch.lens, vec![3, 4]);
        assert_eq!(batch.word(0, 0), 1);
        assert_eq!(batch.word(1, 3), 7);
        assert_eq!(batch.word(0, 3), PAD);
        assert_eq!(batch.word_count, 7);
    }

    #[test]
    fn long_sentence_splits_into_chunks() {
        let v = vocab(50);
        let mut bb = builder(2, 4, 2, &v);
        let sent: Vec<u32> = (0..10).collect(); // 10 words, S=4 -> 4+4+2
        let done = bb.push_sentence(&sent);
        assert_eq!(done.len(), 1); // first two chunks fill batch of 2
        assert_eq!(done[0].lens, vec![4, 4]);
        let rest = bb.flush().unwrap();
        assert_eq!(rest.lens[0], 2);
        rest.check(50).unwrap();
    }

    #[test]
    fn single_word_chunks_skipped() {
        let v = vocab(50);
        let mut bb = builder(1, 8, 2, &v);
        assert!(bb.push_sentence(&[3]).is_empty());
        assert!(bb.flush().is_none());
    }

    #[test]
    fn negatives_avoid_center_and_in_range() {
        let v = vocab(20);
        let mut bb = builder(1, 8, 5, &v);
        let done = bb.push_sentence(&[1, 2, 3, 4, 5, 6]);
        let batch = done.into_iter().next().or_else(|| bb.flush()).unwrap();
        batch.check(20).unwrap();
        for si in 0..6 {
            let w = batch.word(0, si);
            for k in 0..5 {
                let g = batch.neg(0, si, k);
                assert_ne!(g, w);
                assert!((g as usize) < 20);
            }
        }
    }

    #[test]
    fn flush_emits_partial_batch_with_empty_slots() {
        let v = vocab(50);
        let mut bb = builder(4, 8, 2, &v);
        bb.push_sentence(&[1, 2, 3]);
        let batch = bb.flush().unwrap();
        assert_eq!(batch.lens, vec![3, 0, 0, 0]);
        batch.check(50).unwrap();
        assert!(bb.flush().is_none());
    }

    #[test]
    fn subsampling_reduces_word_count() {
        let v = vocab(10); // small vocab -> high frequencies -> aggressive
        let c = cfg(1, 32, 2);
        let mut bb = BatchBuilder::new(
            &c,
            Subsampler::new(&v, 1e-4),
            UnigramTable::new(&v, 0.75),
            Pcg32::new(7),
        );
        let sent: Vec<u32> = (0..10).cycle().take(32).collect();
        let mut total = 0;
        let mut batches = bb.push_sentence(&sent);
        if let Some(b) = bb.flush() {
            batches.push(b);
        }
        for b in &batches {
            total += b.word_count;
        }
        assert!(total < 32, "subsampling kept everything ({total})");
    }

    #[test]
    fn gather_scatter_roundtrip_consistency() {
        use crate::runtime::{ExecSpec, StepInputs, StepOutputs};
        let v = vocab(30);
        let mut model = EmbeddingModel::init(30, 4, 9);
        let snapshot = model.clone();
        let mut bb = builder(2, 6, 2, &v);
        let mut batches = bb.push_sentence(&[1, 2, 3, 4]);
        batches.extend(bb.push_sentence(&[5, 6, 7]));
        batches.extend(bb.flush());
        let batch = batches.into_iter().next().unwrap();
        let spec = ExecSpec {
            name: "t".into(),
            variant: "x".into(),
            file: "/dev/null".into(),
            b: 2,
            s: 6,
            d: 4,
            n: 2,
            wf: 2,
            inputs: vec![],
            outputs: vec![],
        };
        let mut inp = StepInputs::zeroed(&spec);
        gather(&model, &batch, &mut inp);
        // gathered rows match the model
        assert_eq!(&inp.syn0[0..4], model.syn0_row(1));
        assert_eq!(&inp.syn1[4..8], model.syn1_row(2));
        // zero deltas leave the model unchanged
        let out = StepOutputs {
            d_syn0: vec![0.0; 2 * 6 * 4],
            d_syn1: vec![0.0; 2 * 6 * 4],
            d_neg: vec![0.0; 2 * 6 * 2 * 4],
            loss: vec![0.0; 2],
        };
        scatter(&mut model, &batch, &out);
        assert_eq!(model.syn0, snapshot.syn0);
        assert_eq!(model.syn1, snapshot.syn1);
    }

    #[test]
    fn scatter_adds_duplicate_rows() {
        let v = vocab(10);
        let mut model = EmbeddingModel::init(10, 2, 1);
        let w5_before = model.syn0_row(5).to_vec();
        let mut bb = builder(1, 4, 1, &v);
        // duplicate word in one sentence; B=1 so the batch completes here
        let batch =
            bb.push_sentence(&[5, 5, 5]).into_iter().next().unwrap();
        let out = StepOutputs {
            d_syn0: vec![1.0; 4 * 2],
            d_syn1: vec![0.0; 4 * 2],
            d_neg: vec![0.0; 4 * 1 * 2],
            loss: vec![0.0; 1],
        };
        scatter(&mut model, &batch, &out);
        // three occurrences, each adding 1.0 -> +3 total
        for (x, x0) in model.syn0_row(5).iter().zip(&w5_before) {
            assert!((x - (x0 + 3.0)).abs() < 1e-6);
        }
    }
}
