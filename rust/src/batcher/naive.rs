//! The window-expansion batcher used by the baseline implementations.
//!
//! Wombat and accSGNS expand every context window into explicit
//! (center, context[], negatives[]) records before transfer (the paper's
//! Section 4.1 contrasts this with FULL-W2V, which ships only sentence
//! indices).  Expansion multiplies the per-word batching work by the
//! window size, which is exactly why their batching rates in Table 1 are
//! an order of magnitude lower.

use crate::corpus::subsample::Subsampler;
use crate::sampler::unigram::UnigramTable;
use crate::sampler::window::context_positions;
use crate::util::rng::Pcg32;

/// One fully-expanded training window (the baseline batch record).
#[derive(Debug, Clone)]
pub struct ExpandedWindow {
    pub center: u32,
    pub context: Vec<u32>,
    pub negatives: Vec<u32>,
}

/// Expand a sentence into per-window records, replicating context words
/// (the data-amplification the naive format pays).
pub fn expand_sentence(
    sentence: &[u32],
    wf: usize,
    n_neg: usize,
    subsampler: &Subsampler,
    negatives: &UnigramTable,
    rng: &mut Pcg32,
) -> Vec<ExpandedWindow> {
    let mut kept: Vec<u32> = sentence.to_vec();
    subsampler.filter(&mut kept, rng);
    let mut out = Vec::with_capacity(kept.len());
    for t in 0..kept.len() {
        let ctx = context_positions(t, wf, kept.len());
        if ctx.is_empty() {
            continue;
        }
        let mut negs = vec![0u32; n_neg];
        negatives.fill(rng, kept[t], &mut negs);
        out.push(ExpandedWindow {
            center: kept[t],
            context: ctx.iter().map(|&j| kept[j]).collect(),
            negatives: negs,
        });
    }
    out
}

/// Total ids materialized by the expansion (the traffic-amplification
/// metric Table 1's rate differences come from).
pub fn expanded_id_count(windows: &[ExpandedWindow]) -> usize {
    windows
        .iter()
        .map(|w| 1 + w.context.len() + w.negatives.len())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;

    fn fixtures() -> (Subsampler, UnigramTable) {
        let v = Vocab::from_counts(
            (0..30).map(|i| (format!("w{i}"), 10u64)),
            1,
        );
        (Subsampler::new(&v, 0.0), UnigramTable::new(&v, 0.75))
    }

    #[test]
    fn expansion_matches_window_geometry() {
        let (ss, ut) = fixtures();
        let mut rng = Pcg32::new(1);
        let sent: Vec<u32> = (0..8).collect();
        let ws = expand_sentence(&sent, 2, 3, &ss, &ut, &mut rng);
        assert_eq!(ws.len(), 8);
        assert_eq!(ws[0].center, 0);
        assert_eq!(ws[0].context, vec![1, 2]);
        assert_eq!(ws[4].context, vec![2, 3, 5, 6]);
        assert!(ws.iter().all(|w| w.negatives.len() == 3));
        assert!(ws.iter().all(|w| w.negatives.iter().all(|&g| g != w.center)));
    }

    #[test]
    fn amplification_factor_is_large() {
        let (ss, ut) = fixtures();
        let mut rng = Pcg32::new(2);
        let sent: Vec<u32> = (0..20).collect();
        let ws = expand_sentence(&sent, 3, 5, &ss, &ut, &mut rng);
        let ids = expanded_id_count(&ws);
        // naive format materializes ~(2Wf + N + 1) ids per word vs 1+N
        assert!(ids > 8 * sent.len(), "ids={ids}");
    }

    #[test]
    fn single_word_no_windows() {
        let (ss, ut) = fixtures();
        let mut rng = Pcg32::new(3);
        assert!(expand_sentence(&[5], 3, 2, &ss, &ut, &mut rng).is_empty());
    }
}
