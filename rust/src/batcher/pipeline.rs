//! Multi-stream batching pipeline: the thread/channel analogue of the
//! paper's CPU-threads + CUDA-streams coordination (Section 4.1, Figure 4).
//!
//! N batcher threads ("streams") each consume a strided shard of the
//! epoch's sentences, run subsampling + negative sampling + batch
//! assembly, and push completed [`IndexBatch`]es into one bounded channel.
//! The bound provides backpressure: when the trainer (the GPU analogue)
//! falls behind, batchers block instead of ballooning memory.  Batching
//! throughput is metered per stream — this is the quantity the paper's
//! Table 1 reports in Mwords/s.

use super::{BatchBuilder, IndexBatch};
use crate::config::{PipelineConfig, TrainConfig};
use crate::corpus::subsample::Subsampler;
use crate::sampler::unigram::UnigramTable;
use crate::util::rng::{Pcg32, SplitMix64};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Shared batching-throughput counters.
#[derive(Debug, Default)]
pub struct PipelineStats {
    /// Real words placed into batches.
    pub words: AtomicU64,
    /// Batches emitted.
    pub batches: AtomicU64,
    /// Nanoseconds the batcher threads spent busy (excludes channel
    /// blocking — Table 1 measures pure batching speed).
    pub busy_nanos: AtomicU64,
}

impl PipelineStats {
    /// Pure batching rate in words/sec.
    pub fn batching_rate(&self) -> f64 {
        let w = self.words.load(Ordering::Relaxed) as f64;
        let ns = self.busy_nanos.load(Ordering::Relaxed) as f64;
        if ns == 0.0 {
            0.0
        } else {
            w / (ns / 1e9)
        }
    }
}

/// A running pipeline: drain `rx`, then `join()`.
pub struct Pipeline {
    pub rx: Receiver<IndexBatch>,
    pub stats: Arc<PipelineStats>,
    handles: Vec<JoinHandle<()>>,
}

impl Pipeline {
    /// Launch batcher streams over an in-memory epoch of sentences.
    ///
    /// `epoch_seed` must differ across epochs so subsampling and negative
    /// draws are re-randomized (word2vec semantics).
    pub fn launch(
        sentences: Arc<Vec<Vec<u32>>>,
        train: &TrainConfig,
        pipe: &PipelineConfig,
        subsampler: &Subsampler,
        negatives: &UnigramTable,
        epoch_seed: u64,
    ) -> Pipeline {
        let streams = pipe.resolved_streams();
        let depth = pipe.queue_depth.max(1) * streams;
        let (tx, rx) = sync_channel::<IndexBatch>(depth);
        let stats = Arc::new(PipelineStats::default());
        let mut seeder = SplitMix64::new(epoch_seed ^ train.seed);
        let mut handles = Vec::with_capacity(streams);
        for stream_id in 0..streams {
            let tx = tx.clone();
            let sentences = sentences.clone();
            let stats = stats.clone();
            let mut builder = BatchBuilder::new(
                train,
                subsampler.clone(),
                negatives.clone(),
                Pcg32::with_stream(seeder.next_u64(), stream_id as u64),
            );
            handles.push(std::thread::spawn(move || {
                let mut local_words = 0u64;
                let mut local_batches = 0u64;
                let mut busy = 0u64;
                let send =
                    |batch: IndexBatch,
                     words: &mut u64,
                     batches: &mut u64|
                     -> bool {
                        *words += batch.word_count as u64;
                        *batches += 1;
                        tx.send(batch).is_ok()
                    };
                'outer: for sent in sentences
                    .iter()
                    .skip(stream_id)
                    .step_by(streams)
                {
                    let t0 = std::time::Instant::now();
                    let done = builder.push_sentence(sent);
                    busy += t0.elapsed().as_nanos() as u64;
                    for b in done {
                        if !send(b, &mut local_words, &mut local_batches) {
                            break 'outer; // receiver hung up
                        }
                    }
                }
                let t0 = std::time::Instant::now();
                let last = builder.flush();
                busy += t0.elapsed().as_nanos() as u64;
                if let Some(b) = last {
                    send(b, &mut local_words, &mut local_batches);
                }
                stats.words.fetch_add(local_words, Ordering::Relaxed);
                stats.batches.fetch_add(local_batches, Ordering::Relaxed);
                stats.busy_nanos.fetch_add(busy, Ordering::Relaxed);
            }));
        }
        drop(tx); // receiver sees EOF once all streams finish
        Pipeline { rx, stats, handles }
    }

    /// Join all batcher threads (call after draining `rx`).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;

    fn fixtures(v: usize) -> (Vocab, Subsampler, UnigramTable) {
        let vocab = Vocab::from_counts(
            (0..v).map(|i| (format!("w{i}"), 10u64)),
            1,
        );
        let ss = Subsampler::new(&vocab, 0.0);
        let ut = UnigramTable::new(&vocab, 0.75);
        (vocab, ss, ut)
    }

    fn sentences(n: usize, len: usize, vmax: u32) -> Arc<Vec<Vec<u32>>> {
        Arc::new(
            (0..n)
                .map(|i| {
                    (0..len).map(|j| ((i * 7 + j * 3) as u32) % vmax).collect()
                })
                .collect(),
        )
    }

    fn cfg(b: usize, streams: usize) -> (TrainConfig, PipelineConfig) {
        (
            TrainConfig {
                batch_sentences: b,
                sentence_chunk: 16,
                negatives: 3,
                subsample: 0.0,
                ..TrainConfig::default()
            },
            PipelineConfig { streams, queue_depth: 2 },
        )
    }

    #[test]
    fn all_words_arrive_exactly_once() {
        let (vocab, ss, ut) = fixtures(40);
        let sents = sentences(57, 9, 40);
        let want: usize = sents.iter().map(|s| s.len()).sum();
        let (tc, pc) = cfg(4, 3);
        let p = Pipeline::launch(sents, &tc, &pc, &ss, &ut, 1);
        let mut got = 0usize;
        let mut batches = 0usize;
        for b in p.rx.iter() {
            b.check(vocab.len()).unwrap();
            got += b.word_count;
            batches += 1;
        }
        p.join();
        assert_eq!(got, want);
        assert!(batches >= 57 / 4);
    }

    #[test]
    fn stats_are_accounted() {
        let (_, ss, ut) = fixtures(40);
        let sents = sentences(40, 9, 40);
        let (tc, pc) = cfg(4, 2);
        let p = Pipeline::launch(sents, &tc, &pc, &ss, &ut, 2);
        let stats = p.stats.clone();
        for _ in p.rx.iter() {}
        p.join();
        assert_eq!(stats.words.load(Ordering::Relaxed), 40 * 9);
        assert!(stats.batches.load(Ordering::Relaxed) > 0);
        assert!(stats.batching_rate() > 0.0);
    }

    #[test]
    fn receiver_drop_stops_streams() {
        let (_, ss, ut) = fixtures(40);
        let sents = sentences(5000, 9, 40);
        let (tc, pc) = cfg(1, 2); // queue_depth 2 -> blocks quickly
        let p = Pipeline::launch(sents, &tc, &pc, &ss, &ut, 3);
        // take a few batches, then hang up
        let mut it = p.rx.iter();
        for _ in 0..3 {
            it.next().unwrap();
        }
        drop(it);
        drop(p.rx);
        // streams must exit instead of deadlocking
        for h in p.handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn epoch_seed_changes_negatives() {
        let (_, ss, ut) = fixtures(40);
        let sents = sentences(8, 9, 40);
        let (tc, pc) = cfg(2, 1);
        let collect = |seed: u64| -> Vec<IndexBatch> {
            let p = Pipeline::launch(
                sents.clone(),
                &tc,
                &pc,
                &ss,
                &ut,
                seed,
            );
            let v: Vec<_> = p.rx.iter().collect();
            p.join();
            v
        };
        let a = collect(1);
        let b = collect(1);
        let c = collect(2);
        assert_eq!(a.len(), b.len());
        // determinism for equal seeds
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // different negatives for different epoch seeds
        assert!(a.iter().zip(&c).any(|(x, y)| x.negs != y.negs));
        // but the same words/lens (subsampling off)
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.words, y.words);
        }
    }
}
