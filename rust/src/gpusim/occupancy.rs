//! Occupancy calculator: kernel resource usage -> resident warps
//! (Table 6's Max/Active/Eligible Warps per scheduler).

use super::arch::ArchSpec;
use crate::memmodel::Variant;

/// Per-kernel resource profile.  Derived from each implementation's
/// published decomposition: threads/block = embedding dim for the
/// vector-parallel kernels (d=128 -> 4 warps), Wombat uses small fixed
/// word-pair blocks; register and shared usage follow each algorithm's
/// caching strategy (Sections 2.2.2, 3, 4.2).
#[derive(Debug, Clone)]
pub struct KernelProfile {
    pub variant: Variant,
    pub threads_per_block: usize,
    /// 32-bit registers per thread.
    pub regs_per_thread: usize,
    /// Shared memory per block, bytes.
    pub shared_per_block: usize,
    /// Fraction of resident warps that hold work on average (kernels with
    /// per-window block synchronization or tail effects idle some warps);
    /// calibrated to Table 6's Active/Max ratios.
    pub activity: f64,
}

impl KernelProfile {
    pub fn for_variant(v: Variant) -> Self {
        match v {
            // d=128 threads; S x d f32 ring buffer in shared (16 KB at
            // S=32,d=128); the negative cache costs ~1 register/thread
            // (each thread holds one lane of the (N+1) x d block).
            Variant::FullW2v => KernelProfile {
                variant: v,
                threads_per_block: 128,
                regs_per_thread: 40,
                shared_per_block: 32 * 128 * 4,
                activity: 0.82,
            },
            // same negative registers without the ring buffer; fits the
            // full 64-warp budget (Table 6: max warps 16 per scheduler).
            Variant::FullRegister => KernelProfile {
                variant: v,
                threads_per_block: 128,
                regs_per_thread: 32,
                shared_per_block: 0,
                activity: 0.97,
            },
            // CPU-style port: plain vector threads, minimal state.
            Variant::AccSgns => KernelProfile {
                variant: v,
                threads_per_block: 128,
                regs_per_thread: 32,
                shared_per_block: 0,
                activity: 0.85,
            },
            // small word-pair blocks + per-window staging buffers; block
            // granularity leaves schedulers under-fed (paper: scheduling
            // limitations hold Wombat back on newer architectures).
            Variant::Wombat => KernelProfile {
                variant: v,
                threads_per_block: 32,
                regs_per_thread: 48,
                shared_per_block: (2 * 3 + 6) * 128 * 4,
                activity: 0.42,
            },
        }
    }
}

/// Occupancy outcome (per warp scheduler, matching Table 6's unit).
#[derive(Debug, Clone)]
pub struct OccupancyReport {
    /// Resident blocks per SM after all limits.
    pub blocks_per_sm: usize,
    /// Which resource bound: "registers" | "shared" | "blocks" | "warps".
    pub limiter: &'static str,
    /// Max resident warps per scheduler.
    pub max_warps: f64,
    /// Average warps making progress per scheduler.
    pub active_warps: f64,
    /// Occupancy vs the architecture max (0..1).
    pub occupancy_frac: f64,
}

/// Hardware block-per-SM cap (all three paper architectures).
const MAX_BLOCKS_PER_SM: usize = 32;

pub fn occupancy(prof: &KernelProfile, arch: &ArchSpec) -> OccupancyReport {
    let warps_per_block = prof.threads_per_block.div_ceil(32);
    let max_warps_sm =
        arch.max_warps_per_scheduler * arch.warp_schedulers;

    let by_regs = if prof.regs_per_thread == 0 {
        MAX_BLOCKS_PER_SM
    } else {
        arch.regs_per_sm / (prof.regs_per_thread * prof.threads_per_block)
    };
    let by_shared = if prof.shared_per_block == 0 {
        MAX_BLOCKS_PER_SM
    } else {
        arch.shared_per_sm / prof.shared_per_block
    };
    let by_warps = max_warps_sm / warps_per_block;

    let mut blocks = by_regs.min(by_shared).min(by_warps).min(MAX_BLOCKS_PER_SM);
    blocks = blocks.max(1);
    let limiter = if blocks == by_shared && by_shared <= by_regs && by_shared <= by_warps {
        "shared"
    } else if blocks == by_regs && by_regs <= by_warps {
        "registers"
    } else if blocks == by_warps {
        "warps"
    } else {
        "blocks"
    };

    let warps_sm = (blocks * warps_per_block).min(max_warps_sm);
    let max_warps = warps_sm as f64 / arch.warp_schedulers as f64;
    let active_warps = max_warps * prof.activity;
    OccupancyReport {
        blocks_per_sm: blocks,
        limiter,
        max_warps,
        active_warps,
        occupancy_frac: warps_sm as f64 / max_warps_sm as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_register_reaches_peak_occupancy() {
        // Table 6: FULL-Register max warps 16 (per scheduler) on both archs
        for arch in [ArchSpec::v100(), ArchSpec::titan_xp()] {
            let occ = occupancy(
                &KernelProfile::for_variant(Variant::FullRegister),
                &arch,
            );
            assert!(
                occ.max_warps >= 12.0,
                "{}: {}",
                arch.name,
                occ.max_warps
            );
            assert!(occ.active_warps > 0.9 * occ.max_warps);
        }
    }

    #[test]
    fn full_w2v_trades_occupancy_for_shared() {
        // Table 6: FULL-W2V max warps 13 (XP) / 9 (V100) — shared-memory
        // bound, below FULL-Register but with high eligibility.
        let v100 = occupancy(
            &KernelProfile::for_variant(Variant::FullW2v),
            &ArchSpec::v100(),
        );
        let reg_v100 = occupancy(
            &KernelProfile::for_variant(Variant::FullRegister),
            &ArchSpec::v100(),
        );
        assert!(v100.max_warps < reg_v100.max_warps);
        assert_eq!(v100.limiter, "shared");
        assert!((4.0..14.0).contains(&v100.max_warps), "{}", v100.max_warps);
    }

    #[test]
    fn wombat_scheduler_starved() {
        // Table 6: Wombat active warps ~4.6 of max ~11 on both archs
        for arch in [ArchSpec::v100(), ArchSpec::titan_xp()] {
            let occ =
                occupancy(&KernelProfile::for_variant(Variant::Wombat), &arch);
            let acc = occupancy(
                &KernelProfile::for_variant(Variant::AccSgns),
                &arch,
            );
            assert!(
                occ.active_warps < 0.6 * acc.active_warps,
                "{}: wombat {} vs acc {}",
                arch.name,
                occ.active_warps,
                acc.active_warps
            );
        }
    }

    #[test]
    fn blocks_at_least_one() {
        // degenerate: gigantic shared request still yields 1 block
        let prof = KernelProfile {
            variant: Variant::FullW2v,
            threads_per_block: 1024,
            regs_per_thread: 255,
            shared_per_block: 1 << 20,
            activity: 1.0,
        };
        let occ = occupancy(&prof, &ArchSpec::p100());
        assert_eq!(occ.blocks_per_sm, 1);
    }
}
