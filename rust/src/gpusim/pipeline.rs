//! Issue/stall pipeline model: per-window instruction + memory-latency
//! accounting -> IPC, stall breakdown (Table 5) and projected throughput
//! (Figures 6/7).
//!
//! Model: each thread block trains one sentence; windows are strictly
//! sequential inside a block (the algorithm's data dependence), so a
//! block's warps alternate between issuing `I` instruction cycles and
//! stalling `S` memory-latency cycles per window.  A scheduler with `A`
//! active warps achieves issue utilization `min(1, A * I/(I+S))` —
//! latency is hidden only if enough other warps have work (Section 2.3's
//! resource-tradeoff discussion).  End-to-end time is the bottleneck of
//! issue throughput, exposed latency, and DRAM bandwidth.

use super::arch::ArchSpec;
use super::occupancy::OccupancyReport;
use crate::memmodel::{access_profile, flops_per_window, traffic, Variant, Workload};

/// Simulated execution metrics for one (variant, arch).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub variant: Variant,
    /// Instructions per cycle per SM (Table 5's IPC row).
    pub ipc: f64,
    /// Stall breakdown as % of warp residency time (Table 5 rows).
    pub long_scoreboard_pct: f64,
    pub short_scoreboard_pct: f64,
    pub arithmetic_pct: f64,
    pub overhead_pct: f64,
    /// Eligible warps per scheduler per cycle (Table 6 row).
    pub eligible_warps: f64,
    /// Projected end-to-end training throughput.
    pub words_per_sec: f64,
    /// Projected achieved GFLOP/s (Figure 1's y-axis).
    pub achieved_gflops: f64,
    /// Which resource bounds the projection: "issue" | "bandwidth".
    pub bound: &'static str,
}

/// Per-window synchronization overhead cycles (block-wide barrier each
/// window slide; Wombat pays per word pairing — calibrated to Table 5's
/// Overhead row ordering).
fn sync_overhead_cycles(v: Variant, wf: usize, n: usize) -> f64 {
    match v {
        Variant::FullW2v => 30.0,
        Variant::FullRegister => 40.0,
        Variant::AccSgns => 30.0 + 4.0 * (n as f64 + 1.0),
        // block-wide barrier after every pair's shared-memory reduction
        Variant::Wombat => 30.0 * (2.0 * wf as f64) * (n as f64 + 1.0),
    }
}

/// Fraction of raw memory latency actually *exposed* as long/short
/// scoreboard stalls.  The window-matrix decomposition issues the (N+1)
/// output-row loads independently (Section 3.1's negative-sample
/// independence), overlapping almost all of the latency; the per-pair
/// forms serialize load -> dot -> update chains and eat it.
fn latency_exposure(v: Variant) -> f64 {
    match v {
        Variant::FullW2v => 0.15,
        Variant::FullRegister => 0.60,
        Variant::AccSgns => 0.90,
        Variant::Wombat => 0.60,
    }
}

/// How well a variant can feed additional warp schedulers.  Wombat's
/// small fixed word-pair blocks cannot generate enough concurrent work
/// per SM, so extra schedulers on newer parts go idle (the paper's
/// "scheduling limitations ... hold back performance on newer
/// architectures", Section 2.2.2).
fn scheduler_feed(v: Variant, schedulers: usize) -> f64 {
    match v {
        Variant::Wombat => (2.0 / schedulers as f64).min(1.0),
        _ => 1.0,
    }
}

/// Instruction-stream expansion over raw FMA count: address arithmetic,
/// predication/masking, loop control, reduction shuffles.  Small-tile SGNS
/// kernels are instruction-bound, and the per-pair decompositions pay far
/// more bookkeeping per useful FLOP (calibrated to the paper's measured
/// throughput ratios, Figure 6).
fn inst_expansion(v: Variant) -> f64 {
    match v {
        Variant::FullW2v => 8.0,       // dense window-matrix tiles
        Variant::FullRegister => 9.0,  // + per-window re-gather addressing
        Variant::AccSgns => 18.0,      // per-pair scalar dot/axpy chains
        Variant::Wombat => 12.0,       // per-pair matvec on tiny blocks
    }
}

/// Fraction of issue slots a single warp can actually fill, limited by
/// intra-thread dependency chains (dot -> sigmoid -> axpy is serial in the
/// per-pair kernels; the window-matrix form exposes independent columns —
/// the paper's "independence of negative samples", Section 3.1).
fn ilp_efficiency(v: Variant) -> f64 {
    match v {
        Variant::FullW2v => 0.90,
        Variant::FullRegister => 0.80,
        Variant::AccSgns => 0.35,
        Variant::Wombat => 0.50,
    }
}

pub fn simulate(
    v: Variant,
    w: &Workload,
    arch: &ArchSpec,
    occ: &OccupancyReport,
) -> SimReport {
    let prof = access_profile(v, w);
    let warps_per_block = match v {
        Variant::Wombat => 1.0,
        _ => (w.d as f64 / 32.0).max(1.0),
    };
    let windows = w.words_per_epoch as f64;
    let rb = w.row_bytes();

    // --- per-window, per-warp issue work -----------------------------
    // FMA instructions: flops / 2 per lane, 32 lanes per warp, split
    // across the block's warps, expanded by the variant's bookkeeping
    // overhead (address math, masking, reductions).
    let inst_fma = flops_per_window(w) / 2.0 / 32.0 / warps_per_block
        * inst_expansion(v);
    // memory instructions: one 32-lane transaction per 32 floats of a row
    let inst_mem =
        prof.l1_rows * (w.d as f64 / 32.0) / warps_per_block;
    let inst_total = inst_fma + inst_mem;

    // --- per-window memory stalls (cycles a block's warps wait) ------
    // DRAM rows per window come from the reuse model (traffic()), which
    // already includes the variant's L2-contention share.
    let tr = traffic(v, w, arch.l2_bytes);
    let dram_rows_pw = tr.dram_gb * 1e9 / (windows * rb);
    // memory-level parallelism: outstanding requests overlap within the
    // block, bounded by its warps
    let mlp = warps_per_block.min(4.0);
    let expose = latency_exposure(v);
    let stall_l1 =
        inst_mem * arch.lat_l1 / 8.0 / mlp * expose; // L1 mostly pipelined
    let stall_l2 = prof.l2_rows * (w.d as f64 / 32.0) / warps_per_block
        * arch.lat_l2
        / 8.0
        / mlp
        * expose;
    let stall_dram = dram_rows_pw * (w.d as f64 / 32.0) / warps_per_block
        * arch.lat_dram
        / mlp
        * expose;
    let sync = sync_overhead_cycles(v, w.wf, w.n);
    let stall_total = stall_l1 + stall_l2 + stall_dram + sync;

    // --- scheduler utilization ---------------------------------------
    let duty = inst_total / (inst_total + stall_total);
    let a = occ.active_warps.max(0.1)
        * scheduler_feed(v, arch.warp_schedulers);
    let issue_util = (a * duty).min(1.0) * ilp_efficiency(v);
    // steady state: of the warps with issuable work, one issues per cycle
    let eligible = (a * duty * ilp_efficiency(v) - issue_util).max(0.05);
    let ipc = arch.warp_schedulers as f64 * issue_util;

    // --- end-to-end projection ---------------------------------------
    let total_warp_insts = windows * inst_total * warps_per_block;
    let issue_capacity =
        arch.sms as f64 * arch.warp_schedulers as f64 * issue_util;
    let t_issue =
        total_warp_insts / issue_capacity / (arch.clock_ghz * 1e9);
    let t_bw = tr.dram_gb * 1e9 / (arch.mem_bw_gbs * 1e9);
    let t_compute = tr.flops / (arch.peak_tflops * 1e12);
    let (mut t, mut bound) = if t_issue >= t_bw {
        (t_issue, "issue")
    } else {
        (t_bw, "bandwidth")
    };
    if t_compute > t {
        t = t_compute;
        bound = "compute";
    }
    let words_per_sec = w.words_per_epoch as f64 / t;
    let achieved_gflops = tr.flops / t / 1e9;

    // --- stall breakdown (% of warp residency) -----------------------
    let denom = inst_total + stall_total;
    SimReport {
        variant: v,
        ipc,
        long_scoreboard_pct: 100.0 * stall_dram / denom,
        short_scoreboard_pct: 100.0 * (stall_l1 + stall_l2) / denom,
        arithmetic_pct: 100.0 * inst_fma / denom * 0.02,
        overhead_pct: 100.0 * sync / denom,
        eligible_warps: eligible,
        words_per_sec,
        achieved_gflops,
        bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::occupancy::{occupancy, KernelProfile};

    fn sim(v: Variant, arch: &ArchSpec) -> SimReport {
        let w = Workload::text8_paper();
        let occ = occupancy(&KernelProfile::for_variant(v), arch);
        simulate(v, &w, arch, &occ)
    }

    #[test]
    fn table5_ipc_ordering() {
        // FULL-W2V > FULL-Register on both archs; V100 > XP for FULL-W2V
        let v100 = ArchSpec::v100();
        let xp = ArchSpec::titan_xp();
        assert!(
            sim(Variant::FullW2v, &v100).ipc
                > sim(Variant::FullRegister, &v100).ipc
        );
        assert!(
            sim(Variant::FullW2v, &xp).ipc
                > sim(Variant::FullRegister, &xp).ipc
        );
        assert!(
            sim(Variant::FullW2v, &v100).ipc > sim(Variant::FullW2v, &xp).ipc
        );
        // IPC can't exceed scheduler count
        assert!(sim(Variant::FullW2v, &v100).ipc <= 4.0);
    }

    #[test]
    fn table5_long_scoreboard_nearly_eliminated() {
        // the paper's key per-thread result: lifetime context reuse
        // nearly eliminates long-scoreboard (DRAM) stalls
        for arch in [ArchSpec::v100(), ArchSpec::titan_xp()] {
            let full = sim(Variant::FullW2v, &arch);
            let reg = sim(Variant::FullRegister, &arch);
            assert!(
                full.long_scoreboard_pct < 0.4 * reg.long_scoreboard_pct,
                "{}: {} vs {}",
                arch.name,
                full.long_scoreboard_pct,
                reg.long_scoreboard_pct
            );
            assert!(full.long_scoreboard_pct < 8.0);
        }
    }

    #[test]
    fn table6_eligible_warps_band() {
        // near-1+ eligible warps per scheduler for the FULL kernels
        let v100 = ArchSpec::v100();
        let full = sim(Variant::FullW2v, &v100);
        assert!(
            (0.5..4.0).contains(&full.eligible_warps),
            "{}",
            full.eligible_warps
        );
        // wombat's eligibility collapses (paper: 0.18)
        let wombat = sim(Variant::Wombat, &v100);
        assert!(wombat.eligible_warps < 0.6, "{}", wombat.eligible_warps);
    }

    #[test]
    fn achieved_gflops_below_roofline() {
        let w = Workload::text8_paper();
        for arch in ArchSpec::all() {
            for &v in &Variant::ALL {
                let occ = occupancy(&KernelProfile::for_variant(v), &arch);
                let s = simulate(v, &w, &arch, &occ);
                let tr = traffic(v, &w, arch.l2_bytes);
                let cap = arch.roofline_gflops(tr.arithmetic_intensity);
                assert!(
                    s.achieved_gflops <= cap * 1.001,
                    "{} {} exceeds roofline: {} > {}",
                    arch.name,
                    v.name(),
                    s.achieved_gflops,
                    cap
                );
            }
        }
    }

    #[test]
    fn wombat_overhead_dominates() {
        let v100 = ArchSpec::v100();
        let wombat = sim(Variant::Wombat, &v100);
        let full = sim(Variant::FullW2v, &v100);
        assert!(wombat.overhead_pct > full.overhead_pct);
    }
}
