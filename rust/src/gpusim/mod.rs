//! GPU execution model (the hardware-counter substitute).
//!
//! The paper's Tables 5/6 and its cross-architecture scaling claims come
//! from nsight on V100/TitanXP/P100 hardware we do not have.  This module
//! models the three GPUs from the paper's own Table 2 specs and each
//! implementation's kernel resource profile, producing:
//!
//! * an **occupancy calculator** (registers/shared-memory/block-size
//!   limits → max & active warps per scheduler — Table 6);
//! * an **issue/stall pipeline model** (instruction mix + per-level
//!   memory traffic from [`crate::memmodel`] → IPC and the stall
//!   breakdown — Table 5);
//! * a **throughput projection** (bottleneck of issue rate vs exposed
//!   memory latency vs DRAM bandwidth → words/sec per architecture —
//!   Figures 6/7's cross-architecture shape, including the paper's
//!   P100→V100 ~2.97x scaling for FULL-W2V).
//!
//! Constants marked "calibrated" are fit to the paper's measured tables;
//! everything else is first-principles from Table 2.

pub mod arch;
pub mod occupancy;
pub mod pipeline;

pub use arch::{ArchSpec, Roofline};
pub use occupancy::{occupancy, KernelProfile, OccupancyReport};
pub use pipeline::{simulate, SimReport};

use crate::memmodel::{Variant, Workload};

/// Full per-(arch, variant) projection used by benches and examples.
#[derive(Debug, Clone)]
pub struct Projection {
    pub arch: String,
    pub variant: Variant,
    pub occupancy: OccupancyReport,
    pub sim: SimReport,
}

/// Project every variant on every paper architecture.
pub fn project_all(w: &Workload) -> Vec<Projection> {
    let mut out = Vec::new();
    for a in [ArchSpec::v100(), ArchSpec::titan_xp(), ArchSpec::p100()] {
        for &v in &Variant::ALL {
            let prof = KernelProfile::for_variant(v);
            let occ = occupancy(&prof, &a);
            let sim = simulate(v, w, &a, &occ);
            out.push(Projection {
                arch: a.name.to_string(),
                variant: v,
                occupancy: occ,
                sim,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find<'a>(
        ps: &'a [Projection],
        arch: &str,
        v: Variant,
    ) -> &'a Projection {
        ps.iter()
            .find(|p| p.arch == arch && p.variant == v)
            .unwrap()
    }

    #[test]
    fn figure6_ordering_on_every_arch() {
        let ps = project_all(&Workload::text8_paper());
        for arch in ["V100", "TitanXP", "P100"] {
            let wps = |v| find(&ps, arch, v).sim.words_per_sec;
            assert!(
                wps(Variant::FullW2v) > wps(Variant::FullRegister),
                "{arch}: full_w2v vs full_register"
            );
            assert!(
                wps(Variant::FullRegister) > wps(Variant::AccSgns),
                "{arch}: full_register vs accSGNS"
            );
            assert!(
                wps(Variant::FullRegister) > wps(Variant::Wombat),
                "{arch}: full_register vs wombat"
            );
        }
        // Figure 6's baseline crossover: Wombat leads accSGNS on the
        // Pascal parts but falls behind on Volta (paper Section 5.2:
        // FULL-W2V is 5.9x over Wombat vs 6.8x over accSGNS on P100, but
        // 8.6x over Wombat vs 5.7x over accSGNS on V100).
        let wps = |arch: &str, v| find(&ps, arch, v).sim.words_per_sec;
        assert!(
            wps("P100", Variant::Wombat) > wps("P100", Variant::AccSgns)
        );
        assert!(
            wps("V100", Variant::AccSgns) > wps("V100", Variant::Wombat)
        );
    }

    #[test]
    fn headline_speedups_in_band() {
        // paper V100: FULL-W2V 5.72x over accSGNS, 8.65x over Wombat
        let ps = project_all(&Workload::text8_paper());
        let wps = |v| find(&ps, "V100", v).sim.words_per_sec;
        let vs_acc = wps(Variant::FullW2v) / wps(Variant::AccSgns);
        let vs_wombat = wps(Variant::FullW2v) / wps(Variant::Wombat);
        assert!(
            (3.0..12.0).contains(&vs_acc),
            "speedup vs accSGNS {vs_acc}"
        );
        assert!(
            (4.0..16.0).contains(&vs_wombat),
            "speedup vs Wombat {vs_wombat}"
        );
        assert!(vs_wombat > vs_acc, "paper: Wombat slower than accSGNS on V100");
    }

    #[test]
    fn cross_architecture_scaling() {
        // paper: FULL-W2V gains ~2.97x from P100 to V100, while prior work
        // scales worse (that is the headline scalability claim)
        let ps = project_all(&Workload::text8_paper());
        let scale = |v: Variant| {
            find(&ps, "V100", v).sim.words_per_sec
                / find(&ps, "P100", v).sim.words_per_sec
        };
        let s_full = scale(Variant::FullW2v);
        assert!((1.8..4.5).contains(&s_full), "P100->V100 scaling {s_full}");
        assert!(
            s_full > scale(Variant::Wombat),
            "FULL-W2V must scale better than Wombat"
        );
    }
}
