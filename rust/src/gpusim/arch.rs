//! Architecture descriptors: the paper's Table 2 platforms, plus the
//! device-agnostic [`Roofline`] model they (and the CPU model in
//! `memmodel::cpu`) share.

/// A roofline (Figure 1): peak compute rate plus memory bandwidth,
/// which together bound attainable FLOP/s at any arithmetic intensity.
/// Shared by the GPU [`ArchSpec`]s and the CPU spec in
/// `crate::memmodel::cpu`, so kernels on either side are judged by the
/// same curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    pub peak_gflops: f64,
    pub mem_bw_gbs: f64,
}

impl Roofline {
    /// Knee: FLOP/byte where compute- and memory-bound meet (Figure
    /// 1's dotted line).
    pub fn knee(&self) -> f64 {
        self.peak_gflops / self.mem_bw_gbs
    }

    /// Attainable GFLOP/s at a given arithmetic intensity (Figure 1's
    /// solid roofline boundary).
    pub fn attainable_gflops(&self, ai: f64) -> f64 {
        self.peak_gflops.min(ai * self.mem_bw_gbs)
    }
}

/// One GPU architecture's modeling parameters.  Specs not in Table 2
/// (latencies, L2 size, register file) use the vendor's published values.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: &'static str,
    pub generation: &'static str,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Peak single-precision TFLOP/s.
    pub peak_tflops: f64,
    /// Device memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Warp schedulers per SM (paper Table 2).
    pub warp_schedulers: usize,
    /// Max resident warps per scheduler (paper Table 6 note: 16).
    pub max_warps_per_scheduler: usize,
    /// Shared memory per SM, bytes.
    pub shared_per_sm: usize,
    /// 32-bit registers per SM.
    pub regs_per_sm: usize,
    /// L2 cache, bytes.
    pub l2_bytes: f64,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// Average latencies in cycles (vendor microbenchmark literature).
    pub lat_l1: f64,
    pub lat_l2: f64,
    pub lat_dram: f64,
}

impl ArchSpec {
    /// Nvidia V100 (Gen-6 Volta): 80 SMs, 14 TFLOP/s, 900 GB/s, 4 sched.
    pub fn v100() -> Self {
        ArchSpec {
            name: "V100",
            generation: "Volta",
            sms: 80,
            peak_tflops: 14.0,
            mem_bw_gbs: 900.0,
            warp_schedulers: 4,
            max_warps_per_scheduler: 16,
            shared_per_sm: 96 * 1024,
            regs_per_sm: 65536,
            l2_bytes: 6.0 * 1024.0 * 1024.0,
            clock_ghz: 1.53,
            lat_l1: 28.0,
            lat_l2: 193.0,
            lat_dram: 400.0,
        }
    }

    /// Nvidia Titan XP (Gen-5 Pascal): 60 SMs, 12.15 TFLOP/s, 548 GB/s.
    pub fn titan_xp() -> Self {
        ArchSpec {
            name: "TitanXP",
            generation: "Pascal",
            sms: 60,
            peak_tflops: 12.15,
            mem_bw_gbs: 548.0,
            warp_schedulers: 2,
            max_warps_per_scheduler: 16,
            shared_per_sm: 96 * 1024,
            regs_per_sm: 65536,
            l2_bytes: 3.0 * 1024.0 * 1024.0,
            clock_ghz: 1.58,
            lat_l1: 82.0,
            lat_l2: 216.0,
            lat_dram: 440.0,
        }
    }

    /// Nvidia P100 (Gen-5 Pascal): 56 SMs, 9.3 TFLOP/s, 549 GB/s HBM2.
    pub fn p100() -> Self {
        ArchSpec {
            name: "P100",
            generation: "Pascal",
            sms: 56,
            peak_tflops: 9.3,
            mem_bw_gbs: 549.0,
            warp_schedulers: 2,
            max_warps_per_scheduler: 16,
            shared_per_sm: 64 * 1024,
            regs_per_sm: 65536,
            l2_bytes: 4.0 * 1024.0 * 1024.0,
            clock_ghz: 1.33,
            lat_l1: 82.0,
            lat_l2: 234.0,
            lat_dram: 500.0,
        }
    }

    pub fn all() -> Vec<ArchSpec> {
        vec![Self::v100(), Self::titan_xp(), Self::p100()]
    }

    /// This device's roofline curve.
    pub fn roofline(&self) -> Roofline {
        Roofline {
            peak_gflops: self.peak_tflops * 1e3,
            mem_bw_gbs: self.mem_bw_gbs,
        }
    }

    /// Roofline knee: FLOP/byte where compute- and memory-bound meet
    /// (Figure 1's dotted line).
    pub fn roofline_knee(&self) -> f64 {
        self.roofline().knee()
    }

    /// Attainable GFLOP/s at a given arithmetic intensity (Figure 1's
    /// solid roofline boundary).
    pub fn roofline_gflops(&self, ai: f64) -> f64 {
        self.roofline().attainable_gflops(ai)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        let v = ArchSpec::v100();
        assert_eq!(v.sms, 80);
        assert_eq!(v.warp_schedulers, 4);
        assert_eq!(v.peak_tflops, 14.0);
        let xp = ArchSpec::titan_xp();
        assert_eq!(xp.sms, 60);
        assert_eq!(xp.warp_schedulers, 2);
        let p = ArchSpec::p100();
        assert_eq!(p.sms, 56);
        assert_eq!(p.mem_bw_gbs, 549.0);
    }

    #[test]
    fn roofline_math() {
        let v = ArchSpec::v100();
        // knee = 14e12 / 900e9 ≈ 15.6 flop/byte
        assert!((v.roofline_knee() - 15.555).abs() < 0.1);
        // memory-bound region scales with AI
        assert!((v.roofline_gflops(1.0) - 900.0).abs() < 1.0);
        // compute-bound region flat at peak
        assert!((v.roofline_gflops(100.0) - 14_000.0).abs() < 1.0);
    }

    #[test]
    fn shared_roofline_struct_matches_legacy_methods() {
        for a in ArchSpec::all() {
            let r = a.roofline();
            assert_eq!(r.knee(), a.roofline_knee(), "{}", a.name);
            for ai in [0.05, 0.25, 2.0, 8.0, 100.0] {
                assert_eq!(
                    r.attainable_gflops(ai),
                    a.roofline_gflops(ai),
                    "{} ai={ai}",
                    a.name
                );
            }
        }
    }

    #[test]
    fn newer_arch_strictly_better() {
        let v = ArchSpec::v100();
        let p = ArchSpec::p100();
        assert!(v.sms > p.sms);
        assert!(v.peak_tflops > p.peak_tflops);
        assert!(v.mem_bw_gbs > p.mem_bw_gbs);
        assert!(v.warp_schedulers > p.warp_schedulers);
        assert!(v.lat_dram < p.lat_dram);
    }
}
