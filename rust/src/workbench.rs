//! Shared scaffolding for examples, benches and the CLI: synthetic-corpus
//! preparation and trainer construction, so every entry point exercises
//! the identical public pipeline (generator -> text -> reader -> vocab ->
//! ids) a real corpus file would take.

use crate::config::{Config, TrainConfig};
use crate::coordinator::{Coordinator, SgnsTrainer};
use crate::corpus::reader::{read_all, ReaderOptions};
use crate::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};
use crate::corpus::vocab::Vocab;
use crate::corpus::CorpusStats;
use anyhow::Result;
use std::sync::Arc;

/// A corpus prepared for training: vocab + id sentences + gold sets.
pub struct Workbench {
    pub corpus: SyntheticCorpus,
    pub vocab: Vocab,
    pub sentences: Arc<Vec<Vec<u32>>>,
    pub total_words: u64,
}

impl Workbench {
    /// Generate a synthetic corpus and push it through the *real* text
    /// pipeline (render to text, tokenize, vocab with min_count, encode).
    pub fn prepare(spec: SyntheticSpec, min_count: usize) -> Self {
        let corpus = SyntheticCorpus::generate(spec);
        let text = corpus.to_text();
        let vocab = Vocab::build(text.split_whitespace(), min_count);
        let (sentences, _raw) =
            read_all(text.as_bytes(), &vocab, ReaderOptions::default());
        let total_words: u64 = sentences.iter().map(|s| s.len() as u64).sum();
        Workbench {
            corpus,
            vocab,
            sentences: Arc::new(sentences),
            total_words,
        }
    }

    /// Table 3-style stats.
    pub fn stats(&self) -> CorpusStats {
        CorpusStats::compute(&self.vocab, &self.sentences)
    }

    /// Build the PJRT coordinator for a train config over this corpus.
    pub fn coordinator(&self, mut cfg: Config) -> Result<Coordinator> {
        if cfg.artifacts_dir == "artifacts" {
            cfg.artifacts_dir = default_artifacts_dir();
        }
        Coordinator::new(cfg, &self.vocab, self.total_words)
    }

    /// Build any trainer by implementation name:
    /// pjrt variants (`full_w2v`, ...) or CPU trainers
    /// (`mikolov`, `pword2vec`, `psgnscc`, `fullw2v`).
    pub fn trainer(
        &self,
        implementation: &str,
        train: &TrainConfig,
    ) -> Result<Box<dyn SgnsTrainer>> {
        if crate::trainer::is_cpu_impl(implementation) {
            // one epoch's words: both the CPU constructors and the
            // coordinator multiply by cfg.epochs themselves (passing
            // words x epochs here used to square the epoch factor and
            // leave the lr nearly undecayed)
            return crate::trainer::build_cpu_trainer(
                implementation,
                train,
                &self.vocab,
                self.total_words,
            );
        }
        let mut cfg = Config::new();
        cfg.artifacts_dir = default_artifacts_dir();
        cfg.train = train.clone();
        cfg.train.variant = implementation.to_string();
        Ok(Box::new(Coordinator::new(cfg, &self.vocab, self.total_words)?))
    }
}

/// The artifacts directory relative to the crate root (works from
/// examples, benches and tests regardless of cwd).
pub fn default_artifacts_dir() -> String {
    let from_env = std::env::var("FULLW2V_ARTIFACTS").ok();
    from_env.unwrap_or_else(|| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

/// True if AOT artifacts are present (benches degrade gracefully).
pub fn have_artifacts() -> bool {
    std::path::Path::new(&default_artifacts_dir())
        .join("manifest.json")
        .exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_runs_real_pipeline() {
        let wb = Workbench::prepare(SyntheticSpec::tiny(), 1);
        assert_eq!(wb.sentences.len(), wb.corpus.sentences.len());
        assert!(wb.total_words > 0);
        let stats = wb.stats();
        assert_eq!(stats.sentences as usize, wb.sentences.len());
        assert!(stats.vocabulary <= wb.corpus.words.len());
    }

    #[test]
    fn min_count_shrinks_vocab() {
        // tiny spec: ~60K words over 300 types (mean ~200) — a min_count
        // above the mean must drop the Zipf tail (median < mean)
        let a = Workbench::prepare(SyntheticSpec::tiny(), 1);
        let b = Workbench::prepare(SyntheticSpec::tiny(), 500);
        assert!(b.vocab.len() < a.vocab.len());
        // encoded words can only shrink
        assert!(b.total_words < a.total_words);
    }

    #[test]
    fn cpu_trainer_construction() {
        let wb = Workbench::prepare(SyntheticSpec::tiny(), 1);
        let cfg = TrainConfig {
            dim: 8,
            subsample: 0.0,
            ..TrainConfig::default()
        };
        for name in ["mikolov", "pword2vec", "psgnscc", "fullw2v"] {
            let t = wb.trainer(name, &cfg).unwrap();
            assert!(t.name().len() > 3);
        }
    }
}
