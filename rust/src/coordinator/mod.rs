//! The L3 training coordinator: drives epochs end-to-end.
//!
//! Per epoch (Figure 4 of the paper): the multi-stream pipeline batches
//! sentences on CPU threads; the coordinator drains the bounded channel,
//! gathers embedding rows, executes the AOT-compiled training step on the
//! PJRT runtime, and scatter-adds the returned deltas (Hogwild-style).
//! The learning rate decays linearly over total planned words, exactly as
//! word2vec.c does.

pub mod lr;

use crate::batcher::pipeline::{Pipeline, PipelineStats};
use crate::batcher::{gather, scatter, IndexBatch};
use crate::config::Config;
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::metrics::{EpochReport, TrainReport};
use crate::model::EmbeddingModel;
use crate::runtime::{Engine, StepInputs};
use crate::sampler::unigram::UnigramTable;
use anyhow::{Context, Result};
use lr::LrSchedule;
use std::sync::Arc;

/// Common interface over the PJRT coordinator and the CPU baselines, so
/// benches and examples can run every implementation uniformly.
pub trait SgnsTrainer {
    fn name(&self) -> String;
    /// Train one epoch over the sentences; `epoch` indexes the schedule.
    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport>;
    fn model(&self) -> &EmbeddingModel;
    fn model_mut(&mut self) -> &mut EmbeddingModel;
}

/// Run a full training job with any trainer.
pub fn train_all(
    trainer: &mut dyn SgnsTrainer,
    sentences: &Arc<Vec<Vec<u32>>>,
    epochs: usize,
) -> Result<TrainReport> {
    let mut report = TrainReport {
        implementation: trainer.name(),
        epochs: Vec::with_capacity(epochs),
    };
    for e in 0..epochs {
        let rep = trainer.train_epoch(sentences, e)?;
        crate::log_debug!(
            "epoch {e}: {:.0} w/s loss/word {:.4}",
            rep.words_per_sec,
            rep.loss_per_word
        );
        report.epochs.push(rep);
    }
    Ok(report)
}

/// The PJRT-backed coordinator (the paper's FULL-W2V system proper).
pub struct Coordinator {
    pub cfg: Config,
    engine: Engine,
    step: Arc<crate::runtime::TrainStep>,
    model: EmbeddingModel,
    subsampler: Subsampler,
    negatives: UnigramTable,
    schedule: LrSchedule,
    /// Reused input buffers (no allocation on the hot path).
    inputs: StepInputs,
    /// Hot-path phase breakdown (seconds), for the §Perf profile.
    pub phase: PhaseStats,
}

/// Cumulative per-phase timings of the training hot path.
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    pub gather_secs: f64,
    pub execute_secs: f64,
    pub scatter_secs: f64,
}

impl Coordinator {
    /// Build a coordinator: loads + compiles the AOT executable the config
    /// names, initializes the model.
    pub fn new(cfg: Config, vocab: &Vocab, total_words_hint: u64) -> Result<Self> {
        cfg.train.validate().map_err(anyhow::Error::msg)?;
        let mut engine = Engine::new(std::path::Path::new(&cfg.artifacts_dir))
            .context("creating PJRT engine")?;
        let exe_name = cfg.train.executable_name();
        let step = engine
            .load(&exe_name)
            .with_context(|| format!("loading executable '{exe_name}'"))?;
        let model =
            EmbeddingModel::init(vocab.len(), cfg.train.dim, cfg.train.seed);
        let subsampler = Subsampler::new(vocab, cfg.train.subsample);
        let negatives = UnigramTable::new(vocab, UnigramTable::DEFAULT_ALPHA);
        let schedule = LrSchedule::new(
            cfg.train.lr,
            cfg.train.min_lr_ratio,
            total_words_hint * cfg.train.epochs as u64,
        );
        let inputs = StepInputs::zeroed(&step.spec);
        Ok(Coordinator {
            cfg,
            engine,
            step,
            model,
            subsampler,
            negatives,
            schedule,
            inputs,
            phase: PhaseStats::default(),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Process one batch: gather -> execute -> scatter.  Returns summed loss.
    fn process_batch(&mut self, batch: &IndexBatch, lr: f32) -> Result<f64> {
        let t0 = std::time::Instant::now();
        gather(&self.model, batch, &mut self.inputs);
        self.inputs.lr = lr;
        let t1 = std::time::Instant::now();
        let out = self.engine.run(&self.step, &self.inputs)?;
        let t2 = std::time::Instant::now();
        scatter(&mut self.model, batch, &out);
        let t3 = std::time::Instant::now();
        self.phase.gather_secs += (t1 - t0).as_secs_f64();
        self.phase.execute_secs += (t2 - t1).as_secs_f64();
        self.phase.scatter_secs += (t3 - t2).as_secs_f64();
        Ok(out.loss.iter().map(|&x| x as f64).sum())
    }
}

impl SgnsTrainer for Coordinator {
    fn name(&self) -> String {
        format!("{} (pjrt)", self.cfg.train.variant)
    }

    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport> {
        let t0 = std::time::Instant::now();
        let pipeline = Pipeline::launch(
            sentences.clone(),
            &self.cfg.train,
            &self.cfg.pipeline,
            &self.subsampler,
            &self.negatives,
            epoch as u64 + 1,
        );
        let stats: Arc<PipelineStats> = pipeline.stats.clone();
        let mut rep = EpochReport { epoch, ..Default::default() };
        let mut lr = self.schedule.current();
        // Drain the stream channel; the bounded queue applies backpressure
        // to the batcher threads while we're inside the PJRT call.
        for batch in pipeline.rx.iter() {
            rep.loss_sum += self.process_batch(&batch, lr)?;
            rep.words += batch.word_count as u64;
            rep.batches += 1;
            lr = self.schedule.advance(batch.word_count as u64);
        }
        pipeline.join();
        rep.lr_end = lr;
        rep.seconds = t0.elapsed().as_secs_f64();
        rep.batching_rate = stats.batching_rate();
        rep.finalize();
        Ok(rep)
    }

    fn model(&self) -> &EmbeddingModel {
        &self.model
    }

    fn model_mut(&mut self) -> &mut EmbeddingModel {
        &mut self.model
    }
}

#[cfg(test)]
mod tests {
    //! Coordinator integration tests (need artifacts) live in
    //! `rust/tests/train_integration.rs`; the lr schedule has its own
    //! unit tests in `lr.rs`.
}
