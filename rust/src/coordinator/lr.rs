//! word2vec.c's linear learning-rate decay:
//! `alpha = alpha0 * max(1 - processed/(total+1), floor)`.

/// Linear LR schedule over a planned total word count.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    lr0: f32,
    floor_ratio: f32,
    total: u64,
    processed: u64,
}

impl LrSchedule {
    pub fn new(lr0: f32, floor_ratio: f32, total_words: u64) -> Self {
        LrSchedule {
            lr0,
            floor_ratio,
            total: total_words,
            processed: 0,
        }
    }

    pub fn current(&self) -> f32 {
        self.lr_at(self.processed)
    }

    /// The lr after `processed` planned words — a pure function of the
    /// schedule's constants, so Hogwild workers can share one schedule
    /// immutably behind an atomic word counter and each compute the lr
    /// for the count they observed.
    pub fn lr_at(&self, processed: u64) -> f32 {
        let frac = if self.total == 0 {
            0.0
        } else {
            processed as f64 / (self.total + 1) as f64
        };
        let scale = (1.0 - frac).max(self.floor_ratio as f64);
        (self.lr0 as f64 * scale) as f32
    }

    /// Record progress; returns the new lr.
    pub fn advance(&mut self, words: u64) -> f32 {
        self.processed = self.processed.saturating_add(words);
        self.current()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_lr0_and_decays_linearly() {
        let mut s = LrSchedule::new(0.025, 1e-4, 1000);
        assert!((s.current() - 0.025).abs() < 1e-9);
        s.advance(500);
        let mid = s.current();
        assert!((mid - 0.025 * (1.0 - 500.0 / 1001.0) as f32).abs() < 1e-6);
        assert!(mid < 0.025 && mid > 0.012);
    }

    #[test]
    fn floors_at_ratio() {
        let mut s = LrSchedule::new(0.025, 1e-2, 100);
        s.advance(10_000); // way past the end
        assert!((s.current() - 0.025 * 1e-2).abs() < 1e-9);
    }

    #[test]
    fn zero_total_stays_at_lr0() {
        let mut s = LrSchedule::new(0.05, 1e-4, 0);
        assert_eq!(s.current(), 0.05);
        s.advance(100);
        assert_eq!(s.current(), 0.05);
    }

    #[test]
    fn lr_at_matches_mutating_walk() {
        // the pure lookup and the advancing walk must agree bit-for-bit,
        // whatever order the Hogwild workers observe the counter in
        let mut s = LrSchedule::new(0.025, 1e-4, 5000);
        let probe = s.clone();
        let mut processed = 0u64;
        for step in [0u64, 17, 500, 1, 4000, 600] {
            assert_eq!(s.current().to_bits(), probe.lr_at(processed).to_bits());
            s.advance(step);
            processed += step;
        }
        assert_eq!(s.current().to_bits(), probe.lr_at(processed).to_bits());
    }

    #[test]
    fn monotone_nonincreasing() {
        let mut s = LrSchedule::new(0.025, 1e-4, 10_000);
        let mut prev = s.current();
        for _ in 0..100 {
            let next = s.advance(150);
            assert!(next <= prev + 1e-12);
            prev = next;
        }
    }
}
