//! Shared SGNS math for the CPU baselines — re-exported from the
//! crate-wide kernel layer.
//!
//! The dot/axpy hot loops moved to `vecops` in PR 2; the sigmoid family
//! ([`SigmoidTable`], exact [`sigmoid`], [`softplus`]) followed when the
//! Hogwild training layer landed, so the serial baselines, the FULL-W2V
//! reference trainer, and any future kernel all share one
//! implementation.  This module remains as the baselines' historical
//! import path.

pub use crate::vecops::{axpy, dot, sigmoid, softplus, SigmoidTable};

#[cfg(test)]
mod tests {
    use super::*;

    /// The re-export surface the baselines compile against.
    #[test]
    fn reexports_are_the_vecops_kernels() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), crate::vecops::dot(&a, &b));
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((softplus(0.0) - 2f64.ln()).abs() < 1e-12);
        let t = SigmoidTable::new();
        assert_eq!(t.sigmoid(100.0), 1.0);
    }
}
