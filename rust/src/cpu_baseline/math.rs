//! Shared SGNS math kernels for the CPU baselines.

/// word2vec.c's EXP_TABLE: sigmoid precomputed over [-MAX_EXP, MAX_EXP]
/// in EXP_TABLE_SIZE buckets, saturating outside.
pub struct SigmoidTable {
    table: Vec<f32>,
    max_exp: f32,
}

impl SigmoidTable {
    pub const EXP_TABLE_SIZE: usize = 1000;
    pub const MAX_EXP: f32 = 6.0;

    pub fn new() -> Self {
        let n = Self::EXP_TABLE_SIZE;
        let table = (0..n)
            .map(|i| {
                let x = (i as f32 / n as f32 * 2.0 - 1.0) * Self::MAX_EXP;
                let e = x.exp();
                e / (e + 1.0)
            })
            .collect();
        SigmoidTable { table, max_exp: Self::MAX_EXP }
    }

    /// Table lookup, saturating to {0, 1} outside ±MAX_EXP exactly like
    /// word2vec.c (which skips the update when |x| > MAX_EXP for the
    /// positive label path; we return the saturated value instead, which
    /// zeroes the gradient for label-matched pairs).
    #[inline]
    pub fn sigmoid(&self, x: f32) -> f32 {
        if x >= self.max_exp {
            1.0
        } else if x <= -self.max_exp {
            0.0
        } else {
            let idx = ((x + self.max_exp)
                * (Self::EXP_TABLE_SIZE as f32 / (2.0 * self.max_exp)))
                as usize;
            self.table[idx.min(Self::EXP_TABLE_SIZE - 1)]
        }
    }
}

impl Default for SigmoidTable {
    fn default() -> Self {
        Self::new()
    }
}

/// Exact sigmoid (used by the matrix baselines; numerically stable).
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable softplus log(1+e^x), for loss reporting.
#[inline]
pub fn softplus(x: f32) -> f64 {
    let x = x as f64;
    if x > 30.0 {
        x
    } else if x < -30.0 {
        0.0
    } else {
        x.exp().ln_1p()
    }
}

// The dot/axpy hot loops live in the crate-wide kernel layer now; the
// re-export keeps `math::{dot, axpy}` as the baselines' import path.
pub use crate::vecops::{axpy, dot};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_tracks_exact_sigmoid() {
        let t = SigmoidTable::new();
        for i in -50..=50 {
            let x = i as f32 * 0.1;
            let err = (t.sigmoid(x) - sigmoid(x)).abs();
            assert!(err < 0.01, "x={x} err={err}");
        }
    }

    #[test]
    fn table_saturates() {
        let t = SigmoidTable::new();
        assert_eq!(t.sigmoid(100.0), 1.0);
        assert_eq!(t.sigmoid(-100.0), 0.0);
        assert_eq!(t.sigmoid(6.0), 1.0);
        assert_eq!(t.sigmoid(-6.0), 0.0);
    }

    #[test]
    fn exact_sigmoid_properties() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((sigmoid(5.0) + sigmoid(-5.0) - 1.0).abs() < 1e-6);
        assert!(sigmoid(-80.0) >= 0.0 && sigmoid(80.0) <= 1.0);
    }

    #[test]
    fn softplus_stable() {
        assert!((softplus(0.0) - 2f64.ln()).abs() < 1e-12);
        assert_eq!(softplus(100.0), 100.0);
        assert_eq!(softplus(-100.0), 0.0);
    }

    #[test]
    fn dot_axpy() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
