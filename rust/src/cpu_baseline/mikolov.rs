//! Faithful word2vec.c scalar SGNS (skip-gram, negative sampling).
//!
//! Per-pair immediate updates with the EXP_TABLE sigmoid; negatives are
//! shared per window (the reuse policy the paper equalizes across all
//! compared implementations, Section 5.3.3).  This is both the slowest
//! baseline in the throughput figures and the semantic reference the
//! integration tests compare embedding quality against.

use super::math::{softplus, SigmoidTable};
use crate::vecops::{axpy, dot};
use super::{epoch_loop, BaseTrainer};
use crate::config::TrainConfig;
use crate::coordinator::SgnsTrainer;
use crate::corpus::vocab::Vocab;
use crate::metrics::EpochReport;
use crate::model::EmbeddingModel;
use crate::sampler::window::context_positions;
use crate::util::rng::Pcg32;
use anyhow::Result;
use std::sync::Arc;

pub struct MikolovTrainer {
    base: BaseTrainer,
    sig: SigmoidTable,
}

impl MikolovTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        MikolovTrainer {
            base: BaseTrainer::new(cfg, vocab, total_words_hint),
            sig: SigmoidTable::new(),
        }
    }

    /// One sentence of scalar training; returns NS loss (pre-update).
    fn train_sentence(
        base: &mut BaseTrainer,
        sig: &SigmoidTable,
        sent: &[u32],
        lr: f32,
        rng: &mut Pcg32,
    ) -> f64 {
        let wf = base.cfg.fixed_width();
        let n_neg = base.cfg.negatives;
        let d = base.model.dim;
        let mut negs = vec![0u32; n_neg];
        let mut neu1e = vec![0.0f32; d];
        let mut loss = 0.0f64;
        for t in 0..sent.len() {
            let center = sent[t];
            // per-window shared negatives
            base.negatives.fill(rng, center, &mut negs);
            for j in context_positions(t, wf, sent.len()) {
                let ctx = sent[j];
                neu1e.iter_mut().for_each(|x| *x = 0.0);
                // positive pair + N negatives, immediate syn1 updates
                for k in 0..=n_neg {
                    let (target, label) = if k == 0 {
                        (center, 1.0f32)
                    } else {
                        (negs[k - 1], 0.0f32)
                    };
                    let h = base.model.syn0_row(ctx);
                    let u = base.model.syn1_row(target);
                    let z = dot(h, u);
                    let f = sig.sigmoid(z);
                    let g = (label - f) * lr;
                    loss += if k == 0 {
                        softplus(-z)
                    } else {
                        softplus(z)
                    };
                    // neu1e += g * u  (pre-update u)
                    axpy(g, u, &mut neu1e);
                    // syn1[target] += g * h — aliasing-free: copy h first
                    let h_copy: Vec<f32> = h.to_vec();
                    axpy(g, &h_copy, base.model.syn1_row_mut(target));
                }
                let neu = neu1e.clone();
                axpy(1.0, &neu, base.model.syn0_row_mut(ctx));
            }
        }
        loss
    }
}

impl SgnsTrainer for MikolovTrainer {
    fn name(&self) -> String {
        "mikolov (cpu scalar)".into()
    }

    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport> {
        // disjoint field borrows: base mutably, sigmoid table immutably
        let sig = &self.sig;
        let rep = epoch_loop(&mut self.base, sentences, epoch, |b, s, lr, rng| {
            Self::train_sentence(b, sig, s, lr, rng)
        });
        Ok(rep)
    }

    fn model(&self) -> &EmbeddingModel {
        &self.base.model
    }

    fn model_mut(&mut self) -> &mut EmbeddingModel {
        &mut self.base.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};
    use crate::coordinator::train_all;

    fn tiny_setup() -> (TrainConfig, Vocab, Arc<Vec<Vec<u32>>>) {
        let corpus = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let text = corpus.to_text();
        let vocab = Vocab::build(text.split_whitespace(), 1);
        let sentences: Vec<Vec<u32>> = corpus
            .sentences
            .iter()
            .map(|s| {
                s.iter()
                    .map(|&id| vocab.id(&corpus.words[id as usize]).unwrap())
                    .collect()
            })
            .collect();
        let cfg = TrainConfig {
            dim: 16,
            window: 4,
            negatives: 3,
            epochs: 2,
            subsample: 0.0,
            sentence_chunk: 32,
            ..TrainConfig::default()
        };
        (cfg, vocab, Arc::new(sentences))
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let (cfg, vocab, sents) = tiny_setup();
        let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
        let mut tr = MikolovTrainer::new(&cfg, &vocab, total);
        let rep = train_all(&mut tr, &sents, 2).unwrap();
        let (first, last) = rep.loss_trajectory();
        assert!(
            last < first,
            "loss did not decrease: {first} -> {last}"
        );
        // sane magnitude: initial loss/pair ~ (N+1) log 2 per word-pair
        assert!(first > 0.0 && first < 100.0);
    }

    #[test]
    fn embeddings_move_from_init() {
        let (cfg, vocab, sents) = tiny_setup();
        let mut tr = MikolovTrainer::new(&cfg, &vocab, 1000);
        let before = tr.model().syn0.clone();
        tr.train_epoch(&sents, 0).unwrap();
        let after = &tr.model().syn0;
        let moved = before
            .iter()
            .zip(after)
            .filter(|(a, b)| (*a - *b).abs() > 1e-7)
            .count();
        assert!(moved > before.len() / 2);
    }
}
