//! Faithful word2vec.c scalar SGNS (skip-gram, negative sampling).
//!
//! Per-pair immediate updates with the EXP_TABLE sigmoid; negatives are
//! shared per window (the reuse policy the paper equalizes across all
//! compared implementations, Section 5.3.3).  This is both the slowest
//! baseline in the throughput figures and the semantic reference the
//! integration tests compare embedding quality against.
//!
//! The update rule lives in [`MikolovKernel`], a per-thread
//! [`ShardTrainer`] chunk kernel driven by the Hogwild epoch driver;
//! at one thread the walk is exactly the historical serial loop.

use super::BaseTrainer;
use crate::config::TrainConfig;
use crate::coordinator::SgnsTrainer;
use crate::corpus::vocab::Vocab;
use crate::metrics::EpochReport;
use crate::model::EmbeddingModel;
use crate::sampler::window::context_positions;
use crate::trainer::{hogwild, ReuseCounters, ShardCtx, ShardTrainer};
use crate::util::rng::Pcg32;
use crate::vecops::{axpy, dot, softplus, SigmoidTable};
use anyhow::Result;
use std::sync::Arc;

pub struct MikolovTrainer {
    base: BaseTrainer,
    sig: Arc<SigmoidTable>,
}

impl MikolovTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        MikolovTrainer {
            base: BaseTrainer::new(cfg, vocab, total_words_hint),
            sig: Arc::new(SigmoidTable::new()),
        }
    }
}

/// Per-thread scalar kernel: word2vec.c's per-pair immediate updates.
struct MikolovKernel {
    sig: Arc<SigmoidTable>,
    negs: Vec<u32>,
    neu1e: Vec<f32>,
    h: Vec<f32>,
    u: Vec<f32>,
    reuse: ReuseCounters,
}

impl MikolovKernel {
    fn new(sig: Arc<SigmoidTable>) -> Self {
        MikolovKernel {
            sig,
            negs: Vec::new(),
            neu1e: Vec::new(),
            h: Vec::new(),
            u: Vec::new(),
            reuse: ReuseCounters::default(),
        }
    }
}

impl ShardTrainer for MikolovKernel {
    fn train_chunk(
        &mut self,
        ctx: &ShardCtx<'_>,
        sent: &[u32],
        lr: f32,
        rng: &mut Pcg32,
    ) -> f64 {
        let wf = ctx.cfg.fixed_width();
        let n_neg = ctx.cfg.negatives;
        let d = ctx.model.dim();
        self.negs.resize(n_neg, 0);
        self.neu1e.resize(d, 0.0);
        self.h.resize(d, 0.0);
        self.u.resize(d, 0.0);
        let mut loss = 0.0f64;
        for t in 0..sent.len() {
            let center = sent[t];
            // per-window shared negatives
            ctx.negatives.fill(rng, center, &mut self.negs);
            for j in context_positions(t, wf, sent.len()) {
                let ctx_word = sent[j];
                self.neu1e.iter_mut().for_each(|x| *x = 0.0);
                // the context row is stable across the pair loop (only
                // syn1 updates inside it), so one copy serves all pairs
                ctx.model.copy_syn0_row(ctx_word, &mut self.h);
                // positive pair + N negatives, immediate syn1 updates
                for k in 0..=n_neg {
                    let (target, label) = if k == 0 {
                        (center, 1.0f32)
                    } else {
                        (self.negs[k - 1], 0.0f32)
                    };
                    // pre-update output row
                    ctx.model.copy_syn1_row(target, &mut self.u);
                    let z = dot(&self.h, &self.u);
                    let f = self.sig.sigmoid(z);
                    let g = (label - f) * lr;
                    loss += if k == 0 { softplus(-z) } else { softplus(z) };
                    // neu1e += g * u  (pre-update u)
                    axpy(g, &self.u, &mut self.neu1e);
                    // syn1[target] += g * h, immediately
                    ctx.model.axpy_syn1_row(target, g, &self.h);
                    if k > 0 {
                        // every negative interaction re-fetches the row:
                        // the no-reuse baseline the counters compare to
                        self.reuse.neg_rows_loaded += 1;
                        self.reuse.neg_row_uses += 1;
                    }
                }
                ctx.model.add_syn0_row(ctx_word, &self.neu1e);
            }
        }
        loss
    }

    fn reuse(&self) -> ReuseCounters {
        self.reuse
    }
}

impl SgnsTrainer for MikolovTrainer {
    fn name(&self) -> String {
        "mikolov (cpu scalar)".into()
    }

    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport> {
        let sig = &self.sig;
        Ok(hogwild::run_epoch(&mut self.base, sentences, epoch, |_tid| {
            MikolovKernel::new(sig.clone())
        }))
    }

    fn model(&self) -> &EmbeddingModel {
        &self.base.model
    }

    fn model_mut(&mut self) -> &mut EmbeddingModel {
        &mut self.base.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train_all;
    use crate::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};

    fn tiny_setup() -> (TrainConfig, Vocab, Arc<Vec<Vec<u32>>>) {
        let corpus = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let text = corpus.to_text();
        let vocab = Vocab::build(text.split_whitespace(), 1);
        let sentences: Vec<Vec<u32>> = corpus
            .sentences
            .iter()
            .map(|s| {
                s.iter()
                    .map(|&id| vocab.id(&corpus.words[id as usize]).unwrap())
                    .collect()
            })
            .collect();
        let cfg = TrainConfig {
            dim: 16,
            window: 4,
            negatives: 3,
            epochs: 2,
            subsample: 0.0,
            sentence_chunk: 32,
            ..TrainConfig::default()
        };
        (cfg, vocab, Arc::new(sentences))
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let (cfg, vocab, sents) = tiny_setup();
        let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
        let mut tr = MikolovTrainer::new(&cfg, &vocab, total);
        let rep = train_all(&mut tr, &sents, 2).unwrap();
        let (first, last) = rep.loss_trajectory();
        assert!(
            last < first,
            "loss did not decrease: {first} -> {last}"
        );
        // sane magnitude: initial loss/pair ~ (N+1) log 2 per word-pair
        assert!(first > 0.0 && first < 100.0);
    }

    #[test]
    fn embeddings_move_from_init() {
        let (cfg, vocab, sents) = tiny_setup();
        let mut tr = MikolovTrainer::new(&cfg, &vocab, 1000);
        let before = tr.model().syn0.clone();
        tr.train_epoch(&sents, 0).unwrap();
        let after = &tr.model().syn0;
        let moved = before
            .iter()
            .zip(after)
            .filter(|(a, b)| (*a - *b).abs() > 1e-7)
            .count();
        assert!(moved > before.len() / 2);
    }

    #[test]
    fn negative_traffic_has_no_reuse() {
        // the scalar baseline fetches a negative row per interaction:
        // loads == uses, reuse factor exactly 1
        let (cfg, vocab, sents) = tiny_setup();
        let mut tr = MikolovTrainer::new(&cfg, &vocab, 1000);
        let rep = tr.train_epoch(&sents, 0).unwrap();
        assert!(rep.neg_rows_loaded > 0);
        assert_eq!(rep.neg_rows_loaded, rep.neg_row_uses);
    }
}
