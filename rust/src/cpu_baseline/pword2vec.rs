//! pWord2Vec (Ji et al.): shared-negative window-matrix SGNS on CPU.
//!
//! The window's context rows C (m x d) are paired against the output block
//! U = [center; negatives] ((N+1) x d) as two small matrix products per
//! window, with both sides updated once per window from pre-update values.
//! These are exactly the FULL-W2V kernel semantics (`ref.sgns_window_ref`),
//! so this trainer doubles as the quality counterpart in Table 7 and as a
//! cross-check of the PJRT path in integration tests.
//!
//! The update rule lives in [`PWord2VecKernel`], a per-thread
//! [`ShardTrainer`] chunk kernel driven by the Hogwild epoch driver.

use super::BaseTrainer;
use crate::config::TrainConfig;
use crate::coordinator::SgnsTrainer;
use crate::corpus::vocab::Vocab;
use crate::metrics::EpochReport;
use crate::model::EmbeddingModel;
use crate::sampler::window::context_positions;
use crate::trainer::{hogwild, ReuseCounters, ShardCtx, ShardTrainer};
use crate::util::rng::Pcg32;
use crate::vecops::{axpy, dot, sigmoid, softplus};
use anyhow::Result;
use std::sync::Arc;

pub struct PWord2VecTrainer {
    base: BaseTrainer,
}

impl PWord2VecTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        PWord2VecTrainer {
            base: BaseTrainer::new(cfg, vocab, total_words_hint),
        }
    }
}

/// Per-thread window-matrix kernel; scratch reused across windows (no
/// hot-loop allocation).
#[derive(Default)]
struct PWord2VecKernel {
    c: Vec<f32>,  // m x d context rows
    u: Vec<f32>,  // (N+1) x d output rows
    g: Vec<f32>,  // m x (N+1) gradients
    dc: Vec<f32>, // m x d
    du: Vec<f32>, // (N+1) x d
    negs: Vec<u32>,
    ctx_ids: Vec<u32>,
    reuse: ReuseCounters,
}

impl ShardTrainer for PWord2VecKernel {
    fn train_chunk(
        &mut self,
        ctx: &ShardCtx<'_>,
        sent: &[u32],
        lr: f32,
        rng: &mut Pcg32,
    ) -> f64 {
        let sc = self;
        let wf = ctx.cfg.fixed_width();
        let n_neg = ctx.cfg.negatives;
        let d = ctx.model.dim();
        let cols = n_neg + 1;
        sc.negs.resize(n_neg, 0);
        let mut loss = 0.0f64;
        for t in 0..sent.len() {
            let center = sent[t];
            sc.ctx_ids.clear();
            for j in context_positions(t, wf, sent.len()) {
                sc.ctx_ids.push(sent[j]);
            }
            let m = sc.ctx_ids.len();
            if m == 0 {
                continue;
            }
            ctx.negatives.fill(rng, center, &mut sc.negs);

            // gather C and U
            sc.c.resize(m * d, 0.0);
            sc.u.resize(cols * d, 0.0);
            for (i, &w) in sc.ctx_ids.iter().enumerate() {
                ctx.model.copy_syn0_row(w, &mut sc.c[i * d..(i + 1) * d]);
            }
            ctx.model.copy_syn1_row(center, &mut sc.u[0..d]);
            for (k, &g) in sc.negs.iter().enumerate() {
                ctx.model
                    .copy_syn1_row(g, &mut sc.u[(k + 1) * d..(k + 2) * d]);
            }
            // negatives gathered once per window, reused by every
            // context row of the window
            sc.reuse.neg_rows_loaded += n_neg as u64;
            sc.reuse.neg_row_uses += (m * n_neg) as u64;

            // G = (label - sigmoid(C U^T)) * lr, loss from pre-update Z
            sc.g.resize(m * cols, 0.0);
            for i in 0..m {
                for k in 0..cols {
                    let z = dot(
                        &sc.c[i * d..(i + 1) * d],
                        &sc.u[k * d..(k + 1) * d],
                    );
                    let label = if k == 0 { 1.0 } else { 0.0 };
                    sc.g[i * cols + k] = (label - sigmoid(z)) * lr;
                    loss += if k == 0 { softplus(-z) } else { softplus(z) };
                }
            }

            // dC = G U, dU = G^T C (pre-update operands)
            sc.dc.resize(m * d, 0.0);
            sc.dc.iter_mut().for_each(|x| *x = 0.0);
            sc.du.resize(cols * d, 0.0);
            sc.du.iter_mut().for_each(|x| *x = 0.0);
            for i in 0..m {
                for k in 0..cols {
                    let g = sc.g[i * cols + k];
                    if g != 0.0 {
                        axpy(
                            g,
                            &sc.u[k * d..(k + 1) * d],
                            &mut sc.dc[i * d..(i + 1) * d],
                        );
                        axpy(
                            g,
                            &sc.c[i * d..(i + 1) * d],
                            &mut sc.du[k * d..(k + 1) * d],
                        );
                    }
                }
            }

            // scatter both sides (duplicates in ctx_ids sum, like Hogwild)
            for (i, &w) in sc.ctx_ids.iter().enumerate() {
                ctx.model.add_syn0_row(w, &sc.dc[i * d..(i + 1) * d]);
            }
            ctx.model.add_syn1_row(center, &sc.du[0..d]);
            for (k, &gid) in sc.negs.iter().enumerate() {
                ctx.model
                    .add_syn1_row(gid, &sc.du[(k + 1) * d..(k + 2) * d]);
            }
        }
        loss
    }

    fn reuse(&self) -> ReuseCounters {
        self.reuse
    }
}

impl SgnsTrainer for PWord2VecTrainer {
    fn name(&self) -> String {
        "pWord2Vec (cpu matrix)".into()
    }

    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport> {
        Ok(hogwild::run_epoch(&mut self.base, sentences, epoch, |_tid| {
            PWord2VecKernel::default()
        }))
    }

    fn model(&self) -> &EmbeddingModel {
        &self.base.model
    }

    fn model_mut(&mut self) -> &mut EmbeddingModel {
        &mut self.base.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Window-matrix semantics must match the Python window oracle: we
    /// replicate a tiny fixed case and compare against hand-computed
    /// pWord2Vec updates through the public trainer API.
    #[test]
    fn one_window_update_matches_manual_math() {
        let vocab = Vocab::from_counts(
            (0..4).map(|i| (format!("w{i}"), 10u64)),
            1,
        );
        let cfg = TrainConfig {
            dim: 2,
            window: 2, // wf = 1
            negatives: 1,
            subsample: 0.0,
            sentence_chunk: 8,
            ..TrainConfig::default()
        };
        let mut tr = PWord2VecTrainer::new(&cfg, &vocab, 100);
        // plant deterministic vectors
        for id in 0..4u32 {
            let v = [0.1 * (id as f32 + 1.0), -0.05 * (id as f32 + 1.0)];
            tr.base.model.syn0_row_mut(id).copy_from_slice(&v);
            let u = [0.02 * (id as f32 + 1.0), 0.03];
            tr.base.model.syn1_row_mut(id).copy_from_slice(&u);
        }
        let before0 = tr.base.model.syn0.clone();
        let before1 = tr.base.model.syn1.clone();
        let sents = Arc::new(vec![vec![0u32, 1]]);
        tr.train_epoch(&sents, 0).unwrap();
        // two windows processed (t=0 ctx {1}, t=1 ctx {0});
        // verify syn0/syn1 changed only for ids 0,1 and negatives
        let moved0: Vec<usize> = (0..4)
            .filter(|&i| {
                tr.base.model.syn0[i * 2..i * 2 + 2]
                    != before0[i * 2..i * 2 + 2]
            })
            .collect();
        assert_eq!(moved0, vec![0, 1]);
        // syn1 changed for centers {0,1} and sampled negatives
        let moved1 = (0..4)
            .filter(|&i| {
                tr.base.model.syn1[i * 2..i * 2 + 2]
                    != before1[i * 2..i * 2 + 2]
            })
            .count();
        assert!(moved1 >= 2);
    }

    #[test]
    fn loss_decreases() {
        use crate::coordinator::train_all;
        use crate::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};
        let corpus = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let text = corpus.to_text();
        let vocab = Vocab::build(text.split_whitespace(), 1);
        let sentences: Arc<Vec<Vec<u32>>> = Arc::new(
            corpus
                .sentences
                .iter()
                .map(|s| {
                    s.iter()
                        .map(|&id| {
                            vocab.id(&corpus.words[id as usize]).unwrap()
                        })
                        .collect()
                })
                .collect(),
        );
        let cfg = TrainConfig {
            dim: 16,
            window: 4,
            negatives: 3,
            epochs: 2,
            subsample: 0.0,
            sentence_chunk: 32,
            ..TrainConfig::default()
        };
        let total: u64 = sentences.iter().map(|s| s.len() as u64).sum();
        let mut tr = PWord2VecTrainer::new(&cfg, &vocab, total);
        let rep = train_all(&mut tr, &sentences, 2).unwrap();
        let (first, last) = rep.loss_trajectory();
        assert!(last < first, "{first} -> {last}");
    }
}
