//! CPU baseline trainers: the comparator implementations from the paper's
//! evaluation (Section 5.1), in pure Rust.
//!
//! * [`mikolov`] — faithful word2vec.c scalar SGNS (per-pair immediate
//!   updates, EXP_TABLE sigmoid).  Semantic reference.
//! * [`pword2vec`] — Ji et al.'s shared-negative window-matrix SGNS
//!   (the CPU relative of FULL-W2V's update rule).
//! * [`psgnscc`] — Rengasamy et al.'s context-combining batcher: several
//!   windows share one negative set and update as one larger matrix op.
//!
//! All three implement [`crate::coordinator::SgnsTrainer`], so the
//! throughput benches (Figs 6/7) and the quality bench (Table 7) run them
//! interchangeably with the PJRT coordinator.
//!
//! Since the Hogwild training layer landed, each baseline is a
//! [`crate::trainer::ShardTrainer`] chunk kernel driven by
//! `trainer::hogwild::run_epoch` — the serial `epoch_loop` these modules
//! used through PR 3 is gone, and `train.threads > 1` shards every
//! baseline across Hogwild workers.  The shared `BaseTrainer`
//! scaffolding lives in [`crate::trainer`] now; the FULL-W2V reference
//! CPU trainer (both reuse axes) is `trainer::FullW2vTrainer`.

pub mod math;
pub mod mikolov;
pub mod psgnscc;
pub mod pword2vec;

pub use mikolov::MikolovTrainer;
pub use psgnscc::PsgnsccTrainer;
pub use pword2vec::PWord2VecTrainer;

pub(crate) use crate::trainer::BaseTrainer;
