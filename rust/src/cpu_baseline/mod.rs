//! CPU baseline trainers: the comparator implementations from the paper's
//! evaluation (Section 5.1), in pure Rust.
//!
//! * [`mikolov`] — faithful word2vec.c scalar SGNS (per-pair immediate
//!   updates, EXP_TABLE sigmoid).  Semantic reference.
//! * [`pword2vec`] — Ji et al.'s shared-negative window-matrix SGNS
//!   (the CPU relative of FULL-W2V's update rule).
//! * [`psgnscc`] — Rengasamy et al.'s context-combining batcher: several
//!   windows share one negative set and update as one larger matrix op.
//!
//! All three implement [`crate::coordinator::SgnsTrainer`], so the
//! throughput benches (Figs 6/7) and the quality bench (Table 7) run them
//! interchangeably with the PJRT coordinator.

pub mod math;
pub mod mikolov;
pub mod psgnscc;
pub mod pword2vec;

pub use mikolov::MikolovTrainer;
pub use psgnscc::PsgnsccTrainer;
pub use pword2vec::PWord2VecTrainer;

use crate::config::TrainConfig;
use crate::coordinator::lr::LrSchedule;
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::model::EmbeddingModel;
use crate::sampler::unigram::UnigramTable;
use crate::util::rng::Pcg32;

/// Shared scaffolding for the CPU trainers.
pub(crate) struct BaseTrainer {
    pub model: EmbeddingModel,
    pub subsampler: Subsampler,
    pub negatives: UnigramTable,
    pub schedule: LrSchedule,
    pub cfg: TrainConfig,
}

impl BaseTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        BaseTrainer {
            model: EmbeddingModel::init(vocab.len(), cfg.dim, cfg.seed),
            subsampler: Subsampler::new(vocab, cfg.subsample),
            negatives: UnigramTable::new(vocab, UnigramTable::DEFAULT_ALPHA),
            schedule: LrSchedule::new(
                cfg.lr,
                cfg.min_lr_ratio,
                total_words_hint * cfg.epochs as u64,
            ),
            cfg: cfg.clone(),
        }
    }

    pub fn epoch_rng(&self, epoch: usize) -> Pcg32 {
        Pcg32::with_stream(self.cfg.seed ^ (epoch as u64 + 1), 0xc9)
    }
}

/// Run a closure over every (subsampled) sentence of an epoch, collecting
/// the standard report.  `f(sentence, lr) -> loss`.
pub(crate) fn epoch_loop<F>(
    base: &mut BaseTrainer,
    sentences: &[Vec<u32>],
    epoch: usize,
    mut f: F,
) -> crate::metrics::EpochReport
where
    F: FnMut(&mut BaseTrainer, &[u32], f32, &mut Pcg32) -> f64,
{
    let t0 = std::time::Instant::now();
    let mut rng = base.epoch_rng(epoch);
    let mut rep = crate::metrics::EpochReport { epoch, ..Default::default() };
    let mut lr = base.schedule.current();
    let mut kept = Vec::new();
    for sent in sentences {
        kept.clear();
        kept.extend_from_slice(sent);
        base.subsampler.filter(&mut kept, &mut rng);
        if kept.len() < 2 {
            continue;
        }
        // cap to the same chunk length the GPU path uses, for fairness
        let chunk = base.cfg.sentence_chunk;
        let mut loss = 0.0;
        let mut words = 0u64;
        let kept_taken = std::mem::take(&mut kept);
        for c in kept_taken.chunks(chunk) {
            if c.len() < 2 {
                continue;
            }
            loss += f(base, c, lr, &mut rng);
            words += c.len() as u64;
        }
        kept = kept_taken;
        rep.loss_sum += loss;
        rep.words += words;
        rep.batches += 1;
        lr = base.schedule.advance(words);
    }
    rep.lr_end = lr;
    rep.seconds = t0.elapsed().as_secs_f64();
    rep.finalize();
    rep
}
