//! pSGNScc (Rengasamy et al.): context-combined batched SGNS on CPU.
//!
//! Consecutive context windows are *combined* into one larger matrix
//! operation sharing a single negative set, raising arithmetic intensity
//! on CPUs (the paper's strongest CPU comparator).  We combine `CC`
//! windows per block: the block's context rows form C ((m1+..+mCC) x d)
//! and the output block stacks the CC centers + the shared negatives
//! ((CC + N) x d); the label matrix marks each context row's own center
//! positive, everything else negative.  Updates apply once per block.
//!
//! The update rule lives in [`PsgnsccKernel`], a per-thread
//! [`ShardTrainer`] chunk kernel driven by the Hogwild epoch driver.

use super::BaseTrainer;
use crate::config::TrainConfig;
use crate::coordinator::SgnsTrainer;
use crate::corpus::vocab::Vocab;
use crate::metrics::EpochReport;
use crate::model::EmbeddingModel;
use crate::sampler::window::context_positions;
use crate::trainer::{hogwild, ReuseCounters, ShardCtx, ShardTrainer};
use crate::util::rng::Pcg32;
use crate::vecops::{axpy, dot, sigmoid, softplus};
use anyhow::Result;
use std::sync::Arc;

/// Windows combined per block (the paper's batching knob).
pub const COMBINE: usize = 4;

pub struct PsgnsccTrainer {
    base: BaseTrainer,
}

impl PsgnsccTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        PsgnsccTrainer {
            base: BaseTrainer::new(cfg, vocab, total_words_hint),
        }
    }
}

/// Per-thread combined-window kernel.
#[derive(Default)]
struct PsgnsccKernel {
    c: Vec<f32>,
    u: Vec<f32>,
    g: Vec<f32>,
    dc: Vec<f32>,
    du: Vec<f32>,
    negs: Vec<u32>,
    ctx_ids: Vec<u32>,
    /// Which combined-window each context row belongs to.
    row_window: Vec<usize>,
    centers: Vec<u32>,
    reuse: ReuseCounters,
}

impl ShardTrainer for PsgnsccKernel {
    fn train_chunk(
        &mut self,
        ctx: &ShardCtx<'_>,
        sent: &[u32],
        lr: f32,
        rng: &mut Pcg32,
    ) -> f64 {
        let sc = self;
        let wf = ctx.cfg.fixed_width();
        let n_neg = ctx.cfg.negatives;
        let d = ctx.model.dim();
        sc.negs.resize(n_neg, 0);
        let mut loss = 0.0f64;
        let mut t = 0;
        while t < sent.len() {
            let block_end = (t + COMBINE).min(sent.len());
            // assemble combined block
            sc.ctx_ids.clear();
            sc.row_window.clear();
            sc.centers.clear();
            for (wi, tt) in (t..block_end).enumerate() {
                sc.centers.push(sent[tt]);
                for j in context_positions(tt, wf, sent.len()) {
                    sc.ctx_ids.push(sent[j]);
                    sc.row_window.push(wi);
                }
            }
            let m = sc.ctx_ids.len();
            let ncenters = sc.centers.len();
            if m == 0 {
                t = block_end;
                continue;
            }
            // one shared negative set per block, avoiding all centers
            for slot in sc.negs.iter_mut() {
                loop {
                    let g = ctx.negatives.sample(rng);
                    if !sc.centers.contains(&g) {
                        *slot = g;
                        break;
                    }
                }
            }
            let cols = ncenters + n_neg;

            // gather
            sc.c.resize(m * d, 0.0);
            for (i, &w) in sc.ctx_ids.iter().enumerate() {
                ctx.model.copy_syn0_row(w, &mut sc.c[i * d..(i + 1) * d]);
            }
            sc.u.resize(cols * d, 0.0);
            for (k, &w) in sc.centers.iter().enumerate() {
                ctx.model.copy_syn1_row(w, &mut sc.u[k * d..(k + 1) * d]);
            }
            for (k, &g) in sc.negs.iter().enumerate() {
                let kk = ncenters + k;
                ctx.model
                    .copy_syn1_row(g, &mut sc.u[kk * d..(kk + 1) * d]);
            }
            // negatives gathered once per combined block, reused by
            // every context row of all CC windows
            sc.reuse.neg_rows_loaded += n_neg as u64;
            sc.reuse.neg_row_uses += (m * n_neg) as u64;

            // gradients: row i's positive column is its own window's center
            sc.g.resize(m * cols, 0.0);
            for i in 0..m {
                let own = sc.row_window[i];
                for k in 0..cols {
                    let z = dot(
                        &sc.c[i * d..(i + 1) * d],
                        &sc.u[k * d..(k + 1) * d],
                    );
                    // a context row trains only against its own center and
                    // the shared negatives (not other windows' centers)
                    let (label, active) = if k == own {
                        (1.0, true)
                    } else if k >= ncenters {
                        (0.0, true)
                    } else {
                        (0.0, false)
                    };
                    sc.g[i * cols + k] = if active {
                        loss += if k == own {
                            softplus(-z)
                        } else {
                            softplus(z)
                        };
                        (label - sigmoid(z)) * lr
                    } else {
                        0.0
                    };
                }
            }

            // dC = G U, dU = G^T C
            sc.dc.resize(m * d, 0.0);
            sc.dc.iter_mut().for_each(|x| *x = 0.0);
            sc.du.resize(cols * d, 0.0);
            sc.du.iter_mut().for_each(|x| *x = 0.0);
            for i in 0..m {
                for k in 0..cols {
                    let g = sc.g[i * cols + k];
                    if g != 0.0 {
                        axpy(
                            g,
                            &sc.u[k * d..(k + 1) * d],
                            &mut sc.dc[i * d..(i + 1) * d],
                        );
                        axpy(
                            g,
                            &sc.c[i * d..(i + 1) * d],
                            &mut sc.du[k * d..(k + 1) * d],
                        );
                    }
                }
            }

            // scatter
            for (i, &w) in sc.ctx_ids.iter().enumerate() {
                ctx.model.add_syn0_row(w, &sc.dc[i * d..(i + 1) * d]);
            }
            for (k, &w) in sc.centers.iter().enumerate() {
                ctx.model.add_syn1_row(w, &sc.du[k * d..(k + 1) * d]);
            }
            for (k, &g) in sc.negs.iter().enumerate() {
                let kk = ncenters + k;
                ctx.model.add_syn1_row(g, &sc.du[kk * d..(kk + 1) * d]);
            }
            t = block_end;
        }
        loss
    }

    fn reuse(&self) -> ReuseCounters {
        self.reuse
    }
}

impl SgnsTrainer for PsgnsccTrainer {
    fn name(&self) -> String {
        "pSGNScc (cpu combined)".into()
    }

    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport> {
        Ok(hogwild::run_epoch(&mut self.base, sentences, epoch, |_tid| {
            PsgnsccKernel::default()
        }))
    }

    fn model(&self) -> &EmbeddingModel {
        &self.base.model
    }

    fn model_mut(&mut self) -> &mut EmbeddingModel {
        &mut self.base.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train_all;
    use crate::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};

    #[test]
    fn loss_decreases_and_is_comparable_to_pword2vec() {
        let corpus = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let text = corpus.to_text();
        let vocab = Vocab::build(text.split_whitespace(), 1);
        let sentences: Arc<Vec<Vec<u32>>> = Arc::new(
            corpus
                .sentences
                .iter()
                .map(|s| {
                    s.iter()
                        .map(|&id| {
                            vocab.id(&corpus.words[id as usize]).unwrap()
                        })
                        .collect()
                })
                .collect(),
        );
        let cfg = TrainConfig {
            dim: 16,
            window: 4,
            negatives: 3,
            epochs: 2,
            subsample: 0.0,
            sentence_chunk: 32,
            ..TrainConfig::default()
        };
        let total: u64 = sentences.iter().map(|s| s.len() as u64).sum();
        let mut tr = PsgnsccTrainer::new(&cfg, &vocab, total);
        let rep = train_all(&mut tr, &sentences, 2).unwrap();
        let (first, last) = rep.loss_trajectory();
        assert!(last < first, "{first} -> {last}");

        let mut pw =
            crate::cpu_baseline::PWord2VecTrainer::new(&cfg, &vocab, total);
        let rep_pw = train_all(&mut pw, &sentences, 2).unwrap();
        // combined batching changes arithmetic order but must converge to a
        // similar loss region
        let (_, last_pw) = rep_pw.loss_trajectory();
        assert!(
            (last - last_pw).abs() < 0.35 * last_pw.max(last),
            "pSGNScc {last} vs pWord2Vec {last_pw}"
        );
    }

    #[test]
    fn negatives_avoid_block_centers() {
        // direct check of the block-negative invariant via a small corpus
        let vocab = Vocab::from_counts(
            (0..10).map(|i| (format!("w{i}"), 10u64)),
            1,
        );
        let cfg = TrainConfig {
            dim: 4,
            window: 2,
            negatives: 3,
            subsample: 0.0,
            sentence_chunk: 16,
            ..TrainConfig::default()
        };
        let mut tr = PsgnsccTrainer::new(&cfg, &vocab, 100);
        // run a few epochs; the inner loop asserts via the retry loop —
        // here we just ensure it terminates and trains
        let sents = Arc::new(vec![vec![0u32, 1, 2, 3, 4, 5, 6, 7]]);
        tr.train_epoch(&sents, 0).unwrap();
    }
}
