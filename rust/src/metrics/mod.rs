//! Training/benchmark metrics and experiment-row emission.

use crate::obs::{Histogram, StageTimes};
use crate::util::json::{obj, Json};

/// Negative-row traffic accounting — the training-side mirror of the
/// serving engine's `rows_loaded_per_query`.  A *load* is one syn1
/// negative row fetched from the shared model; a *use* is one
/// (context row × negative row) interaction served from whatever copy
/// the kernel holds.  `uses / loads` is the realized reuse factor the
/// paper's Section 3 analysis predicts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReuseCounters {
    pub neg_rows_loaded: u64,
    pub neg_row_uses: u64,
}

impl ReuseCounters {
    pub fn merge(&mut self, other: ReuseCounters) {
        self.neg_rows_loaded += other.neg_rows_loaded;
        self.neg_row_uses += other.neg_row_uses;
    }

    /// Interactions served per row loaded (0 when nothing was loaded).
    pub fn reuse_factor(&self) -> f64 {
        if self.neg_rows_loaded == 0 {
            0.0
        } else {
            self.neg_row_uses as f64 / self.neg_rows_loaded as f64
        }
    }
}

/// Per-epoch training metrics.
#[derive(Debug, Clone, Default)]
pub struct EpochReport {
    pub epoch: usize,
    /// Real words trained this epoch (post-subsampling).
    pub words: u64,
    pub batches: u64,
    /// Sum of per-sentence NS losses.
    pub loss_sum: f64,
    /// Mean NS loss per trained word.
    pub loss_per_word: f64,
    /// Wall-clock seconds for the epoch.
    pub seconds: f64,
    /// End-to-end training throughput (words/sec).
    pub words_per_sec: f64,
    /// Pure batching rate (words/sec, Table 1 metric).
    pub batching_rate: f64,
    /// Final learning rate of the epoch.
    pub lr_end: f32,
    /// Hogwild worker threads used (0 = not a Hogwild-driven epoch,
    /// 1 = the serial reference path).
    pub threads: usize,
    /// Negative syn1 rows fetched from the shared model (training-side
    /// mirror of the serving engine's rows-loaded accounting; 0 when
    /// the implementation doesn't measure it).
    pub neg_rows_loaded: u64,
    /// Context-row x negative-row interactions served from those loads.
    pub neg_row_uses: u64,
    /// Per-stage decomposition of worker busy time (corpus-iteration /
    /// context-ring / negative-block / update), summed across workers.
    /// Empty when the driver doesn't measure stages.
    pub stages: StageTimes,
    /// Total worker busy seconds across all threads (the quantity the
    /// stage sums reconcile against; `seconds` is the epoch wall time).
    pub busy_seconds: f64,
}

impl EpochReport {
    pub fn finalize(&mut self) {
        if self.seconds > 0.0 {
            self.words_per_sec = self.words as f64 / self.seconds;
        }
        if self.words > 0 {
            self.loss_per_word = self.loss_sum / self.words as f64;
        }
    }

    /// Negative-row interactions served per row loaded from the shared
    /// model (0 when unmeasured) — the realized reuse factor.
    pub fn neg_row_reuse(&self) -> f64 {
        ReuseCounters {
            neg_rows_loaded: self.neg_rows_loaded,
            neg_row_uses: self.neg_row_uses,
        }
        .reuse_factor()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("epoch", Json::Num(self.epoch as f64)),
            ("words", Json::Num(self.words as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("loss_per_word", Json::Num(self.loss_per_word)),
            ("seconds", Json::Num(self.seconds)),
            ("words_per_sec", Json::Num(self.words_per_sec)),
            ("batching_rate", Json::Num(self.batching_rate)),
            ("lr_end", Json::Num(self.lr_end as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("neg_rows_loaded", Json::Num(self.neg_rows_loaded as f64)),
            ("neg_row_uses", Json::Num(self.neg_row_uses as f64)),
            ("neg_row_reuse", Json::Num(self.neg_row_reuse())),
            ("stages", self.stages.to_json()),
            ("busy_seconds", Json::Num(self.busy_seconds)),
        ])
    }
}

/// Whole-run training metrics.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub implementation: String,
    pub epochs: Vec<EpochReport>,
}

impl TrainReport {
    pub fn total_words(&self) -> u64 {
        self.epochs.iter().map(|e| e.words).sum()
    }

    pub fn total_seconds(&self) -> f64 {
        self.epochs.iter().map(|e| e.seconds).sum()
    }

    /// Aggregate throughput over all epochs.
    pub fn words_per_sec(&self) -> f64 {
        let s = self.total_seconds();
        if s > 0.0 {
            self.total_words() as f64 / s
        } else {
            0.0
        }
    }

    /// First/last epoch loss — the convergence signal examples log.
    pub fn loss_trajectory(&self) -> (f64, f64) {
        let first = self.epochs.first().map(|e| e.loss_per_word).unwrap_or(0.0);
        let last = self.epochs.last().map(|e| e.loss_per_word).unwrap_or(0.0);
        (first, last)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("implementation", Json::Str(self.implementation.clone())),
            ("words_per_sec", Json::Num(self.words_per_sec())),
            (
                "epochs",
                Json::Arr(self.epochs.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }
}

/// Latency distribution summary for the serving engine.
///
/// Built from raw per-request nanosecond samples; quantiles use the
/// nearest-rank method on the sorted sample set.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyStats {
    pub count: u64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Completed queries per second over the observation window.
    pub qps: f64,
}

impl LatencyStats {
    /// Summarize raw nanosecond samples over `wall_seconds` of serving.
    pub fn from_nanos(samples: &[u64], wall_seconds: f64) -> Self {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        let q = |frac: f64| -> f64 {
            let idx = ((sorted.len() - 1) as f64 * frac).round() as usize;
            sorted[idx] as f64 / 1e3
        };
        LatencyStats {
            count: samples.len() as u64,
            p50_us: q(0.50),
            p99_us: q(0.99),
            max_us: *sorted.last().unwrap() as f64 / 1e3,
            qps: if wall_seconds > 0.0 {
                samples.len() as f64 / wall_seconds
            } else {
                0.0
            },
        }
    }

    /// Summarize a recorded [`Histogram`] over `wall_seconds` of serving.
    /// Quantiles interpolate inside log2 buckets (error bounded by one
    /// bucket's ~3% relative width); the max is exact.
    pub fn from_hist(hist: &Histogram, wall_seconds: f64) -> Self {
        if hist.is_empty() {
            return LatencyStats::default();
        }
        LatencyStats {
            count: hist.count(),
            p50_us: hist.quantile(0.50) / 1e3,
            p99_us: hist.quantile(0.99) / 1e3,
            max_us: hist.max_ns() as f64 / 1e3,
            qps: if wall_seconds > 0.0 {
                hist.count() as f64 / wall_seconds
            } else {
                0.0
            },
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("max_us", Json::Num(self.max_us)),
            ("qps", Json::Num(self.qps)),
        ])
    }
}

/// Per-route request-latency recorder for the HTTP front-end.
///
/// Each route keeps a constant-memory [`Histogram`] (count, exact
/// all-time max, and log2-bucketed quantiles come from it), so memory
/// never grows with traffic.  `record` is one short mutex hold per
/// request; `to_json` is what `GET /stats` embeds next to
/// [`crate::serve::ServeReport::to_json`], and [`Self::histograms`]
/// feeds the `GET /metrics` Prometheus exposition.
#[derive(Debug)]
pub struct RouteMetrics {
    inner: std::sync::Mutex<
        std::collections::BTreeMap<&'static str, Histogram>,
    >,
    /// Observation-window start: per-route qps is count over this span.
    created: std::time::Instant,
}

impl Default for RouteMetrics {
    fn default() -> Self {
        RouteMetrics::new()
    }
}

impl RouteMetrics {
    pub fn new() -> Self {
        RouteMetrics {
            inner: std::sync::Mutex::new(std::collections::BTreeMap::new()),
            created: std::time::Instant::now(),
        }
    }

    /// Record one served request on `route`.  Route names are `'static`
    /// on purpose: the router's label set is fixed, so arbitrary request
    /// paths can never grow the map without bound.
    pub fn record(&self, route: &'static str, elapsed: std::time::Duration) {
        let ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        let mut map = self.inner.lock().unwrap();
        map.entry(route).or_default().record(ns);
    }

    /// (route, stats) snapshot, route-name ordered.  `qps` is the
    /// route's count over the recorder's lifetime (the observation
    /// window starts when the server does); the engine report still
    /// carries the authoritative engine-side throughput number.
    pub fn snapshot(&self) -> Vec<(&'static str, LatencyStats)> {
        let window = self.created.elapsed().as_secs_f64();
        let map = self.inner.lock().unwrap();
        map.iter()
            .map(|(route, h)| (*route, LatencyStats::from_hist(h, window)))
            .collect()
    }

    /// Per-route histogram clones for the Prometheus exposition.
    pub fn histograms(&self) -> Vec<(&'static str, Histogram)> {
        let map = self.inner.lock().unwrap();
        map.iter().map(|(route, h)| (*route, h.clone())).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.snapshot()
                .into_iter()
                .map(|(route, stats)| (route.to_string(), stats.to_json()))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finalize_computes_rates() {
        let mut e = EpochReport {
            words: 1000,
            loss_sum: 2500.0,
            seconds: 2.0,
            ..Default::default()
        };
        e.finalize();
        assert!((e.words_per_sec - 500.0).abs() < 1e-9);
        assert!((e.loss_per_word - 2.5).abs() < 1e-9);
    }

    #[test]
    fn report_aggregates() {
        let mut r = TrainReport {
            implementation: "x".into(),
            epochs: vec![],
        };
        for i in 0..3 {
            let mut e = EpochReport {
                epoch: i,
                words: 100,
                loss_sum: (100 * (3 - i)) as f64,
                seconds: 1.0,
                ..Default::default()
            };
            e.finalize();
            r.epochs.push(e);
        }
        assert_eq!(r.total_words(), 300);
        assert!((r.words_per_sec() - 100.0).abs() < 1e-9);
        let (first, last) = r.loss_trajectory();
        assert!(first > last); // decreasing loss
    }

    #[test]
    fn neg_row_reuse_factor() {
        let e = EpochReport {
            neg_rows_loaded: 10,
            neg_row_uses: 250,
            ..Default::default()
        };
        assert!((e.neg_row_reuse() - 25.0).abs() < 1e-12);
        assert_eq!(EpochReport::default().neg_row_reuse(), 0.0);
    }

    #[test]
    fn latency_quantiles() {
        // 1..=100 microseconds, in nanos
        let samples: Vec<u64> = (1..=100u64).map(|x| x * 1_000).collect();
        let s = LatencyStats::from_nanos(&samples, 2.0);
        assert_eq!(s.count, 100);
        assert!((s.p50_us - 50.0).abs() <= 1.0);
        assert!((s.p99_us - 99.0).abs() <= 1.0);
        assert_eq!(s.max_us, 100.0);
        assert!((s.qps - 50.0).abs() < 1e-9);
        assert_eq!(LatencyStats::from_nanos(&[], 1.0), LatencyStats::default());
    }

    #[test]
    fn latency_stats_from_histogram() {
        let mut h = Histogram::new();
        for i in 1..=100u64 {
            h.record(i * 1_000);
        }
        let s = LatencyStats::from_hist(&h, 2.0);
        assert_eq!(s.count, 100);
        assert!((s.p50_us - 50.0).abs() <= 2.0, "p50 {}", s.p50_us);
        assert!((s.p99_us - 99.0).abs() <= 4.0, "p99 {}", s.p99_us);
        assert_eq!(s.max_us, 100.0, "max stays exact");
        assert!((s.qps - 50.0).abs() < 1e-9);
        assert_eq!(
            LatencyStats::from_hist(&Histogram::new(), 1.0),
            LatencyStats::default()
        );
    }

    #[test]
    fn route_metrics_record_and_bound() {
        use std::time::Duration;
        let m = RouteMetrics::new();
        assert!(m.snapshot().is_empty());
        for i in 1..=100u64 {
            m.record("nn", Duration::from_micros(i));
        }
        m.record("healthz", Duration::from_micros(5));
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
        let nn = &snap
            .iter()
            .find(|(r, _)| *r == "nn")
            .expect("nn route recorded")
            .1;
        assert_eq!(nn.count, 100);
        assert!((nn.p50_us - 50.0).abs() <= 2.0);
        assert_eq!(nn.max_us, 100.0);
        assert!(nn.qps > 0.0, "snapshot qps comes from the window now");
        // constant memory: heavy traffic only bumps counts, and the
        // all-time max survives
        for _ in 0..10_000 {
            m.record("nn", Duration::from_micros(1));
        }
        let snap = m.snapshot();
        let nn = &snap.iter().find(|(r, _)| *r == "nn").unwrap().1;
        assert_eq!(nn.count, 100 + 10_000);
        assert_eq!(nn.max_us, 100.0, "all-time max survives");
        let j = m.to_json().to_string();
        assert!(j.contains("\"nn\""));
        assert!(j.contains("\"healthz\""));
        assert_eq!(m.histograms().len(), 2);
    }

    #[test]
    fn json_emission() {
        let mut e = EpochReport { epoch: 1, words: 10, seconds: 1.0, ..Default::default() };
        e.finalize();
        let r = TrainReport { implementation: "t".into(), epochs: vec![e] };
        let j = r.to_json().to_string();
        assert!(j.contains("\"implementation\":\"t\""));
        assert!(j.contains("\"epochs\":["));
    }
}
