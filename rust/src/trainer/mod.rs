//! The CPU training layer: shard kernels + the Hogwild epoch driver.
//!
//! FULL-W2V's contribution (paper Sections 3–4) is a memory-tier
//! discipline: every embedding row should be fetched from the slow tier
//! once and then reused from a fast tier for as many interactions as its
//! lifetime allows.  This module realizes that discipline on CPU and
//! maps the paper's GPU hierarchy onto the host memory system:
//!
//! | paper tier (GPU)        | CPU realization (this module)              |
//! |-------------------------|--------------------------------------------|
//! | registers               | the **sliding context-window block** — the |
//! |                         | ring of `2*W_f+1` cached syn0 rows in      |
//! |                         | [`fullw2v`]; one row enters and one        |
//! |                         | retires per center-word advance, and all   |
//! |                         | window interactions hit the cached copies  |
//! | shared memory (SM)      | the **sentence-chunk negative block** —    |
//! |                         | `N` syn1 rows drawn and loaded once per    |
//! |                         | chunk, scored and updated in place for the |
//! |                         | chunk's whole lifetime, written back as    |
//! |                         | one delta per row at chunk end             |
//! | HBM / global memory     | the **shared model matrices** behind       |
//! |                         | [`SharedModel`] — the only tier worker     |
//! |                         | threads contend on, touched once per       |
//! |                         | row-lifetime instead of once per use       |
//!
//! Parallelism is Hogwild (the update discipline the paper inherits from
//! pWord2Vec): [`hogwild::run_epoch`] splits an epoch's sentences into
//! contiguous shards, one worker thread per shard, all workers updating
//! one [`SharedModel`] without locks.  Each worker owns a deterministic
//! [`Pcg32`] stream (worker 0's stream is the historical serial stream,
//! so `threads = 1` walks exactly the old serial path), and the linear
//! lr decay is driven by one atomic word counter shared by all workers.
//!
//! Every CPU implementation — the three comparator baselines in
//! [`crate::cpu_baseline`] and the [`fullw2v`] reference trainer — is a
//! [`ShardTrainer`] *chunk kernel*: per-thread scratch plus a
//! `train_chunk` method.  The driver owns everything else (subsampling,
//! chunking, lr, accounting), so the serial `epoch_loop` the baselines
//! used through PR 3 no longer exists.

pub mod fullw2v;
pub mod hogwild;

pub use fullw2v::FullW2vTrainer;

use crate::config::TrainConfig;
use crate::coordinator::lr::LrSchedule;
use crate::coordinator::SgnsTrainer;
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::model::{EmbeddingModel, SharedModel};
use crate::obs::StageTimes;
use crate::sampler::unigram::UnigramTable;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};

/// The stages every CPU epoch decomposes into — the observability
/// counterpart of the paper's Tables 4–6 memory-traffic breakdown.
/// `corpus_iteration` is driver-side work (subsampling, chunking, lr);
/// `context_ring` and `negative_block` are the two cached reuse tiers
/// a self-instrumenting kernel attributes internally; `update` is the
/// rest of the kernel (logits, gradients, scatters).  Indexed by the
/// `ST_*` constants below; [`hogwild::run_epoch`] merges per-worker
/// [`StageTimes`] into [`crate::metrics::EpochReport::stages`].
pub const TRAIN_STAGES: &[&str] =
    &["corpus_iteration", "context_ring", "negative_block", "update"];
pub const ST_CORPUS_ITERATION: usize = 0;
pub const ST_CONTEXT_RING: usize = 1;
pub const ST_NEGATIVE_BLOCK: usize = 2;
pub const ST_UPDATE: usize = 3;

/// Shared scaffolding for the CPU trainers: the model plus the
/// corpus-side tables and the lr schedule.  (Moved here from
/// `cpu_baseline` when the Hogwild driver replaced `epoch_loop`.)
pub(crate) struct BaseTrainer {
    pub model: EmbeddingModel,
    pub subsampler: Subsampler,
    pub negatives: UnigramTable,
    pub schedule: LrSchedule,
    pub cfg: TrainConfig,
}

impl BaseTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        BaseTrainer {
            model: EmbeddingModel::init(vocab.len(), cfg.dim, cfg.seed),
            subsampler: Subsampler::new(vocab, cfg.subsample),
            negatives: UnigramTable::new(vocab, UnigramTable::DEFAULT_ALPHA),
            schedule: LrSchedule::new(
                cfg.lr,
                cfg.min_lr_ratio,
                total_words_hint * cfg.epochs as u64,
            ),
            cfg: cfg.clone(),
        }
    }
}

/// Read-only epoch context the driver hands every shard kernel: the
/// Hogwild model view plus the sampling tables and hyperparameters.
pub struct ShardCtx<'a> {
    pub model: &'a SharedModel<'a>,
    pub negatives: &'a UnigramTable,
    pub cfg: &'a TrainConfig,
}

pub use crate::metrics::ReuseCounters;

/// One CPU SGNS update kernel: per-thread scratch plus the chunk update
/// rule.  The Hogwild driver constructs one kernel per worker thread and
/// feeds it subsampled sentence chunks; the kernel reads and writes the
/// shared model through `ctx.model` only.
pub trait ShardTrainer {
    /// Train one sentence chunk at learning rate `lr`; returns the
    /// summed NS loss computed from pre-update values (the same loss
    /// definition every serial baseline used).
    fn train_chunk(
        &mut self,
        ctx: &ShardCtx<'_>,
        chunk: &[u32],
        lr: f32,
        rng: &mut Pcg32,
    ) -> f64;

    /// Cumulative negative-row traffic since construction.
    fn reuse(&self) -> ReuseCounters {
        ReuseCounters::default()
    }

    /// Per-stage time the kernel attributes internally (the
    /// [`TRAIN_STAGES`] ring and negative-block tiers).  `None` for
    /// kernels that do not self-instrument — the driver then books all
    /// kernel time as `update`.
    fn stage_times(&self) -> Option<StageTimes> {
        None
    }
}

/// The CPU implementations `train --impl NAME` accepts.
pub const CPU_IMPLS: [&str; 4] = ["mikolov", "pword2vec", "psgnscc", "fullw2v"];

/// True if `name` names a CPU trainer (vs a PJRT kernel variant).
pub fn is_cpu_impl(name: &str) -> bool {
    CPU_IMPLS.contains(&name)
}

/// Build a CPU trainer by implementation name.  `total_words_hint` is
/// the corpus word count of **one epoch** — the constructor multiplies
/// by `cfg.epochs` (exactly like `Coordinator::new`), so the lr decays
/// over the full planned job.  Passing pre-multiplied words would
/// square the epoch factor and leave the lr nearly undecayed.
pub fn build_cpu_trainer(
    name: &str,
    cfg: &TrainConfig,
    vocab: &Vocab,
    total_words_hint: u64,
) -> Result<Box<dyn SgnsTrainer>> {
    Ok(match name {
        "mikolov" => Box::new(crate::cpu_baseline::MikolovTrainer::new(
            cfg,
            vocab,
            total_words_hint,
        )),
        "pword2vec" => Box::new(crate::cpu_baseline::PWord2VecTrainer::new(
            cfg,
            vocab,
            total_words_hint,
        )),
        "psgnscc" => Box::new(crate::cpu_baseline::PsgnsccTrainer::new(
            cfg,
            vocab,
            total_words_hint,
        )),
        "fullw2v" => {
            Box::new(FullW2vTrainer::new(cfg, vocab, total_words_hint))
        }
        other => {
            return Err(anyhow!(
                "unknown CPU implementation '{other}' (expected one of {})",
                CPU_IMPLS.join(", ")
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_impl_registry() {
        for name in CPU_IMPLS {
            assert!(is_cpu_impl(name));
        }
        assert!(!is_cpu_impl("full_w2v"), "pjrt variants are not cpu impls");
        assert!(build_cpu_trainer(
            "nope",
            &TrainConfig::default(),
            &Vocab::from_counts([("a".to_string(), 5u64)], 1),
            10,
        )
        .is_err());
    }

    #[test]
    fn reuse_counters_merge_and_factor() {
        let mut a = ReuseCounters { neg_rows_loaded: 5, neg_row_uses: 50 };
        a.merge(ReuseCounters { neg_rows_loaded: 5, neg_row_uses: 30 });
        assert_eq!(a.neg_rows_loaded, 10);
        assert_eq!(a.neg_row_uses, 80);
        assert!((a.reuse_factor() - 8.0).abs() < 1e-12);
        assert_eq!(ReuseCounters::default().reuse_factor(), 0.0);
    }
}
