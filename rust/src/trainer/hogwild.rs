//! The Hogwild epoch driver: shards an epoch's sentences over worker
//! threads that update one [`SharedModel`] without synchronization.
//!
//! Design invariants:
//!
//! * **Determinism at `threads = 1`.**  Worker 0's RNG stream is the
//!   stream the serial trainers historically used
//!   (`Pcg32::with_stream(seed ^ (epoch+1), 0xc9)`), and a single worker
//!   owns every sentence in order, so the one-thread path draws the
//!   exact sample sequence the pre-Hogwild `epoch_loop` drew and is
//!   bit-reproducible across runs.
//! * **Token-balanced shards.**  An epoch ends when its slowest worker
//!   does, and sentence *counts* are a bad proxy for work — a contiguous
//!   run of long sentences used to pile onto one shard and stretch the
//!   epoch's tail.  [`balanced_shards`] assigns sentences to workers by
//!   greedy token-count balancing (LPT: longest sentence first, each to
//!   the currently lightest shard — heaviest shard ≤ 4/3 · optimal),
//!   then restores corpus order within each shard, which keeps the
//!   single-shard (`threads = 1`) walk identical to the serial order.
//! * **Per-chunk accounting.**  The serial loop advanced the lr and
//!   counted `batches` once per *sentence* even when a sentence spanned
//!   several chunks — every chunk of a long sentence trained at a stale
//!   lr and the batch count undercounted the real unit of work.  The
//!   driver advances the shared atomic word counter and recomputes the
//!   lr per *chunk* (`LrSchedule::lr_at` over the observed count), and
//!   `EpochReport::batches` counts chunks.
//! * **One schedule, one counter.**  Workers never mutate the schedule;
//!   they `fetch_add` their chunk's word count and read the lr for the
//!   count they observed, which makes the decay identical to the serial
//!   walk at one thread and fair-interleaved at N.

use super::{
    BaseTrainer, ReuseCounters, ShardCtx, ShardTrainer, ST_CONTEXT_RING,
    ST_CORPUS_ITERATION, ST_NEGATIVE_BLOCK, ST_UPDATE, TRAIN_STAGES,
};
use crate::metrics::EpochReport;
use crate::model::SharedModel;
use crate::obs::{Span, StageTimes};
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};

/// The deterministic RNG for worker `tid` of `epoch`.  Worker 0
/// reproduces the serial trainers' historical epoch stream, which is
/// what makes `threads = 1` bit-identical to the old serial path.
pub fn worker_rng(seed: u64, epoch: usize, tid: usize) -> Pcg32 {
    Pcg32::with_stream(seed ^ (epoch as u64 + 1), 0xc9 ^ ((tid as u64) << 8))
}

#[derive(Default)]
struct Partial {
    loss: f64,
    words: u64,
    chunks: u64,
    reuse: ReuseCounters,
    /// This worker's [`TRAIN_STAGES`] decomposition of `busy_ns`.
    stages: StageTimes,
    /// Wall time this worker spent inside its shard loop — summed over
    /// workers it exceeds `EpochReport::seconds` whenever threads > 1,
    /// which is exactly the parallel-efficiency signal.
    busy_ns: u64,
}

/// Assign sentence indices to `shards` worker shards, balancing total
/// *token* count rather than sentence count.
///
/// Greedy LPT: visit sentences longest-first, place each on the shard
/// with the smallest running token load (ties to the lowest shard id,
/// and equal lengths keep ascending index order — the assignment is a
/// pure function of the length vector).  Each shard's index list is then
/// sorted back to corpus order, so with one shard the result is exactly
/// `0..n` and the `threads = 1` path stays bit-reproducible.  Shards may
/// come back empty when there are fewer sentences than shards; callers
/// skip those.
pub(crate) fn balanced_shards(
    lengths: &[usize],
    shards: usize,
) -> Vec<Vec<usize>> {
    let shards = shards.max(1);
    let mut order: Vec<usize> = (0..lengths.len()).collect();
    order.sort_by(|&a, &b| {
        lengths[b].cmp(&lengths[a]).then_with(|| a.cmp(&b))
    });
    let mut load = vec![0u64; shards];
    let mut out = vec![Vec::new(); shards];
    for idx in order {
        let lightest = (0..shards)
            .min_by_key(|&s| (load[s], s))
            .expect("at least one shard");
        load[lightest] += lengths[idx] as u64;
        out[lightest].push(idx);
    }
    for shard in &mut out {
        shard.sort_unstable();
    }
    out
}

/// Run one epoch of any [`ShardTrainer`] kernel over the sentences,
/// Hogwild-parallel across `base.cfg.resolved_threads()` workers.
/// `make_kernel(tid)` builds each worker's kernel (scratch) in-thread.
pub(crate) fn run_epoch<K, F>(
    base: &mut BaseTrainer,
    sentences: &[Vec<u32>],
    epoch: usize,
    make_kernel: F,
) -> EpochReport
where
    K: ShardTrainer,
    F: Fn(usize) -> K + Sync,
{
    let t0 = std::time::Instant::now();
    let threads = base.cfg.resolved_threads().max(1);
    let chunk_len = base.cfg.sentence_chunk;
    let seed = base.cfg.seed;
    let start_words = base.schedule.processed();
    let counter = AtomicU64::new(start_words);

    // token-balanced shard assignment (not contiguous equal sentence
    // counts): the epoch's wall clock is its heaviest shard's
    let lengths: Vec<usize> = sentences.iter().map(|s| s.len()).collect();
    let shard_indices = balanced_shards(&lengths, threads);
    let mut partials: Vec<Partial> = Vec::with_capacity(threads);
    let mut workers_used = 0usize;
    {
        // Disjoint field borrows: the model uniquely (for the Hogwild
        // view), everything else shared across the worker threads.
        let shared = SharedModel::new(&mut base.model);
        let subsampler = &base.subsampler;
        let negatives = &base.negatives;
        let cfg = &base.cfg;
        let schedule = &base.schedule;
        std::thread::scope(|s| {
            let handles: Vec<_> = shard_indices
                .iter()
                .enumerate()
                .filter(|(_, shard)| !shard.is_empty())
                .map(|(tid, shard)| {
                    let shared = &shared;
                    let counter = &counter;
                    let make_kernel = &make_kernel;
                    s.spawn(move || {
                        let mut kernel = make_kernel(tid);
                        let ctx = ShardCtx {
                            model: shared,
                            negatives,
                            cfg,
                        };
                        let mut rng = worker_rng(seed, epoch, tid);
                        let mut p = Partial::default();
                        let mut kept: Vec<u32> = Vec::new();
                        // lap clock: everything between kernel calls is
                        // corpus iteration (sentence walk, subsampling,
                        // chunking, lr), everything inside is kernel —
                        // contiguous laps tile the worker's busy time,
                        // so the stage sums reconcile by construction
                        let mut span = Span::start();
                        let mut corpus_ns = 0u64;
                        let mut kernel_ns = 0u64;
                        for &si in shard {
                            kept.clear();
                            kept.extend_from_slice(&sentences[si]);
                            subsampler.filter(&mut kept, &mut rng);
                            if kept.len() < 2 {
                                continue;
                            }
                            for c in kept.chunks(chunk_len) {
                                if c.len() < 2 {
                                    continue;
                                }
                                let seen = counter.fetch_add(
                                    c.len() as u64,
                                    Ordering::Relaxed,
                                );
                                let lr = schedule.lr_at(seen);
                                corpus_ns += span.lap_ns();
                                p.loss +=
                                    kernel.train_chunk(&ctx, c, lr, &mut rng);
                                kernel_ns += span.lap_ns();
                                p.words += c.len() as u64;
                                p.chunks += 1;
                            }
                        }
                        p.reuse = kernel.reuse();
                        let mut st = StageTimes::new(TRAIN_STAGES);
                        st.add(ST_CORPUS_ITERATION, corpus_ns);
                        if let Some(ks) = kernel.stage_times() {
                            st.merge(&ks);
                        }
                        // whatever kernel time the kernel did not claim
                        // for its cached tiers is the update phase
                        let claimed = st.get_ns(ST_CONTEXT_RING)
                            + st.get_ns(ST_NEGATIVE_BLOCK);
                        st.add(
                            ST_UPDATE,
                            kernel_ns.saturating_sub(claimed),
                        );
                        p.stages = st;
                        p.busy_ns = corpus_ns + kernel_ns;
                        p
                    })
                })
                .collect();
            workers_used = handles.len();
            for h in handles {
                partials.push(h.join().expect("hogwild worker panicked"));
            }
        });
    }

    let mut rep = EpochReport { epoch, ..Default::default() };
    let mut reuse = ReuseCounters::default();
    for p in &partials {
        rep.loss_sum += p.loss;
        rep.words += p.words;
        rep.batches += p.chunks;
        rep.stages.merge(&p.stages);
        // LINT: allow(kernel-purity): unit conversion on per-worker
        // report fields, not a vector kernel.
        rep.busy_seconds += p.busy_ns as f64 * 1e-9;
        reuse.merge(p.reuse);
    }
    debug_assert_eq!(
        counter.load(Ordering::Relaxed) - start_words,
        rep.words,
        "counter and partial word counts must agree"
    );
    base.schedule.advance(rep.words);
    rep.lr_end = base.schedule.current();
    rep.threads = workers_used;
    rep.neg_rows_loaded = reuse.neg_rows_loaded;
    rep.neg_row_uses = reuse.neg_row_uses;
    rep.seconds = t0.elapsed().as_secs_f64();
    rep.finalize();
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::coordinator::lr::LrSchedule;
    use crate::corpus::vocab::Vocab;
    use std::sync::Mutex;

    /// A probe kernel that records the (chunk length, lr) pairs the
    /// driver feeds it, so the per-chunk schedule is directly observable.
    struct ProbeKernel<'a> {
        seen: &'a Mutex<Vec<(usize, f32)>>,
    }

    impl ShardTrainer for ProbeKernel<'_> {
        fn train_chunk(
            &mut self,
            _ctx: &ShardCtx<'_>,
            chunk: &[u32],
            lr: f32,
            _rng: &mut Pcg32,
        ) -> f64 {
            self.seen.lock().unwrap().push((chunk.len(), lr));
            chunk.len() as f64
        }
    }

    fn probe_base(chunk: usize, total_hint: u64) -> (BaseTrainer, Vocab) {
        let vocab =
            Vocab::from_counts((0..16).map(|i| (format!("w{i}"), 50u64)), 1);
        let cfg = TrainConfig {
            dim: 4,
            window: 2,
            negatives: 2,
            subsample: 0.0,
            sentence_chunk: chunk,
            epochs: 1,
            ..TrainConfig::default()
        };
        (BaseTrainer::new(&cfg, &vocab, total_hint), vocab)
    }

    /// The satellite bugfix pinned down: a sentence spanning several
    /// chunks advances the lr once per chunk (not once per sentence),
    /// and `batches` counts chunks.
    #[test]
    fn hogwild_lr_and_batches_advance_per_chunk() {
        let (mut base, _vocab) = probe_base(8, 32);
        // one 32-word sentence -> 4 chunks of 8
        let sentences = vec![(0..32u32).map(|i| i % 16).collect::<Vec<_>>()];
        let seen = Mutex::new(Vec::new());
        let rep = run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel {
            seen: &seen,
        });
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 4, "4 chunks trained");
        assert_eq!(rep.batches, 4, "batches must count chunks");
        assert_eq!(rep.words, 32);
        // per-chunk lr: chunk k trains at lr_at(8k), strictly decaying
        let probe = LrSchedule::new(
            base.cfg.lr,
            base.cfg.min_lr_ratio,
            32 * base.cfg.epochs as u64,
        );
        for (k, &(len, lr)) in seen.iter().enumerate() {
            assert_eq!(len, 8);
            assert_eq!(
                lr.to_bits(),
                probe.lr_at(8 * k as u64).to_bits(),
                "chunk {k} lr"
            );
        }
        assert!(seen[3].1 < seen[0].1, "lr decays within the sentence");
        assert_eq!(rep.lr_end.to_bits(), probe.lr_at(32).to_bits());
        assert_eq!(rep.threads, 1);
    }

    #[test]
    fn hogwild_word_counter_persists_across_epochs() {
        let (mut base, _vocab) = probe_base(8, 64);
        let sentences = vec![(0..16u32).collect::<Vec<_>>()];
        let seen = Mutex::new(Vec::new());
        run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel { seen: &seen });
        assert_eq!(base.schedule.processed(), 16);
        run_epoch(&mut base, &sentences, 1, |_tid| ProbeKernel { seen: &seen });
        assert_eq!(base.schedule.processed(), 32);
        let seen = seen.into_inner().unwrap();
        // epoch 1's first chunk already sees epoch 0's words
        let probe = LrSchedule::new(base.cfg.lr, base.cfg.min_lr_ratio, 64);
        assert_eq!(seen[2].1.to_bits(), probe.lr_at(16).to_bits());
    }

    #[test]
    fn hogwild_splits_work_across_threads() {
        let (mut base, _vocab) = probe_base(8, 1000);
        base.cfg.threads = 3;
        let sentences: Vec<Vec<u32>> =
            (0..9).map(|_| (0..8u32).collect()).collect();
        let seen = Mutex::new(Vec::new());
        let rep = run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel {
            seen: &seen,
        });
        assert_eq!(rep.threads, 3);
        assert_eq!(rep.words, 72);
        assert_eq!(rep.batches, 9);
        // more workers than shards degrades gracefully
        base.cfg.threads = 64;
        let rep = run_epoch(&mut base, &sentences, 1, |_tid| ProbeKernel {
            seen: &seen,
        });
        assert!(rep.threads <= 9, "at most one worker per sentence shard");
        assert_eq!(rep.words, 72);
    }

    /// The ROADMAP skew satellite pinned down: one pathologically long
    /// sentence plus many short ones must not land half the tokens on
    /// one worker the way contiguous equal-sentence-count splits did.
    #[test]
    fn balanced_shards_balance_token_counts() {
        let mut lengths = vec![100usize];
        lengths.extend(std::iter::repeat(1).take(100));
        let shards = balanced_shards(&lengths, 2);
        let load = |s: &Vec<usize>| -> u64 {
            s.iter().map(|&i| lengths[i] as u64).sum()
        };
        let (l0, l1) = (load(&shards[0]), load(&shards[1]));
        assert_eq!(l0 + l1, 200, "every token assigned exactly once");
        // LPT on this shape is a perfect 100/100 split; the old
        // contiguous split put 100 + 50 = 150 tokens on shard 0
        assert_eq!(l0.max(l1), 100, "got {l0}/{l1}");
        // each sentence appears exactly once, in corpus order per shard
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..101).collect::<Vec<_>>());
        for s in &shards {
            assert!(s.windows(2).all(|w| w[0] < w[1]), "corpus order");
        }
        // pure function of the lengths: identical across calls
        assert_eq!(shards, balanced_shards(&lengths, 2));
    }

    #[test]
    fn balanced_shards_single_shard_is_identity() {
        // threads = 1 must walk the corpus in original order — this is
        // what keeps the single-thread path bit-reproducible
        assert_eq!(
            balanced_shards(&[3, 1, 4, 1, 5], 1),
            vec![vec![0, 1, 2, 3, 4]]
        );
        // more shards than sentences: singleton shards, the rest empty
        let shards = balanced_shards(&[2, 2], 4);
        assert_eq!(shards.iter().filter(|s| !s.is_empty()).count(), 2);
        assert!(balanced_shards(&[], 3).iter().all(|s| s.is_empty()));
    }

    /// A probe kernel that attributes chunks to the worker that trained
    /// them, so the driver-level token balance is directly observable.
    struct TidProbeKernel<'a> {
        tid: usize,
        seen: &'a Mutex<Vec<(usize, usize)>>,
    }

    impl ShardTrainer for TidProbeKernel<'_> {
        fn train_chunk(
            &mut self,
            _ctx: &ShardCtx<'_>,
            chunk: &[u32],
            _lr: f32,
            _rng: &mut Pcg32,
        ) -> f64 {
            self.seen.lock().unwrap().push((self.tid, chunk.len()));
            0.0
        }
    }

    /// End-to-end skew regression: 4 long sentences at the front of the
    /// corpus followed by 32 short ones.  The old contiguous split gave
    /// worker 0 all four long sentences (156 of 192 tokens); balanced
    /// shards must keep both workers within a few tokens of half.
    #[test]
    fn hogwild_shards_are_token_balanced_not_sentence_balanced() {
        let (mut base, _vocab) = probe_base(64, 1000);
        base.cfg.threads = 2;
        let mut sentences: Vec<Vec<u32>> =
            (0..4).map(|_| (0..32u32).map(|i| i % 16).collect()).collect();
        sentences
            .extend((0..32).map(|_| vec![0u32, 1]));
        let seen = Mutex::new(Vec::new());
        let rep = run_epoch(&mut base, &sentences, 0, |tid| TidProbeKernel {
            tid,
            seen: &seen,
        });
        assert_eq!(rep.threads, 2);
        assert_eq!(rep.words, 4 * 32 + 32 * 2);
        let mut per_tid = [0u64; 2];
        for &(tid, words) in seen.lock().unwrap().iter() {
            per_tid[tid] += words as u64;
        }
        let (a, b) = (per_tid[0], per_tid[1]);
        assert_eq!(a + b, 192);
        assert!(
            a.abs_diff(b) <= 8,
            "token skew {a}/{b}: shards must balance tokens \
             (contiguous splits gave 156/36)"
        );
    }

    /// Stage decomposition: an uninstrumented kernel books all kernel
    /// time as `update`, the lap clock tiles each worker's busy time so
    /// the stage sum reconciles, and the merged report carries every
    /// stage key in its JSON.
    #[test]
    fn epoch_report_stages_reconcile_with_busy_time() {
        let (mut base, _vocab) = probe_base(8, 256);
        base.cfg.threads = 2;
        let sentences: Vec<Vec<u32>> =
            (0..8).map(|_| (0..16u32).map(|i| i % 16).collect()).collect();
        let seen = Mutex::new(Vec::new());
        let rep = run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel {
            seen: &seen,
        });
        assert_eq!(rep.stages.names(), TRAIN_STAGES);
        assert!(rep.busy_seconds > 0.0);
        let stage_sum = rep.stages.total_ns() as f64 * 1e-9;
        let drift = (stage_sum - rep.busy_seconds).abs();
        assert!(
            drift <= rep.busy_seconds * 0.01 + 1e-3,
            "stage sum {stage_sum}s vs busy {}s",
            rep.busy_seconds
        );
        // ProbeKernel does not self-instrument: the cached-tier stages
        // stay zero and its kernel time lands in `update`
        assert_eq!(rep.stages.get_ns(ST_CONTEXT_RING), 0);
        assert_eq!(rep.stages.get_ns(ST_NEGATIVE_BLOCK), 0);
        let j = rep.to_json();
        let stages = j.get("stages").expect("report JSON carries stages");
        for s in TRAIN_STAGES {
            assert!(stages.get(s).is_some(), "missing stage key {s}");
        }
        assert!(j.get("busy_seconds").is_some());
    }

    #[test]
    fn worker_streams_are_distinct_and_worker0_is_the_serial_stream() {
        let mut a = worker_rng(7, 0, 0);
        let mut b = Pcg32::with_stream(7 ^ 1, 0xc9);
        for _ in 0..16 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut r0 = worker_rng(7, 0, 0);
        let mut r1 = worker_rng(7, 0, 1);
        let s0: Vec<u32> = (0..8).map(|_| r0.next_u32()).collect();
        let s1: Vec<u32> = (0..8).map(|_| r1.next_u32()).collect();
        assert_ne!(s0, s1);
    }
}
