//! The Hogwild epoch driver: shards an epoch's sentences over worker
//! threads that update one [`SharedModel`] without synchronization.
//!
//! Design invariants:
//!
//! * **Determinism at `threads = 1`.**  Worker 0's RNG stream is the
//!   stream the serial trainers historically used
//!   (`Pcg32::with_stream(seed ^ (epoch+1), 0xc9)`), and a single worker
//!   owns every sentence in order, so the one-thread path draws the
//!   exact sample sequence the pre-Hogwild `epoch_loop` drew and is
//!   bit-reproducible across runs.
//! * **Per-chunk accounting.**  The serial loop advanced the lr and
//!   counted `batches` once per *sentence* even when a sentence spanned
//!   several chunks — every chunk of a long sentence trained at a stale
//!   lr and the batch count undercounted the real unit of work.  The
//!   driver advances the shared atomic word counter and recomputes the
//!   lr per *chunk* (`LrSchedule::lr_at` over the observed count), and
//!   `EpochReport::batches` counts chunks.
//! * **One schedule, one counter.**  Workers never mutate the schedule;
//!   they `fetch_add` their chunk's word count and read the lr for the
//!   count they observed, which makes the decay identical to the serial
//!   walk at one thread and fair-interleaved at N.

use super::{BaseTrainer, ReuseCounters, ShardCtx, ShardTrainer};
use crate::metrics::EpochReport;
use crate::model::SharedModel;
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};

/// The deterministic RNG for worker `tid` of `epoch`.  Worker 0
/// reproduces the serial trainers' historical epoch stream, which is
/// what makes `threads = 1` bit-identical to the old serial path.
pub fn worker_rng(seed: u64, epoch: usize, tid: usize) -> Pcg32 {
    Pcg32::with_stream(seed ^ (epoch as u64 + 1), 0xc9 ^ ((tid as u64) << 8))
}

#[derive(Default)]
struct Partial {
    loss: f64,
    words: u64,
    chunks: u64,
    reuse: ReuseCounters,
}

/// Run one epoch of any [`ShardTrainer`] kernel over the sentences,
/// Hogwild-parallel across `base.cfg.resolved_threads()` workers.
/// `make_kernel(tid)` builds each worker's kernel (scratch) in-thread.
pub(crate) fn run_epoch<K, F>(
    base: &mut BaseTrainer,
    sentences: &[Vec<u32>],
    epoch: usize,
    make_kernel: F,
) -> EpochReport
where
    K: ShardTrainer,
    F: Fn(usize) -> K + Sync,
{
    let t0 = std::time::Instant::now();
    let threads = base.cfg.resolved_threads().max(1);
    let chunk_len = base.cfg.sentence_chunk;
    let seed = base.cfg.seed;
    let start_words = base.schedule.processed();
    let counter = AtomicU64::new(start_words);

    let shard_size = sentences.len().div_ceil(threads).max(1);
    let mut partials: Vec<Partial> = Vec::with_capacity(threads);
    let mut workers_used = 0usize;
    {
        // Disjoint field borrows: the model uniquely (for the Hogwild
        // view), everything else shared across the worker threads.
        let shared = SharedModel::new(&mut base.model);
        let subsampler = &base.subsampler;
        let negatives = &base.negatives;
        let cfg = &base.cfg;
        let schedule = &base.schedule;
        std::thread::scope(|s| {
            let handles: Vec<_> = sentences
                .chunks(shard_size)
                .enumerate()
                .map(|(tid, shard)| {
                    let shared = &shared;
                    let counter = &counter;
                    let make_kernel = &make_kernel;
                    s.spawn(move || {
                        let mut kernel = make_kernel(tid);
                        let ctx = ShardCtx {
                            model: shared,
                            negatives,
                            cfg,
                        };
                        let mut rng = worker_rng(seed, epoch, tid);
                        let mut p = Partial::default();
                        let mut kept: Vec<u32> = Vec::new();
                        for sent in shard {
                            kept.clear();
                            kept.extend_from_slice(sent);
                            subsampler.filter(&mut kept, &mut rng);
                            if kept.len() < 2 {
                                continue;
                            }
                            for c in kept.chunks(chunk_len) {
                                if c.len() < 2 {
                                    continue;
                                }
                                let seen = counter.fetch_add(
                                    c.len() as u64,
                                    Ordering::Relaxed,
                                );
                                let lr = schedule.lr_at(seen);
                                p.loss +=
                                    kernel.train_chunk(&ctx, c, lr, &mut rng);
                                p.words += c.len() as u64;
                                p.chunks += 1;
                            }
                        }
                        p.reuse = kernel.reuse();
                        p
                    })
                })
                .collect();
            workers_used = handles.len();
            for h in handles {
                partials.push(h.join().expect("hogwild worker panicked"));
            }
        });
    }

    let mut rep = EpochReport { epoch, ..Default::default() };
    let mut reuse = ReuseCounters::default();
    for p in &partials {
        rep.loss_sum += p.loss;
        rep.words += p.words;
        rep.batches += p.chunks;
        reuse.merge(p.reuse);
    }
    debug_assert_eq!(
        counter.load(Ordering::Relaxed) - start_words,
        rep.words,
        "counter and partial word counts must agree"
    );
    base.schedule.advance(rep.words);
    rep.lr_end = base.schedule.current();
    rep.threads = workers_used;
    rep.neg_rows_loaded = reuse.neg_rows_loaded;
    rep.neg_row_uses = reuse.neg_row_uses;
    rep.seconds = t0.elapsed().as_secs_f64();
    rep.finalize();
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::coordinator::lr::LrSchedule;
    use crate::corpus::vocab::Vocab;
    use std::sync::Mutex;

    /// A probe kernel that records the (chunk length, lr) pairs the
    /// driver feeds it, so the per-chunk schedule is directly observable.
    struct ProbeKernel<'a> {
        seen: &'a Mutex<Vec<(usize, f32)>>,
    }

    impl ShardTrainer for ProbeKernel<'_> {
        fn train_chunk(
            &mut self,
            _ctx: &ShardCtx<'_>,
            chunk: &[u32],
            lr: f32,
            _rng: &mut Pcg32,
        ) -> f64 {
            self.seen.lock().unwrap().push((chunk.len(), lr));
            chunk.len() as f64
        }
    }

    fn probe_base(chunk: usize, total_hint: u64) -> (BaseTrainer, Vocab) {
        let vocab =
            Vocab::from_counts((0..16).map(|i| (format!("w{i}"), 50u64)), 1);
        let cfg = TrainConfig {
            dim: 4,
            window: 2,
            negatives: 2,
            subsample: 0.0,
            sentence_chunk: chunk,
            epochs: 1,
            ..TrainConfig::default()
        };
        (BaseTrainer::new(&cfg, &vocab, total_hint), vocab)
    }

    /// The satellite bugfix pinned down: a sentence spanning several
    /// chunks advances the lr once per chunk (not once per sentence),
    /// and `batches` counts chunks.
    #[test]
    fn hogwild_lr_and_batches_advance_per_chunk() {
        let (mut base, _vocab) = probe_base(8, 32);
        // one 32-word sentence -> 4 chunks of 8
        let sentences = vec![(0..32u32).map(|i| i % 16).collect::<Vec<_>>()];
        let seen = Mutex::new(Vec::new());
        let rep = run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel {
            seen: &seen,
        });
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 4, "4 chunks trained");
        assert_eq!(rep.batches, 4, "batches must count chunks");
        assert_eq!(rep.words, 32);
        // per-chunk lr: chunk k trains at lr_at(8k), strictly decaying
        let probe = LrSchedule::new(
            base.cfg.lr,
            base.cfg.min_lr_ratio,
            32 * base.cfg.epochs as u64,
        );
        for (k, &(len, lr)) in seen.iter().enumerate() {
            assert_eq!(len, 8);
            assert_eq!(
                lr.to_bits(),
                probe.lr_at(8 * k as u64).to_bits(),
                "chunk {k} lr"
            );
        }
        assert!(seen[3].1 < seen[0].1, "lr decays within the sentence");
        assert_eq!(rep.lr_end.to_bits(), probe.lr_at(32).to_bits());
        assert_eq!(rep.threads, 1);
    }

    #[test]
    fn hogwild_word_counter_persists_across_epochs() {
        let (mut base, _vocab) = probe_base(8, 64);
        let sentences = vec![(0..16u32).collect::<Vec<_>>()];
        let seen = Mutex::new(Vec::new());
        run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel { seen: &seen });
        assert_eq!(base.schedule.processed(), 16);
        run_epoch(&mut base, &sentences, 1, |_tid| ProbeKernel { seen: &seen });
        assert_eq!(base.schedule.processed(), 32);
        let seen = seen.into_inner().unwrap();
        // epoch 1's first chunk already sees epoch 0's words
        let probe = LrSchedule::new(base.cfg.lr, base.cfg.min_lr_ratio, 64);
        assert_eq!(seen[2].1.to_bits(), probe.lr_at(16).to_bits());
    }

    #[test]
    fn hogwild_splits_work_across_threads() {
        let (mut base, _vocab) = probe_base(8, 1000);
        base.cfg.threads = 3;
        let sentences: Vec<Vec<u32>> =
            (0..9).map(|_| (0..8u32).collect()).collect();
        let seen = Mutex::new(Vec::new());
        let rep = run_epoch(&mut base, &sentences, 0, |_tid| ProbeKernel {
            seen: &seen,
        });
        assert_eq!(rep.threads, 3);
        assert_eq!(rep.words, 72);
        assert_eq!(rep.batches, 9);
        // more workers than shards degrades gracefully
        base.cfg.threads = 64;
        let rep = run_epoch(&mut base, &sentences, 1, |_tid| ProbeKernel {
            seen: &seen,
        });
        assert!(rep.threads <= 9, "at most one worker per sentence shard");
        assert_eq!(rep.words, 72);
    }

    #[test]
    fn worker_streams_are_distinct_and_worker0_is_the_serial_stream() {
        let mut a = worker_rng(7, 0, 0);
        let mut b = Pcg32::with_stream(7 ^ 1, 0xc9);
        for _ in 0..16 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut r0 = worker_rng(7, 0, 0);
        let mut r1 = worker_rng(7, 0, 1);
        let s0: Vec<u32> = (0..8).map(|_| r0.next_u32()).collect();
        let s1: Vec<u32> = (0..8).map(|_| r1.next_u32()).collect();
        assert_ne!(s0, s1);
    }
}
