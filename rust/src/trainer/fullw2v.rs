//! The FULL-W2V reference CPU trainer: both of the paper's reuse axes,
//! realized on the host memory hierarchy.
//!
//! * **Lifetime of negatives (paper Section 3.3, shared-memory tier).**
//!   The `N` negative samples are drawn **once per sentence chunk** and
//!   their syn1 rows are loaded into a chunk-lifetime scratch block.
//!   Every window in the chunk scores against and updates the cached
//!   rows; the shared model sees exactly one delta write-back per
//!   negative row per chunk.  Global traffic for negatives drops from
//!   `O(windows x N)` row loads to `O(N)` per chunk — the dominant term
//!   in the paper's 89% access reduction.
//! * **Sliding context window (paper Section 3.2, register tier).**  The
//!   `2*W_f + 1` syn0 rows around the center live in a ring of cached
//!   copies.  Advancing the center by one position swaps exactly one
//!   row: the row leaving on the left retires (its accumulated delta is
//!   written back), the row entering on the right is loaded.  All window
//!   interactions — scores and gradient accumulation — hit the cached
//!   copies, so each syn0 row is loaded and stored once per chunk
//!   regardless of how many windows it participates in.
//!
//! The update rule is pWord2Vec's window-matrix SGNS (the same rule the
//! paper's kernels implement): per window, logits and gradients are
//! computed from pre-update operands, context rows accumulate
//! `G x U`, the center's syn1 row takes `g_pos^T x C` immediately, and
//! negative rows accumulate `G_neg^T x C` in the chunk block.  All row
//! math goes through the `vecops` kernels — [`dot_block`] scores one
//! cached context row against the whole negative block in a single
//! fused pass, and [`axpy_block`] scatters one gradient column into
//! every cached window row.
//!
//! Deferred write-back is the one semantic difference from the serial
//! comparators: if a negative's id also occurs as a center/context word
//! inside the same chunk, those reads see the row as of chunk start.
//! The paper makes exactly this trade (Section 3.3: delayed negative
//! updates "do not measurably affect convergence"); the quality
//! integration tests bound the effect.

use super::{
    hogwild, BaseTrainer, ReuseCounters, ShardCtx, ShardTrainer,
    ST_CONTEXT_RING, ST_NEGATIVE_BLOCK, TRAIN_STAGES,
};
use crate::config::TrainConfig;
use crate::coordinator::SgnsTrainer;
use crate::corpus::vocab::Vocab;
use crate::metrics::EpochReport;
use crate::model::EmbeddingModel;
use crate::obs::StageTimes;
use crate::util::rng::Pcg32;
use crate::vecops::{axpy, axpy_block, dot, dot_block, sigmoid, softplus};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

pub struct FullW2vTrainer {
    base: BaseTrainer,
}

impl FullW2vTrainer {
    pub fn new(cfg: &TrainConfig, vocab: &Vocab, total_words_hint: u64) -> Self {
        FullW2vTrainer {
            base: BaseTrainer::new(cfg, vocab, total_words_hint),
        }
    }
}

impl SgnsTrainer for FullW2vTrainer {
    fn name(&self) -> String {
        "fullw2v (cpu reference)".into()
    }

    fn train_epoch(
        &mut self,
        sentences: &Arc<Vec<Vec<u32>>>,
        epoch: usize,
    ) -> Result<EpochReport> {
        Ok(hogwild::run_epoch(&mut self.base, sentences, epoch, |_tid| {
            FullW2vKernel::default()
        }))
    }

    fn model(&self) -> &EmbeddingModel {
        &self.base.model
    }

    fn model_mut(&mut self) -> &mut EmbeddingModel {
        &mut self.base.model
    }
}

/// Per-thread kernel state: the two cached tiers plus window scratch.
#[derive(Default)]
pub struct FullW2vKernel {
    // chunk-lifetime negative block (shared-memory tier analogue)
    negs: Vec<u32>,
    neg_cur: Vec<f32>,  // N x d live working rows
    neg_orig: Vec<f32>, // values at chunk start, for the delta write-back
    // sliding window ring (register tier analogue), slot = position % cap
    win_ids: Vec<u32>,
    win_cur: Vec<f32>,  // cap x d live working rows
    win_orig: Vec<f32>, // values at load, for the retire write-back
    // per-window scratch
    u_center: Vec<f32>,  // d — fresh copy of the center's syn1 row
    z_pos: Vec<f32>,     // m logits vs the center
    z_neg: Vec<f32>,     // m x N logits vs the negative block (row-major)
    g_pos: Vec<f32>,     // m positive-column gradients
    g_negt: Vec<f32>,    // N x m negative gradients, column-contiguous
    dc: Vec<f32>,        // m x d context-row delta
    du_center: Vec<f32>, // d
    delta: Vec<f32>,     // d write-back buffer
    reuse: ReuseCounters,
    /// Time spent in the two cached tiers ([`TRAIN_STAGES`]): ring
    /// loads/retires under `context_ring`, negative draws/loads and the
    /// chunk-end delta write-back under `negative_block`.  The Hogwild
    /// driver books the rest of the kernel's time as `update`.
    stages: StageTimes,
}

impl FullW2vKernel {
    fn ensure_capacity(&mut self, d: usize, wf: usize, n_neg: usize) {
        let cap = 2 * wf + 1;
        let m_max = 2 * wf;
        self.negs.resize(n_neg, 0);
        self.neg_cur.resize(n_neg * d, 0.0);
        self.neg_orig.resize(n_neg * d, 0.0);
        self.win_ids.resize(cap, 0);
        self.win_cur.resize(cap * d, 0.0);
        self.win_orig.resize(cap * d, 0.0);
        self.u_center.resize(d, 0.0);
        self.z_pos.resize(m_max, 0.0);
        self.z_neg.resize(m_max * n_neg, 0.0);
        self.g_pos.resize(m_max, 0.0);
        self.g_negt.resize(n_neg * m_max, 0.0);
        self.dc.resize(m_max * d, 0.0);
        self.du_center.resize(d, 0.0);
        self.delta.resize(d, 0.0);
    }

    /// Admit position `p` into the ring: record its id and cache its
    /// syn0 row (one global load per position per chunk).
    fn load_slot(&mut self, ctx: &ShardCtx<'_>, sent: &[u32], p: usize, cap: usize, d: usize) {
        let slot = p % cap;
        let s = slot * d;
        let id = sent[p];
        self.win_ids[slot] = id;
        ctx.model.copy_syn0_row(id, &mut self.win_cur[s..s + d]);
        self.win_orig[s..s + d].copy_from_slice(&self.win_cur[s..s + d]);
    }

    /// Retire position `p`: write its accumulated delta back to the
    /// shared model (one global store per position per chunk).
    fn flush_slot(&mut self, ctx: &ShardCtx<'_>, p: usize, cap: usize, d: usize) {
        let slot = p % cap;
        let s = slot * d;
        for j in 0..d {
            self.delta[j] = self.win_cur[s + j] - self.win_orig[s + j];
        }
        ctx.model.add_syn0_row(self.win_ids[slot], &self.delta[..d]);
    }
}

impl ShardTrainer for FullW2vKernel {
    fn train_chunk(
        &mut self,
        ctx: &ShardCtx<'_>,
        sent: &[u32],
        lr: f32,
        rng: &mut Pcg32,
    ) -> f64 {
        let d = ctx.model.dim();
        let wf = ctx.cfg.fixed_width();
        let n_neg = ctx.cfg.negatives;
        let cap = 2 * wf + 1;
        let len = sent.len();
        debug_assert!(len >= 2, "driver filters degenerate chunks");
        self.ensure_capacity(d, wf, n_neg);
        self.stages.ensure(TRAIN_STAGES);

        // Chunk-lifetime negatives: drawn once, rows loaded once.  A
        // negative that collides with a center is skipped at use time
        // (word2vec.c's `target == word` rule), not redrawn, so the
        // block stays valid for every window in the chunk.
        let tick = Instant::now();
        for k in 0..n_neg {
            let g = ctx.negatives.sample(rng);
            self.negs[k] = g;
            ctx.model.copy_syn1_row(g, &mut self.neg_cur[k * d..(k + 1) * d]);
        }
        self.neg_orig[..n_neg * d].copy_from_slice(&self.neg_cur[..n_neg * d]);
        self.reuse.neg_rows_loaded += n_neg as u64;
        self.stages
            .add(ST_NEGATIVE_BLOCK, tick.elapsed().as_nanos() as u64);

        // Prime the ring with the first window's rows.
        let tick = Instant::now();
        for p in 0..=wf.min(len - 1) {
            self.load_slot(ctx, sent, p, cap, d);
        }
        self.stages
            .add(ST_CONTEXT_RING, tick.elapsed().as_nanos() as u64);

        let mut loss = 0.0f64;
        for t in 0..len {
            if t > 0 {
                // Slide: the retiring position and the entering one map
                // to the same ring slot (they differ by exactly cap), so
                // retire first, then admit.
                let tick = Instant::now();
                if t > wf {
                    self.flush_slot(ctx, t - wf - 1, cap, d);
                }
                let enter = t + wf;
                if enter < len {
                    self.load_slot(ctx, sent, enter, cap, d);
                }
                self.stages
                    .add(ST_CONTEXT_RING, tick.elapsed().as_nanos() as u64);
            }
            let center = sent[t];
            let lo = t.saturating_sub(wf);
            let hi = (t + wf).min(len - 1);
            let m = hi - lo; // window size minus the center itself
            if m == 0 {
                continue;
            }
            // The center's output row is the only per-window global
            // read: copied fresh, updated immediately after the window.
            ctx.model.copy_syn1_row(center, &mut self.u_center[..d]);

            // Phase 1: logits from pre-update operands.  Each cached
            // context row scores against the whole negative block in
            // one fused pass.
            let mut i = 0;
            for p in lo..=hi {
                if p == t {
                    continue;
                }
                let s = (p % cap) * d;
                self.z_pos[i] =
                    dot(&self.win_cur[s..s + d], &self.u_center[..d]);
                if n_neg > 0 {
                    dot_block(
                        &self.neg_cur[..n_neg * d],
                        d,
                        &self.win_cur[s..s + d],
                        &mut self.z_neg[i * n_neg..(i + 1) * n_neg],
                    );
                }
                i += 1;
            }
            self.reuse.neg_row_uses += (m * n_neg) as u64;

            // Phase 2: gradients (transposed so each negative's column
            // is contiguous for the scatter) + pre-update loss.
            for i in 0..m {
                let z = self.z_pos[i];
                self.g_pos[i] = (1.0 - sigmoid(z)) * lr;
                loss += softplus(-z);
                for k in 0..n_neg {
                    if self.negs[k] == center {
                        self.g_negt[k * m + i] = 0.0;
                        continue;
                    }
                    let z = self.z_neg[i * n_neg + k];
                    self.g_negt[k * m + i] = (0.0 - sigmoid(z)) * lr;
                    loss += softplus(z);
                }
            }

            // Phase 3a: dC = G x U from the pre-update U copies — one
            // fused column scatter per output row.
            self.dc[..m * d].iter_mut().for_each(|x| *x = 0.0);
            axpy_block(
                &self.g_pos[..m],
                &self.u_center[..d],
                &mut self.dc[..m * d],
                d,
            );
            for k in 0..n_neg {
                axpy_block(
                    &self.g_negt[k * m..(k + 1) * m],
                    &self.neg_cur[k * d..(k + 1) * d],
                    &mut self.dc[..m * d],
                    d,
                );
            }

            // Phase 3b: dU = G^T x C from the pre-update context rows
            // (the ring is untouched until phase 3c).
            self.du_center[..d].iter_mut().for_each(|x| *x = 0.0);
            let mut i = 0;
            for p in lo..=hi {
                if p == t {
                    continue;
                }
                let s = (p % cap) * d;
                axpy(
                    self.g_pos[i],
                    &self.win_cur[s..s + d],
                    &mut self.du_center[..d],
                );
                for k in 0..n_neg {
                    let gk = self.g_negt[k * m + i];
                    if gk != 0.0 {
                        axpy(
                            gk,
                            &self.win_cur[s..s + d],
                            &mut self.neg_cur[k * d..(k + 1) * d],
                        );
                    }
                }
                i += 1;
            }

            // Phase 3c: context deltas land in the cached ring rows.
            let mut i = 0;
            for p in lo..=hi {
                if p == t {
                    continue;
                }
                let s = (p % cap) * d;
                axpy(
                    1.0,
                    &self.dc[i * d..(i + 1) * d],
                    &mut self.win_cur[s..s + d],
                );
                i += 1;
            }

            // Phase 3d: the center's syn1 row has no lifetime beyond
            // this window — write it straight back.
            ctx.model.add_syn1_row(center, &self.du_center[..d]);
        }

        // Retire the rows still cached in the ring...
        let tick = Instant::now();
        for p in len.saturating_sub(wf + 1)..len {
            self.flush_slot(ctx, p, cap, d);
        }
        self.stages
            .add(ST_CONTEXT_RING, tick.elapsed().as_nanos() as u64);
        // ...and write each chunk-lifetime negative back as one delta.
        let tick = Instant::now();
        for k in 0..n_neg {
            for j in 0..d {
                self.delta[j] = self.neg_cur[k * d + j] - self.neg_orig[k * d + j];
            }
            ctx.model.add_syn1_row(self.negs[k], &self.delta[..d]);
        }
        self.stages
            .add(ST_NEGATIVE_BLOCK, tick.elapsed().as_nanos() as u64);
        loss
    }

    fn reuse(&self) -> ReuseCounters {
        self.reuse
    }

    fn stage_times(&self) -> Option<StageTimes> {
        if self.stages.is_empty() {
            None
        } else {
            Some(self.stages.clone())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train_all;
    use crate::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};

    fn tiny_setup() -> (TrainConfig, Vocab, Arc<Vec<Vec<u32>>>) {
        let corpus = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let text = corpus.to_text();
        let vocab = Vocab::build(text.split_whitespace(), 1);
        let sentences: Vec<Vec<u32>> = corpus
            .sentences
            .iter()
            .map(|s| {
                s.iter()
                    .map(|&id| vocab.id(&corpus.words[id as usize]).unwrap())
                    .collect()
            })
            .collect();
        let cfg = TrainConfig {
            dim: 16,
            window: 4,
            negatives: 3,
            epochs: 2,
            subsample: 0.0,
            sentence_chunk: 32,
            ..TrainConfig::default()
        };
        (cfg, vocab, Arc::new(sentences))
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let (cfg, vocab, sents) = tiny_setup();
        let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
        let mut tr = FullW2vTrainer::new(&cfg, &vocab, total);
        let rep = train_all(&mut tr, &sents, 2).unwrap();
        let (first, last) = rep.loss_trajectory();
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(first > 0.0 && first < 100.0);
    }

    #[test]
    fn negative_block_traffic_is_one_load_per_chunk() {
        let (cfg, vocab, sents) = tiny_setup();
        let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
        let mut tr = FullW2vTrainer::new(&cfg, &vocab, total);
        let rep = tr.train_epoch(&sents, 0).unwrap();
        // exactly N negative-row loads per chunk ...
        assert_eq!(rep.neg_rows_loaded, rep.batches * cfg.negatives as u64);
        // ... amortized over every window of the chunk: with >= 2-word
        // chunks, at least one use per load, and far more on real chunks
        assert!(rep.neg_row_uses > rep.neg_rows_loaded * 4);
    }

    /// The kernel's internal tier attribution flows through the driver:
    /// ring and negative-block stages come back nonzero, the remainder
    /// lands in `update`, and the four-stage sum still reconciles with
    /// the workers' summed busy time.
    #[test]
    fn stage_times_attribute_cached_tiers() {
        let (cfg, vocab, sents) = tiny_setup();
        let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
        let mut tr = FullW2vTrainer::new(&cfg, &vocab, total);
        let rep = tr.train_epoch(&sents, 0).unwrap();
        assert_eq!(rep.stages.names(), TRAIN_STAGES);
        assert!(rep.stages.get_ns(ST_CONTEXT_RING) > 0, "ring untimed");
        assert!(rep.stages.get_ns(ST_NEGATIVE_BLOCK) > 0, "negs untimed");
        assert!(
            rep.stages.get_ns(crate::trainer::ST_UPDATE) > 0,
            "update remainder untimed"
        );
        let stage_sum = rep.stages.total_ns() as f64 * 1e-9;
        let drift = (stage_sum - rep.busy_seconds).abs();
        assert!(
            drift <= rep.busy_seconds * 0.02 + 1e-3,
            "stage sum {stage_sum}s vs busy {}s",
            rep.busy_seconds
        );
    }

    #[test]
    fn converges_to_the_same_loss_region_as_pword2vec() {
        let (cfg, vocab, sents) = tiny_setup();
        let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
        let mut tr = FullW2vTrainer::new(&cfg, &vocab, total);
        let rep = train_all(&mut tr, &sents, 2).unwrap();
        let (_, last) = rep.loss_trajectory();
        let mut pw =
            crate::cpu_baseline::PWord2VecTrainer::new(&cfg, &vocab, total);
        let rep_pw = train_all(&mut pw, &sents, 2).unwrap();
        let (_, last_pw) = rep_pw.loss_trajectory();
        assert!(
            (last - last_pw).abs() < 0.35 * last_pw.max(last),
            "fullw2v {last} vs pWord2Vec {last_pw}"
        );
    }
}
