//! Frequent-word subsampling (Mikolov et al. 2013b, word2vec's `-sample`).
//!
//! A word w with corpus frequency f(w) is *kept* with probability
//! `p(w) = (sqrt(f/t) + 1) * t / f` (clamped to 1), the exact formula
//! word2vec.c implements.  Subsampling happens at batching time, before
//! context windows are formed, so it shrinks effective sentence length —
//! the same placement the paper's CPU batching layer uses.

use super::vocab::Vocab;
use crate::util::rng::Pcg32;

/// Precomputed keep-probabilities for one vocabulary.
#[derive(Debug, Clone)]
pub struct Subsampler {
    keep: Vec<f32>,
    enabled: bool,
}

impl Subsampler {
    pub fn new(vocab: &Vocab, t: f64) -> Self {
        if t <= 0.0 || vocab.is_empty() {
            return Subsampler { keep: vec![1.0; vocab.len()], enabled: false };
        }
        let keep = (0..vocab.len() as u32)
            .map(|id| {
                let f = vocab.frequency(id);
                if f <= 0.0 {
                    return 1.0;
                }
                let p = ((f / t).sqrt() + 1.0) * (t / f);
                p.min(1.0) as f32
            })
            .collect();
        Subsampler { keep, enabled: true }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Keep-probability of a word id.
    pub fn keep_prob(&self, id: u32) -> f32 {
        self.keep[id as usize]
    }

    /// Filter a sentence in place.
    pub fn filter(&self, sentence: &mut Vec<u32>, rng: &mut Pcg32) {
        if !self.enabled {
            return;
        }
        sentence.retain(|&id| rng.next_f32() < self.keep[id as usize]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zipf_vocab(n: usize) -> Vocab {
        // counts ~ 1/rank over `n` words, scaled so the head is frequent
        let counts = (0..n).map(|i| {
            (format!("w{i}"), (100_000 / (i + 1)) as u64)
        });
        Vocab::from_counts(counts, 1)
    }

    #[test]
    fn frequent_words_suppressed_more() {
        let v = zipf_vocab(100);
        let s = Subsampler::new(&v, 1e-3);
        assert!(s.enabled());
        // head word is far more frequent -> lower keep prob
        assert!(s.keep_prob(0) < s.keep_prob(50));
        assert!(s.keep_prob(0) < 1.0);
        // tail words are kept almost surely
        assert!((s.keep_prob(99) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn formula_matches_word2vec() {
        let v = zipf_vocab(10);
        let t = 1e-3;
        let s = Subsampler::new(&v, t);
        for id in 0..10u32 {
            let f = v.frequency(id);
            let want = (((f / t).sqrt() + 1.0) * (t / f)).min(1.0) as f32;
            assert!((s.keep_prob(id) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn disabled_keeps_everything() {
        let v = zipf_vocab(10);
        let s = Subsampler::new(&v, 0.0);
        assert!(!s.enabled());
        let mut sent = vec![0u32, 1, 2, 3];
        let mut rng = Pcg32::new(1);
        s.filter(&mut sent, &mut rng);
        assert_eq!(sent, vec![0, 1, 2, 3]);
    }

    #[test]
    fn empirical_keep_rate_matches_probability() {
        let v = zipf_vocab(50);
        let s = Subsampler::new(&v, 1e-3);
        let mut rng = Pcg32::new(42);
        let id = 0u32;
        let trials = 40_000;
        let mut kept = 0usize;
        for _ in 0..trials {
            let mut sent = vec![id];
            s.filter(&mut sent, &mut rng);
            kept += sent.len();
        }
        let rate = kept as f64 / trials as f64;
        let want = s.keep_prob(id) as f64;
        assert!(
            (rate - want).abs() < 0.02,
            "empirical {rate} vs expected {want}"
        );
    }
}
