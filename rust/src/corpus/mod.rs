//! Corpus handling: vocabulary construction, streaming tokenization,
//! frequency-based subsampling, and the synthetic corpus generator that
//! substitutes for Text8 / One Billion Words (DESIGN.md Section 4).

pub mod reader;
pub mod subsample;
pub mod synthetic;
pub mod vocab;

pub use reader::{CorpusReader, ReaderOptions};
pub use subsample::Subsampler;
pub use synthetic::{SyntheticCorpus, SyntheticSpec};
pub use vocab::Vocab;

/// Summary statistics matching the paper's Table 3 columns.
#[derive(Debug, Clone, PartialEq)]
pub struct CorpusStats {
    pub vocabulary: usize,
    pub words_per_epoch: u64,
    pub sentences: u64,
}

impl CorpusStats {
    pub fn compute(vocab: &Vocab, sentences: &[Vec<u32>]) -> Self {
        CorpusStats {
            vocabulary: vocab.len(),
            words_per_epoch: sentences.iter().map(|s| s.len() as u64).sum(),
            sentences: sentences.len() as u64,
        }
    }
}
