//! Vocabulary: word <-> id maps with occurrence counts, min-count
//! filtering, and word2vec-compatible persistence.
//!
//! Ids are assigned in descending frequency order (ties broken
//! lexicographically) — the layout word2vec.c produces after its vocab
//! sort, which downstream consumers (unigram table, subsampler) rely on.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// An immutable, frequency-sorted vocabulary.
#[derive(Debug, Clone, Default)]
pub struct Vocab {
    words: Vec<String>,
    counts: Vec<u64>,
    index: HashMap<String, u32>,
    total_count: u64,
}

impl Vocab {
    /// Build from raw (word, count) pairs, dropping words with
    /// `count < min_count` (paper: 5).
    pub fn from_counts<I>(counts: I, min_count: usize) -> Self
    where
        I: IntoIterator<Item = (String, u64)>,
    {
        let mut kept: Vec<(String, u64)> = counts
            .into_iter()
            .filter(|(_, c)| *c >= min_count as u64)
            .collect();
        kept.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let mut v = Vocab::default();
        for (w, c) in kept {
            v.index.insert(w.clone(), v.words.len() as u32);
            v.words.push(w);
            v.counts.push(c);
            v.total_count += c;
        }
        v
    }

    /// Count words in an iterator of tokens and build the vocabulary.
    pub fn build<'a, I>(tokens: I, min_count: usize) -> Self
    where
        I: IntoIterator<Item = &'a str>,
    {
        let mut counts: HashMap<String, u64> = HashMap::new();
        for t in tokens {
            *counts.entry(t.to_string()).or_insert(0) += 1;
        }
        Self::from_counts(counts, min_count)
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Total count of kept (in-vocabulary) word occurrences.
    pub fn total_count(&self) -> u64 {
        self.total_count
    }

    pub fn id(&self, word: &str) -> Option<u32> {
        self.index.get(word).copied()
    }

    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    pub fn count(&self, id: u32) -> u64 {
        self.counts[id as usize]
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn words(&self) -> &[String] {
        &self.words
    }

    /// Corpus frequency of a word id.
    pub fn frequency(&self, id: u32) -> f64 {
        self.counts[id as usize] as f64 / self.total_count.max(1) as f64
    }

    /// Map a token sentence to ids, dropping OOV tokens.
    pub fn encode_sentence(&self, tokens: &[&str]) -> Vec<u32> {
        tokens.iter().filter_map(|t| self.id(t)).collect()
    }

    /// Persist as `word<TAB>count` lines, frequency order.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for (w, c) in self.words.iter().zip(&self.counts) {
            writeln!(f, "{w}\t{c}")?;
        }
        Ok(())
    }

    /// Load from `word<TAB>count` lines.
    pub fn load(path: &Path) -> std::io::Result<Self> {
        let f = BufReader::new(std::fs::File::open(path)?);
        let mut counts = Vec::new();
        for line in f.lines() {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let (w, c) = line.split_once('\t').ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad vocab line: {line}"),
                )
            })?;
            let c: u64 = c.parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad count in: {line}"),
                )
            })?;
            counts.push((w.to_string(), c));
        }
        // File is already sorted, but re-sorting keeps the invariant even
        // for hand-edited files.
        Ok(Self::from_counts(counts, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vocab {
        let toks = "the cat sat on the mat the cat sat the";
        Vocab::build(toks.split_whitespace(), 2)
    }

    #[test]
    fn frequency_order_ids() {
        let v = sample();
        // the:4, cat:2, sat:2; on/mat dropped by min_count=2
        assert_eq!(v.len(), 3);
        assert_eq!(v.word(0), "the");
        assert_eq!(v.count(0), 4);
        // tie between cat/sat broken lexicographically
        assert_eq!(v.word(1), "cat");
        assert_eq!(v.word(2), "sat");
        assert_eq!(v.total_count(), 8);
    }

    #[test]
    fn id_lookup_and_oov() {
        let v = sample();
        assert_eq!(v.id("the"), Some(0));
        assert_eq!(v.id("on"), None); // filtered
        assert_eq!(v.id("zebra"), None);
    }

    #[test]
    fn encode_drops_oov() {
        let v = sample();
        let ids = v.encode_sentence(&["the", "zebra", "sat"]);
        assert_eq!(ids, vec![0, 2]);
    }

    #[test]
    fn frequencies_sum_to_one() {
        let v = sample();
        let sum: f64 = (0..v.len() as u32).map(|i| v.frequency(i)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn save_load_roundtrip() {
        let v = sample();
        let dir = std::env::temp_dir().join("fullw2v_vocab_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vocab.tsv");
        v.save(&path).unwrap();
        let v2 = Vocab::load(&path).unwrap();
        assert_eq!(v.words(), v2.words());
        assert_eq!(v.counts(), v2.counts());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_vocab() {
        let v = Vocab::build([].into_iter(), 5);
        assert!(v.is_empty());
        assert_eq!(v.total_count(), 0);
    }
}
