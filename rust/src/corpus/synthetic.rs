//! Synthetic corpus generator with latent semantic ground truth.
//!
//! Substitutes for Text8 / One Billion Words and the WS-353 / SimLex-999 /
//! Mikolov-analogy evaluation sets, none of which are available offline
//! (DESIGN.md Section 4).  The generator produces:
//!
//! * a corpus whose unigram distribution is Zipfian (like natural text) and
//!   whose co-occurrence structure encodes a *latent semantic model*: every
//!   word belongs to a topic **cluster** and carries a syntactic **role**;
//!   sentences are topically coherent, so SGNS can recover the structure;
//! * gold similarity pairs scored by the latent cosine (the analogue of
//!   human similarity judgements);
//! * gold analogies `a:b :: c:d` built from (cluster, role) compositions,
//!   solvable to the extent embeddings recover the latent geometry.
//!
//! Absolute quality numbers differ from the paper's human benchmarks; what
//! Table 7 needs is *equivalence between implementations trained on the
//! same corpus*, which this preserves.

use crate::util::rng::Pcg32;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Number of distinct words.
    pub vocab_size: usize,
    /// Latent topic clusters.
    pub clusters: usize,
    /// Latent syntactic roles.
    pub roles: usize,
    /// Total corpus size in words.
    pub total_words: u64,
    /// Mean sentence length (geometric-ish around this).
    pub mean_sentence_len: usize,
    /// Zipf exponent for within-cluster word frequencies.
    pub zipf_exponent: f64,
    /// Probability a word is drawn from the sentence's topic cluster.
    pub topic_coherence: f64,
    /// Probability a word is drawn from the sentence's role.
    pub role_coherence: f64,
    /// Latent space dimension used for gold similarity scores.
    pub latent_dim: usize,
    pub seed: u64,
}

impl SyntheticSpec {
    /// "text8-mini": throughput-bench scale (fast epochs, real vocab size).
    pub fn text8_mini() -> Self {
        SyntheticSpec {
            vocab_size: 10_000,
            clusters: 40,
            roles: 8,
            total_words: 1_000_000,
            mean_sentence_len: 24,
            zipf_exponent: 1.0,
            topic_coherence: 0.75,
            role_coherence: 0.5,
            latent_dim: 16,
            seed: 0x7e58,
        }
    }

    /// "1bw-mini": quality-eval scale (bigger vocab, more text).
    pub fn obw_mini() -> Self {
        SyntheticSpec {
            vocab_size: 30_000,
            clusters: 80,
            roles: 10,
            total_words: 4_000_000,
            mean_sentence_len: 24,
            zipf_exponent: 1.0,
            topic_coherence: 0.75,
            role_coherence: 0.5,
            latent_dim: 16,
            seed: 0x1b3,
        }
    }

    /// Tiny spec for unit/integration tests.
    pub fn tiny() -> Self {
        SyntheticSpec {
            vocab_size: 300,
            clusters: 6,
            roles: 3,
            total_words: 60_000,
            mean_sentence_len: 16,
            zipf_exponent: 1.0,
            topic_coherence: 0.85,
            role_coherence: 0.4,
            latent_dim: 8,
            seed: 7,
        }
    }
}

/// A gold similarity judgement (the WS-353/SimLex analogue).
#[derive(Debug, Clone)]
pub struct GoldPair {
    pub a: String,
    pub b: String,
    pub score: f64,
}

/// A gold analogy `a : b :: c : d` (answer = d).
#[derive(Debug, Clone)]
pub struct GoldAnalogy {
    pub a: String,
    pub b: String,
    pub c: String,
    pub d: String,
}

/// The generated corpus plus its ground truth.
#[derive(Debug)]
pub struct SyntheticCorpus {
    pub spec: SyntheticSpec,
    /// Sentences of word strings (pre-vocab; feed through the normal
    /// reader/vocab path like any real corpus).
    pub sentences: Vec<Vec<u32>>,
    /// Word id -> surface form ("w<cluster>c<role>r<idx>").
    pub words: Vec<String>,
    /// Word id -> latent vector (ground truth).
    pub latents: Vec<Vec<f32>>,
    /// Word id -> (cluster, role).
    pub labels: Vec<(u16, u16)>,
}

impl SyntheticCorpus {
    /// Generate the corpus.
    pub fn generate(spec: SyntheticSpec) -> Self {
        assert!(spec.vocab_size >= spec.clusters * spec.roles.max(1));
        let mut rng = Pcg32::with_stream(spec.seed, 0x535f);

        // --- latent geometry -------------------------------------------
        let centroids: Vec<Vec<f32>> = (0..spec.clusters)
            .map(|_| random_unit(&mut rng, spec.latent_dim))
            .collect();
        let rolevecs: Vec<Vec<f32>> = (0..spec.roles)
            .map(|_| random_unit(&mut rng, spec.latent_dim))
            .collect();

        // --- word inventory --------------------------------------------
        // Words are dealt round-robin over (cluster, role) cells so every
        // cell spans the Zipf frequency range.
        let mut words = Vec::with_capacity(spec.vocab_size);
        let mut latents = Vec::with_capacity(spec.vocab_size);
        let mut labels = Vec::with_capacity(spec.vocab_size);
        // members[cluster][role] -> word ids, frequency-ranked
        let mut members: Vec<Vec<Vec<u32>>> =
            vec![vec![Vec::new(); spec.roles]; spec.clusters];
        for id in 0..spec.vocab_size {
            let c = id % spec.clusters;
            let r = (id / spec.clusters) % spec.roles;
            let idx = id / (spec.clusters * spec.roles);
            words.push(format!("w{c}c{r}r{idx}"));
            let mut v = centroids[c].clone();
            for (vi, ri) in v.iter_mut().zip(&rolevecs[r]) {
                // LINT: allow(kernel-purity): one-time corpus synthesis
                // at generation time, not a training kernel.
                *vi += 0.6 * ri;
            }
            // small per-word identity noise
            for vi in v.iter_mut() {
                // LINT: allow(kernel-purity): as above — synthesis-time.
                *vi += 0.15 * (rng.next_f32() * 2.0 - 1.0);
            }
            normalize(&mut v);
            latents.push(v);
            labels.push((c as u16, r as u16));
            members[c][r].push(id as u32);
        }

        // --- Zipf samplers ----------------------------------------------
        // One alias-free CDF per (cluster, role) cell and per cluster.
        let cell_cdfs: Vec<Vec<Vec<f64>>> = members
            .iter()
            .map(|roles| {
                roles.iter().map(|ids| zipf_cdf(ids.len(), spec.zipf_exponent)).collect()
            })
            .collect();
        let cluster_all: Vec<Vec<u32>> = members
            .iter()
            .map(|roles| roles.iter().flatten().copied().collect())
            .collect();
        let cluster_cdfs: Vec<Vec<f64>> = cluster_all
            .iter()
            .map(|ids| zipf_cdf(ids.len(), spec.zipf_exponent))
            .collect();

        // --- sentence generation ----------------------------------------
        let mut sentences = Vec::new();
        let mut emitted: u64 = 0;
        while emitted < spec.total_words {
            let topic = rng.next_bounded(spec.clusters as u32) as usize;
            let srole = rng.next_bounded(spec.roles as u32) as usize;
            // sentence length: uniform in [mean/2, 3*mean/2]
            let lo = (spec.mean_sentence_len / 2).max(2);
            let hi = spec.mean_sentence_len * 3 / 2;
            let len =
                lo + rng.next_bounded((hi - lo + 1) as u32) as usize;
            let mut sent = Vec::with_capacity(len);
            for _ in 0..len {
                let c = if (rng.next_f64()) < spec.topic_coherence {
                    topic
                } else {
                    rng.next_bounded(spec.clusters as u32) as usize
                };
                let id = if rng.next_f64() < spec.role_coherence {
                    let r = srole.min(spec.roles - 1);
                    let ids = &members[c][r];
                    if ids.is_empty() {
                        sample_cdf(&cluster_all[c], &cluster_cdfs[c], &mut rng)
                    } else {
                        sample_cdf(ids, &cell_cdfs[c][r], &mut rng)
                    }
                } else {
                    sample_cdf(&cluster_all[c], &cluster_cdfs[c], &mut rng)
                };
                sent.push(id);
            }
            emitted += sent.len() as u64;
            sentences.push(sent);
        }

        SyntheticCorpus { spec, sentences, words, latents, labels }
    }

    /// Render as text lines (one sentence per line) — lets the synthetic
    /// corpus flow through the same reader path as a real file.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for s in &self.sentences {
            let mut first = true;
            for &id in s {
                if !first {
                    out.push(' ');
                }
                out.push_str(&self.words[id as usize]);
                first = false;
            }
            out.push('\n');
        }
        out
    }

    /// Latent cosine similarity between two word ids.
    pub fn latent_similarity(&self, a: u32, b: u32) -> f64 {
        cosine(&self.latents[a as usize], &self.latents[b as usize])
    }

    /// Sample `n` gold similarity pairs (the WS-353/SimLex analogue).
    /// Pairs are stratified: 1/3 same-cluster, 1/3 same-role, 1/3 random,
    /// giving the score distribution spread a rank correlation needs.
    pub fn gold_similarity_pairs(&self, n: usize, seed: u64) -> Vec<GoldPair> {
        let mut rng = Pcg32::with_stream(seed, 0x90_1d);
        let v = self.words.len() as u32;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let a = rng.next_bounded(v);
            let b = match out.len() % 3 {
                0 => {
                    // same cluster
                    let (c, _) = self.labels[a as usize];
                    let cands: Vec<u32> = (0..v)
                        .filter(|&x| self.labels[x as usize].0 == c && x != a)
                        .collect();
                    if cands.is_empty() {
                        continue;
                    }
                    cands[rng.next_bounded(cands.len() as u32) as usize]
                }
                1 => {
                    let (_, r) = self.labels[a as usize];
                    let cands: Vec<u32> = (0..v)
                        .filter(|&x| self.labels[x as usize].1 == r && x != a)
                        .collect();
                    if cands.is_empty() {
                        continue;
                    }
                    cands[rng.next_bounded(cands.len() as u32) as usize]
                }
                _ => {
                    let b = rng.next_bounded(v);
                    if b == a {
                        continue;
                    }
                    b
                }
            };
            out.push(GoldPair {
                a: self.words[a as usize].clone(),
                b: self.words[b as usize].clone(),
                score: self.latent_similarity(a, b),
            });
        }
        out
    }

    /// Sample `n` gold analogies from (cluster, role) compositions:
    /// a=(c1,r1), b=(c1,r2), c=(c2,r1), d=(c2,r2).  Only head-frequency
    /// words (rank 0 within their cell) are used, mirroring how the Mikolov
    /// set uses common words.
    pub fn gold_analogies(&self, n: usize, seed: u64) -> Vec<GoldAnalogy> {
        let mut rng = Pcg32::with_stream(seed, 0xa41);
        let nc = self.spec.clusters as u32;
        let nr = self.spec.roles as u32;
        let head = |c: u32, r: u32| -> Option<&String> {
            let id = (r * nc + c) as usize; // idx 0 word of the cell
            if id < self.words.len() {
                Some(&self.words[id])
            } else {
                None
            }
        };
        let mut out = Vec::with_capacity(n);
        let mut guard = 0;
        while out.len() < n && guard < n * 50 {
            guard += 1;
            let c1 = rng.next_bounded(nc);
            let c2 = rng.next_bounded(nc);
            let r1 = rng.next_bounded(nr);
            let r2 = rng.next_bounded(nr);
            if c1 == c2 || r1 == r2 {
                continue;
            }
            if let (Some(a), Some(b), Some(c), Some(d)) = (
                head(c1, r1),
                head(c1, r2),
                head(c2, r1),
                head(c2, r2),
            ) {
                out.push(GoldAnalogy {
                    a: a.clone(),
                    b: b.clone(),
                    c: c.clone(),
                    d: d.clone(),
                });
            }
        }
        out
    }
}

fn random_unit(rng: &mut Pcg32, dim: usize) -> Vec<f32> {
    // Box-Muller-ish: sum of uniforms is fine for direction sampling
    let mut v: Vec<f32> =
        (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    normalize(&mut v);
    v
}

fn normalize(v: &mut [f32]) {
    // LINT: allow(kernel-purity): frozen gold definition — multiply in
    // f32 then widen, deliberately NOT vecops::dot_f64's widen-then-
    // multiply; the generator's output must be bit-stable across PRs.
    let n = v.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32;
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    // LINT: allow(kernel-purity): frozen gold definition (see normalize
    // above) — f32-multiply-then-widen, bit-stable generator ground
    // truth that must not route through the dispatched kernels.
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (x * y) as f64).sum();
    // LINT: allow(kernel-purity): as above.
    let na: f64 = a.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt();
    // LINT: allow(kernel-purity): as above.
    let nb: f64 = b.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

/// Zipf CDF over ranks 0..n (rank 0 most frequent).
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for i in 0..n {
        acc += 1.0 / ((i + 1) as f64).powf(s);
        cdf.push(acc);
    }
    for c in cdf.iter_mut() {
        *c /= acc;
    }
    cdf
}

fn sample_cdf(ids: &[u32], cdf: &[f64], rng: &mut Pcg32) -> u32 {
    debug_assert_eq!(ids.len(), cdf.len());
    let u = rng.next_f64();
    let pos = cdf.partition_point(|&c| c < u);
    ids[pos.min(ids.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_size() {
        let spec = SyntheticSpec::tiny();
        let c = SyntheticCorpus::generate(spec.clone());
        let total: u64 = c.sentences.iter().map(|s| s.len() as u64).sum();
        assert!(total >= spec.total_words);
        assert!(total < spec.total_words + 2 * spec.mean_sentence_len as u64);
        assert_eq!(c.words.len(), spec.vocab_size);
        assert_eq!(c.latents.len(), spec.vocab_size);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let b = SyntheticCorpus::generate(SyntheticSpec::tiny());
        assert_eq!(a.sentences, b.sentences);
    }

    #[test]
    fn zipfian_head_dominates() {
        let c = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let mut counts = vec![0u64; c.words.len()];
        for s in &c.sentences {
            for &id in s {
                counts[id as usize] += 1;
            }
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = sorted.iter().sum();
        let top10: u64 = sorted.iter().take(c.words.len() / 10).sum();
        // Zipf: top 10% of words should carry well over a third of the mass
        assert!(
            top10 as f64 / total as f64 > 0.35,
            "top10 share {}",
            top10 as f64 / total as f64
        );
    }

    #[test]
    fn same_cluster_pairs_score_higher() {
        let c = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for a in 0..c.words.len() as u32 {
            for b in (a + 1)..(a + 20).min(c.words.len() as u32) {
                let s = c.latent_similarity(a, b);
                if c.labels[a as usize].0 == c.labels[b as usize].0 {
                    same.push(s);
                } else {
                    diff.push(s);
                }
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(mean(&same) > mean(&diff) + 0.15);
    }

    #[test]
    fn cooccurrence_encodes_clusters() {
        // Words from the same cluster must co-occur in sentences far more
        // often than chance — the property SGNS training relies on.
        let c = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let mut same = 0u64;
        let mut total = 0u64;
        for s in &c.sentences {
            for w in s.windows(2) {
                total += 1;
                if c.labels[w[0] as usize].0 == c.labels[w[1] as usize].0 {
                    same += 1;
                }
            }
        }
        let rate = same as f64 / total as f64;
        let chance = 1.0 / c.spec.clusters as f64;
        assert!(
            rate > 3.0 * chance,
            "same-cluster adjacency {rate:.3} vs chance {chance:.3}"
        );
    }

    #[test]
    fn gold_pairs_have_score_spread() {
        let c = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let pairs = c.gold_similarity_pairs(120, 5);
        assert_eq!(pairs.len(), 120);
        let scores: Vec<f64> = pairs.iter().map(|p| p.score).collect();
        let max = scores.iter().cloned().fold(f64::MIN, f64::max);
        let min = scores.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min > 0.4, "spread {max}-{min}");
    }

    #[test]
    fn gold_analogies_wellformed() {
        let c = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let an = c.gold_analogies(50, 5);
        assert!(an.len() >= 40);
        for g in &an {
            // a,b share a cluster; c,d share a cluster; a,c share a role
            let id = |w: &str| {
                c.words.iter().position(|x| x == w).unwrap() as usize
            };
            let (la, lb, lc, ld) = (
                c.labels[id(&g.a)],
                c.labels[id(&g.b)],
                c.labels[id(&g.c)],
                c.labels[id(&g.d)],
            );
            assert_eq!(la.0, lb.0);
            assert_eq!(lc.0, ld.0);
            assert_eq!(la.1, lc.1);
            assert_eq!(lb.1, ld.1);
            assert_ne!(la.0, lc.0);
        }
    }

    #[test]
    fn text_roundtrip_through_reader() {
        use crate::corpus::{reader, vocab::Vocab};
        let c = SyntheticCorpus::generate(SyntheticSpec::tiny());
        let text = c.to_text();
        let all_tokens: Vec<&str> = text.split_whitespace().collect();
        let v = Vocab::build(all_tokens.iter().copied(), 1);
        let (sents, raw) = reader::read_all(
            text.as_bytes(),
            &v,
            reader::ReaderOptions::default(),
        );
        assert_eq!(sents.len(), c.sentences.len());
        let total: u64 = c.sentences.iter().map(|s| s.len() as u64).sum();
        assert_eq!(raw, total);
    }
}
