//! Streaming corpus reader: tokenizes text into sentences of word ids.
//!
//! Handles the paper's corpus-treatment details (Sections 4.1, 5.1):
//! sentence length capping (1000 words), optional *delimiter ignoring*
//! (FULL-W2V packs words into fixed-size pseudo-sentences to raise
//! per-batch work), and OOV dropping against a fixed vocabulary.

use super::vocab::Vocab;
use std::io::{BufRead, BufReader, Read};

/// Reader behaviour knobs.
#[derive(Debug, Clone)]
pub struct ReaderOptions {
    /// Hard cap on sentence length; longer sentences are split.
    pub max_sentence_len: usize,
    /// If true, newline boundaries are ignored and words are packed into
    /// `pack_len`-word pseudo-sentences (paper Section 4.1).
    pub ignore_delimiters: bool,
    /// Pseudo-sentence length used when `ignore_delimiters` is set.
    pub pack_len: usize,
}

impl Default for ReaderOptions {
    fn default() -> Self {
        ReaderOptions {
            max_sentence_len: 1000,
            ignore_delimiters: false,
            pack_len: 1000,
        }
    }
}

/// Tokenize a line on ASCII whitespace, lowercasing (text8 convention).
pub fn tokenize(line: &str) -> impl Iterator<Item = String> + '_ {
    line.split_whitespace().map(|w| w.to_lowercase())
}

/// Streaming sentence reader over any `Read`.
pub struct CorpusReader<R: Read> {
    lines: std::io::Lines<BufReader<R>>,
    vocab: Vocab,
    opts: ReaderOptions,
    carry: Vec<u32>,
    pending: std::collections::VecDeque<Vec<u32>>,
    /// Raw (pre-OOV-filter) token count seen so far.
    pub raw_tokens: u64,
}

impl<R: Read> CorpusReader<R> {
    pub fn new(reader: R, vocab: &Vocab, opts: ReaderOptions) -> Self {
        CorpusReader {
            lines: BufReader::new(reader).lines(),
            vocab: vocab.clone(),
            opts,
            carry: Vec::new(),
            pending: Default::default(),
            raw_tokens: 0,
        }
    }

    fn push_sentence(&mut self, ids: Vec<u32>) {
        if ids.is_empty() {
            return;
        }
        let cap = self.opts.max_sentence_len.max(1);
        for chunk in ids.chunks(cap) {
            if !chunk.is_empty() {
                self.pending.push_back(chunk.to_vec());
            }
        }
    }

    fn ingest_line(&mut self, line: &str) {
        let mut ids = Vec::new();
        for tok in tokenize(line) {
            self.raw_tokens += 1;
            if let Some(id) = self.vocab.id(&tok) {
                ids.push(id);
            }
        }
        if self.opts.ignore_delimiters {
            self.carry.extend(ids);
            let pack = self.opts.pack_len.max(1);
            while self.carry.len() >= pack {
                let rest = self.carry.split_off(pack);
                let full = std::mem::replace(&mut self.carry, rest);
                self.push_sentence(full);
            }
        } else {
            self.push_sentence(ids);
        }
    }

    fn flush_carry(&mut self) {
        if !self.carry.is_empty() {
            let c = std::mem::take(&mut self.carry);
            self.push_sentence(c);
        }
    }
}

impl<R: Read> Iterator for CorpusReader<R> {
    type Item = Vec<u32>;

    fn next(&mut self) -> Option<Vec<u32>> {
        loop {
            if let Some(s) = self.pending.pop_front() {
                return Some(s);
            }
            match self.lines.next() {
                Some(Ok(line)) => self.ingest_line(&line),
                Some(Err(_)) | None => {
                    self.flush_carry();
                    return self.pending.pop_front();
                }
            }
        }
    }
}

/// Read an entire corpus into memory (convenience for small corpora and
/// tests); returns (sentences, raw_token_count).
pub fn read_all<R: Read>(
    reader: R,
    vocab: &Vocab,
    opts: ReaderOptions,
) -> (Vec<Vec<u32>>, u64) {
    let mut r = CorpusReader::new(reader, vocab, opts);
    let mut out = Vec::new();
    for s in &mut r {
        out.push(s);
    }
    let raw = r.raw_tokens;
    (out, raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Vocab {
        Vocab::build(
            "a a a b b b c c c d d d".split_whitespace(),
            1,
        )
    }

    #[test]
    fn sentences_follow_lines() {
        let v = vocab();
        let text = "a b c\nc b a d\n";
        let (sents, raw) =
            read_all(text.as_bytes(), &v, ReaderOptions::default());
        assert_eq!(sents.len(), 2);
        assert_eq!(sents[0].len(), 3);
        assert_eq!(sents[1].len(), 4);
        assert_eq!(raw, 7);
    }

    #[test]
    fn oov_dropped_lowercased() {
        let v = vocab();
        let text = "A zzz B\n";
        let (sents, raw) =
            read_all(text.as_bytes(), &v, ReaderOptions::default());
        assert_eq!(raw, 3);
        assert_eq!(sents.len(), 1);
        assert_eq!(sents[0].len(), 2); // zzz dropped, A/B lowercased
    }

    #[test]
    fn long_sentences_split() {
        let v = vocab();
        let text = "a b c d a b c d a b\n"; // 10 words
        let opts = ReaderOptions { max_sentence_len: 4, ..Default::default() };
        let (sents, _) = read_all(text.as_bytes(), &v, opts);
        assert_eq!(
            sents.iter().map(|s| s.len()).collect::<Vec<_>>(),
            vec![4, 4, 2]
        );
    }

    #[test]
    fn ignore_delimiters_packs() {
        let v = vocab();
        let text = "a b\nc d\na b\nc\n"; // 7 words over 4 lines
        let opts = ReaderOptions {
            ignore_delimiters: true,
            pack_len: 3,
            ..Default::default()
        };
        let (sents, _) = read_all(text.as_bytes(), &v, opts);
        assert_eq!(
            sents.iter().map(|s| s.len()).collect::<Vec<_>>(),
            vec![3, 3, 1]
        );
    }

    #[test]
    fn empty_input() {
        let v = vocab();
        let (sents, raw) =
            read_all("".as_bytes(), &v, ReaderOptions::default());
        assert!(sents.is_empty());
        assert_eq!(raw, 0);
    }

    #[test]
    fn blank_lines_skipped() {
        let v = vocab();
        let (sents, _) =
            read_all("a b\n\n\nc\n".as_bytes(), &v, ReaderOptions::default());
        assert_eq!(sents.len(), 2);
    }
}
