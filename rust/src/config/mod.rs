//! Configuration system: typed configs + a TOML-subset file format + CLI
//! overrides.
//!
//! The file format supports what real deployment configs need — sections,
//! strings, ints, floats, bools, comments — a strict subset of TOML:
//!
//! ```toml
//! # fullw2v.toml
//! [train]
//! variant = "full_w2v"
//! dim = 128
//! window = 5
//! negatives = 5
//! epochs = 20
//! lr = 0.025
//!
//! [pipeline]
//! streams = 4
//! queue_depth = 8
//! ```

mod toml;

pub use toml::{parse_toml, TomlError, TomlValue};

use std::collections::BTreeMap;
use std::path::Path;

/// Word2Vec training hyperparameters (paper defaults, Section 5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Kernel variant: full_w2v | full_register | acc_sgns | wombat.
    pub variant: String,
    /// Embedding dimension d.
    pub dim: usize,
    /// Mikolov window hyperparameter W; the fixed width is `ceil(W/2)`.
    pub window: usize,
    /// Negative samples per context window N.
    pub negatives: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Initial learning rate (linearly decayed to `min_lr_ratio * lr`).
    pub lr: f32,
    /// Floor for the linear lr decay, as a fraction of `lr`.
    pub min_lr_ratio: f32,
    /// Discard words with fewer than this many corpus occurrences.
    pub min_count: usize,
    /// Subsampling threshold t (0 disables), word2vec's `-sample`.
    pub subsample: f64,
    /// Sentences per GPU batch (the AOT executable's B).
    pub batch_sentences: usize,
    /// Max words per sentence chunk (the AOT executable's S).
    pub sentence_chunk: usize,
    /// Hard cap on corpus sentence length (paper: 1000).
    pub max_sentence_len: usize,
    /// Ignore sentence delimiters, packing words into fixed-length
    /// pseudo-sentences (paper Section 4.1 does this for GPU utilization).
    pub ignore_delimiters: bool,
    /// Hogwild worker threads for the CPU trainers (1 = the serial
    /// reference path; 0 = one per available core).
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            variant: "full_w2v".into(),
            dim: 128,
            window: 5,
            negatives: 5,
            epochs: 5,
            lr: 0.025,
            min_lr_ratio: 1e-4,
            min_count: 5,
            subsample: 1e-3,
            batch_sentences: 64,
            sentence_chunk: 32,
            max_sentence_len: 1000,
            ignore_delimiters: false,
            threads: 1,
            seed: 1,
        }
    }
}

impl TrainConfig {
    /// Fixed context width W_f = ceil(W/2) (paper Section 3.2).
    pub fn fixed_width(&self) -> usize {
        self.window.div_ceil(2)
    }

    /// Hogwild worker-thread count with `0 = one per available core`
    /// resolved (the same convention as `PipelineConfig::streams`).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    /// Validate invariants; returns a descriptive error string.
    pub fn validate(&self) -> Result<(), String> {
        if self.dim == 0 {
            return Err("dim must be > 0".into());
        }
        if self.window == 0 {
            return Err("window must be > 0".into());
        }
        if self.sentence_chunk < 2 * self.fixed_width() + 1 {
            return Err(format!(
                "sentence_chunk={} must be >= 2*W_f+1={}",
                self.sentence_chunk,
                2 * self.fixed_width() + 1
            ));
        }
        if self.batch_sentences == 0 {
            return Err("batch_sentences must be > 0".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be > 0".into());
        }
        if self.subsample < 0.0 {
            return Err("subsample must be >= 0".into());
        }
        Ok(())
    }

    /// The AOT executable name this config requires.
    pub fn executable_name(&self) -> String {
        format!(
            "{}_b{}_s{}_d{}_n{}_w{}",
            self.variant,
            self.batch_sentences,
            self.sentence_chunk,
            self.dim,
            self.negatives,
            self.fixed_width()
        )
    }

    fn apply_kv(&mut self, key: &str, v: &TomlValue) -> Result<(), String> {
        match key {
            "variant" => self.variant = v.as_str_or(key)?,
            "dim" => self.dim = v.as_usize_or(key)?,
            "window" => self.window = v.as_usize_or(key)?,
            "negatives" => self.negatives = v.as_usize_or(key)?,
            "epochs" => self.epochs = v.as_usize_or(key)?,
            "lr" => self.lr = v.as_f64_or(key)? as f32,
            "min_lr_ratio" => self.min_lr_ratio = v.as_f64_or(key)? as f32,
            "min_count" => self.min_count = v.as_usize_or(key)?,
            "subsample" => self.subsample = v.as_f64_or(key)?,
            "batch_sentences" => self.batch_sentences = v.as_usize_or(key)?,
            "sentence_chunk" => self.sentence_chunk = v.as_usize_or(key)?,
            "max_sentence_len" => {
                self.max_sentence_len = v.as_usize_or(key)?
            }
            "ignore_delimiters" => {
                self.ignore_delimiters = v.as_bool_or(key)?
            }
            "threads" => self.threads = v.as_usize_or(key)?,
            "seed" => self.seed = v.as_usize_or(key)? as u64,
            _ => return Err(format!("unknown [train] key '{key}'")),
        }
        Ok(())
    }
}

/// Batching-pipeline configuration (the paper's CPU-thread / CUDA-stream
/// coordination layer, Section 4.1).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    /// Number of batcher threads ("streams"). 0 = one per available core.
    pub streams: usize,
    /// Bounded queue depth per stream (backpressure).
    pub queue_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { streams: 0, queue_depth: 4 }
    }
}

impl PipelineConfig {
    pub fn resolved_streams(&self) -> usize {
        if self.streams > 0 {
            self.streams
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    fn apply_kv(&mut self, key: &str, v: &TomlValue) -> Result<(), String> {
        match key {
            "streams" => self.streams = v.as_usize_or(key)?,
            "queue_depth" => self.queue_depth = v.as_usize_or(key)?,
            _ => return Err(format!("unknown [pipeline] key '{key}'")),
        }
        Ok(())
    }
}

/// Default bound on concurrently admitted engine-bound HTTP requests;
/// the single source shared by [`ServeConfig`] and the net layer's
/// `NetOptions` so the two construction paths cannot drift.
pub const DEFAULT_MAX_INFLIGHT: usize = 256;

/// Network serving front-end configuration (`serve --listen` mode).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Listen address for the HTTP front-end (e.g. "127.0.0.1:7878").
    /// Empty = no default; `serve` stays in file mode unless `--listen`
    /// is passed.  Port 0 binds an ephemeral port (printed at startup).
    pub listen: String,
    /// Engine-bound requests admitted concurrently before the front-end
    /// starts shedding with 503 + Retry-After (0 = unlimited).  Sized
    /// relative to the engine's queue depth: admitted requests block on
    /// the bounded queue, so this gauge is what keeps overload from
    /// piling latency onto every request.
    pub max_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            listen: String::new(),
            max_inflight: DEFAULT_MAX_INFLIGHT,
        }
    }
}

impl ServeConfig {
    fn apply_kv(&mut self, key: &str, v: &TomlValue) -> Result<(), String> {
        match key {
            "listen" => self.listen = v.as_str_or(key)?,
            "max_inflight" => self.max_inflight = v.as_usize_or(key)?,
            _ => return Err(format!("unknown [serve] key '{key}'")),
        }
        Ok(())
    }
}

/// Full application config: train + pipeline + serve + paths.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub train: TrainConfig,
    pub pipeline: PipelineConfig,
    pub serve: ServeConfig,
    /// Directory holding AOT artifacts + manifest.json.
    pub artifacts_dir: String,
}

impl Config {
    pub fn new() -> Self {
        Config {
            train: TrainConfig::default(),
            pipeline: PipelineConfig::default(),
            serve: ServeConfig::default(),
            artifacts_dir: "artifacts".into(),
        }
    }

    /// Load from a TOML-subset file.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self, String> {
        let doc = parse_toml(text).map_err(|e| e.to_string())?;
        let mut cfg = Config::new();
        cfg.apply_sections(&doc)?;
        cfg.train.validate()?;
        Ok(cfg)
    }

    fn apply_sections(
        &mut self,
        doc: &BTreeMap<String, BTreeMap<String, TomlValue>>,
    ) -> Result<(), String> {
        for (section, kvs) in doc {
            for (k, v) in kvs {
                match section.as_str() {
                    "train" => self.train.apply_kv(k, v)?,
                    "pipeline" => self.pipeline.apply_kv(k, v)?,
                    "serve" => self.serve.apply_kv(k, v)?,
                    "paths" => match k.as_str() {
                        "artifacts_dir" => {
                            self.artifacts_dir = v.as_str_or(k)?
                        }
                        _ => {
                            return Err(format!("unknown [paths] key '{k}'"))
                        }
                    },
                    "" => return Err(format!("top-level key '{k}' not allowed; use a section")),
                    _ => return Err(format!("unknown section [{section}]")),
                }
            }
        }
        Ok(())
    }

    /// Apply a `section.key=value` CLI override.
    pub fn apply_override(&mut self, spec: &str) -> Result<(), String> {
        let (path, raw) = spec
            .split_once('=')
            .ok_or_else(|| format!("override '{spec}' must be key=value"))?;
        let (section, key) = path
            .split_once('.')
            .ok_or_else(|| format!("override key '{path}' must be section.key"))?;
        let v = toml::parse_value(raw.trim())
            .map_err(|e| format!("override '{spec}': {e}"))?;
        match section {
            "train" => self.train.apply_kv(key.trim(), &v),
            "pipeline" => self.pipeline.apply_kv(key.trim(), &v),
            "serve" => self.serve.apply_kv(key.trim(), &v),
            "paths" if key.trim() == "artifacts_dir" => {
                self.artifacts_dir = v.as_str_or(key)?;
                Ok(())
            }
            _ => Err(format!("unknown override section '{section}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_defaults() {
        let c = TrainConfig::default();
        assert_eq!(c.dim, 128);
        assert_eq!(c.window, 5);
        assert_eq!(c.negatives, 5);
        assert_eq!(c.fixed_width(), 3); // ceil(5/2)
        assert_eq!(c.min_count, 5);
        assert!(c.validate().is_ok());
        assert_eq!(c.executable_name(), "full_w2v_b64_s32_d128_n5_w3");
    }

    #[test]
    fn fixed_width_rounding() {
        let mut c = TrainConfig::default();
        for (w, wf) in [(1, 1), (2, 1), (3, 2), (4, 2), (5, 3), (10, 5)] {
            c.window = w;
            assert_eq!(c.fixed_width(), wf, "W={w}");
        }
    }

    #[test]
    fn parse_full_file() {
        let cfg = Config::from_toml_str(
            r#"
            # comment
            [train]
            variant = "wombat"
            dim = 64
            window = 4
            lr = 0.05
            ignore_delimiters = true

            [pipeline]
            streams = 2
            queue_depth = 16

            [paths]
            artifacts_dir = "my_artifacts"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.train.variant, "wombat");
        assert_eq!(cfg.train.dim, 64);
        assert_eq!(cfg.train.fixed_width(), 2);
        assert!((cfg.train.lr - 0.05).abs() < 1e-9);
        assert!(cfg.train.ignore_delimiters);
        assert_eq!(cfg.pipeline.streams, 2);
        assert_eq!(cfg.pipeline.queue_depth, 16);
        assert_eq!(cfg.artifacts_dir, "my_artifacts");
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(Config::from_toml_str("[train]\nbogus = 1").is_err());
        assert!(Config::from_toml_str("[nope]\nx = 1").is_err());
    }

    #[test]
    fn rejects_invalid_hyperparams() {
        assert!(Config::from_toml_str("[train]\ndim = 0").is_err());
        assert!(
            Config::from_toml_str("[train]\nsentence_chunk = 3").is_err()
        );
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = Config::new();
        cfg.apply_override("train.dim=256").unwrap();
        cfg.apply_override("train.variant=\"acc_sgns\"").unwrap();
        cfg.apply_override("pipeline.streams=8").unwrap();
        assert_eq!(cfg.train.dim, 256);
        assert_eq!(cfg.train.variant, "acc_sgns");
        assert_eq!(cfg.pipeline.streams, 8);
        assert!(cfg.apply_override("train.nope=1").is_err());
        assert!(cfg.apply_override("no-equals").is_err());
    }

    #[test]
    fn bare_string_override() {
        // unquoted strings are accepted in overrides for ergonomics
        let mut cfg = Config::new();
        cfg.apply_override("train.variant=wombat").unwrap();
        assert_eq!(cfg.train.variant, "wombat");
    }

    #[test]
    fn threads_key_parses_and_resolves() {
        let c = TrainConfig::default();
        assert_eq!(c.threads, 1, "serial by default");
        assert_eq!(c.resolved_threads(), 1);
        let cfg =
            Config::from_toml_str("[train]\nthreads = 4").unwrap();
        assert_eq!(cfg.train.threads, 4);
        assert_eq!(cfg.train.resolved_threads(), 4);
        let mut cfg = Config::new();
        cfg.apply_override("train.threads=0").unwrap();
        assert!(cfg.train.resolved_threads() >= 1, "0 = auto");
    }

    #[test]
    fn serve_section_parses_and_overrides() {
        let c = ServeConfig::default();
        assert!(c.listen.is_empty(), "no listen default: file mode");
        assert_eq!(c.max_inflight, 256);
        let cfg = Config::from_toml_str(
            "[serve]\nlisten = \"127.0.0.1:7878\"\nmax_inflight = 32",
        )
        .unwrap();
        assert_eq!(cfg.serve.listen, "127.0.0.1:7878");
        assert_eq!(cfg.serve.max_inflight, 32);
        let mut cfg = Config::new();
        cfg.apply_override("serve.listen=0.0.0.0:80").unwrap();
        cfg.apply_override("serve.max_inflight=8").unwrap();
        assert_eq!(cfg.serve.listen, "0.0.0.0:80");
        assert_eq!(cfg.serve.max_inflight, 8);
        assert!(Config::from_toml_str("[serve]\nbogus = 1").is_err());
    }

    #[test]
    fn resolved_streams_nonzero() {
        let p = PipelineConfig { streams: 0, queue_depth: 1 };
        assert!(p.resolved_streams() >= 1);
        let p = PipelineConfig { streams: 3, queue_depth: 1 };
        assert_eq!(p.resolved_streams(), 3);
    }
}
