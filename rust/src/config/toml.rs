//! TOML-subset parser: `[section]` headers and `key = value` lines where
//! value is a quoted string, integer, float, or boolean.  Comments (`#`)
//! and blank lines are skipped.  This covers what deployment configs use
//! without pulling in a full TOML dependency (unavailable offline).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str_or(&self, key: &str) -> Result<String, String> {
        match self {
            TomlValue::Str(s) => Ok(s.clone()),
            other => Err(format!("key '{key}' expects a string, got {other:?}")),
        }
    }

    pub fn as_usize_or(&self, key: &str) -> Result<usize, String> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => Err(format!(
                "key '{key}' expects a non-negative integer, got {other:?}"
            )),
        }
    }

    pub fn as_f64_or(&self, key: &str) -> Result<f64, String> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(format!("key '{key}' expects a number, got {other:?}")),
        }
    }

    pub fn as_bool_or(&self, key: &str) -> Result<bool, String> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(format!("key '{key}' expects a bool, got {other:?}")),
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a single scalar value (also used for CLI overrides).
/// Unquoted text that is not an int/float/bool parses as a bare string.
pub fn parse_value(raw: &str) -> Result<TomlValue, String> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err("empty value".into());
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {raw}"))?;
        if inner.contains('"') {
            return Err(format!("embedded quote in string: {raw}"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    // bare string (ergonomic for CLI overrides like train.variant=wombat
    // or serve.listen=127.0.0.1:0)
    if raw.chars().all(|c| c.is_alphanumeric() || "_-./:".contains(c)) {
        return Ok(TomlValue::Str(raw.to_string()));
    }
    Err(format!("cannot parse value: {raw}"))
}

/// Parse a TOML-subset document into section -> key -> value.
/// Keys before any section header land in the "" section.
pub fn parse_toml(
    text: &str,
) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>, TomlError> {
    let mut doc: BTreeMap<String, BTreeMap<String, TomlValue>> =
        BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw_line) in text.lines().enumerate() {
        let line = strip_comment(raw_line).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err = |msg: String| TomlError { line: lineno + 1, msg };
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated section header".into()))?
                .trim();
            if name.is_empty() {
                return Err(err("empty section name".into()));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(format!("expected key = value, got '{line}'")))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err("empty key".into()));
        }
        let v = parse_value(value).map_err(|m| err(m))?;
        let dup = doc
            .entry(section.clone())
            .or_default()
            .insert(key.to_string(), v);
        if dup.is_some() {
            return Err(err(format!("duplicate key '{key}'")));
        }
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_kinds() {
        assert_eq!(parse_value("42").unwrap(), TomlValue::Int(42));
        assert_eq!(parse_value("-7").unwrap(), TomlValue::Int(-7));
        assert_eq!(parse_value("2.5e-3").unwrap(), TomlValue::Float(0.0025));
        assert_eq!(parse_value("true").unwrap(), TomlValue::Bool(true));
        assert_eq!(
            parse_value("\"hi\"").unwrap(),
            TomlValue::Str("hi".into())
        );
        assert_eq!(
            parse_value("bare_word").unwrap(),
            TomlValue::Str("bare_word".into())
        );
        // socket addresses stay one bare token for -s serve.listen=...
        assert_eq!(
            parse_value("127.0.0.1:8080").unwrap(),
            TomlValue::Str("127.0.0.1:8080".into())
        );
        assert!(parse_value("\"unterminated").is_err());
        assert!(parse_value("").is_err());
    }

    #[test]
    fn sections_and_comments() {
        let doc = parse_toml(
            "# leading comment\n[a]\nx = 1 # trailing\ny = \"q#q\"\n\n[b]\nz = true\n",
        )
        .unwrap();
        assert_eq!(doc["a"]["x"], TomlValue::Int(1));
        assert_eq!(doc["a"]["y"], TomlValue::Str("q#q".into()));
        assert_eq!(doc["b"]["z"], TomlValue::Bool(true));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("[a]\ngood = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 3);
        let e = parse_toml("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse_toml("[a]\nx = 1\nx = 2\n").is_err());
    }
}
