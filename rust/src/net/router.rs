//! Route table over the serving engine.
//!
//! | route                  | does                                        |
//! |------------------------|---------------------------------------------|
//! | `POST /v1/nn`          | top-k neighbors by `id`, `word`, or `vector`|
//! | `POST /v1/embed`       | raw stored row by `id` or `word`            |
//! | `GET  /healthz`        | liveness (503 once draining)                |
//! | `GET  /stats`          | engine report + net-layer gauges            |
//! | `GET  /metrics`        | Prometheus text exposition ([`crate::obs`]) |
//! | `GET  /debug/traces`   | recent request span trees ([`obs::trace`])  |
//! | `POST /admin/shutdown` | trigger graceful drain                      |
//!
//! Dispatch is **two-phase** so the wire layer can feed the engine's
//! micro-batcher: [`begin`] parses, admits, and *submits* an nn query
//! (returning the reply receiver), and [`finish`] awaits it and builds
//! the response.  A connection that pipelines several requests begins
//! them all before finishing any — the whole window lands in the
//! engine's queue together and is drained as one micro-batch, which is
//! the transport-level analogue of the paper's batching lesson (per-item
//! dispatch wastes batched kernels).
//!
//! Only `/v1/nn` passes admission control ([`super::shed`]): it is the
//! route that blocks on the engine's bounded queue.  Health, stats, and
//! metrics stay answerable during overload on purpose.
//!
//! Every request carries a process-unique id (minted in
//! [`super::conn`]); nn submissions hand it to the engine so the
//! slow-query log can name the offending HTTP request, and served-
//! request logs carry it as a structured field.

use super::http::{Request, Response};
use super::shed::{InflightGauge, Permit};
use crate::corpus::vocab::Vocab;
use crate::metrics::RouteMetrics;
use crate::obs::{self, PromWriter};
use crate::serve::{
    EngineStats, QueryClient, QueryResponse, Neighbor, ShardedStore,
};
use crate::util::json::{obj, Json};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// Everything a connection worker needs, shared across the pool.
pub(crate) struct AppState {
    pub client: QueryClient,
    pub stats: EngineStats,
    pub store: Arc<ShardedStore>,
    /// Store vocabulary for by-word queries and word-annotated results;
    /// `None` serves ids only.
    pub vocab: Option<Vocab>,
    pub gauge: Arc<InflightGauge>,
    pub routes: RouteMetrics,
    /// Set by `/admin/shutdown` (or [`super::NetServer::trigger_shutdown`]);
    /// the acceptor and the keep-alive loops watch it.
    pub stop: AtomicBool,
    /// `k` used when an nn body omits it (`serve --listen --k K`).
    pub default_k: usize,
}

/// A begun request: already answerable, deferred local work, or an nn
/// query in flight inside the engine (permit held until the response is
/// built).
pub(crate) enum Pending {
    Ready(&'static str, Response),
    /// Local work postponed to [`finish`] so it cannot delay later nn
    /// submissions in the same pipelined window (embed can page a cold
    /// shard in from disk — that I/O must not starve the micro-batcher).
    Deferred(
        &'static str,
        Box<dyn FnOnce(&AppState) -> Response + Send>,
    ),
    Nn { rx: Receiver<QueryResponse>, _permit: Permit },
}

/// Phase 1: parse, admit, and submit.  Engine-bound work is *in the
/// micro-batcher's queue* when this returns.  `trace` is the request's
/// effective trace id — minted by the connection layer, or adopted from
/// the `x-fullw2v-trace` request header; nn queries carry it into the
/// engine, which records their span trees under it ([`obs::trace`]).
pub(crate) fn begin(state: &AppState, req: &Request, trace: u64) -> Pending {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => Pending::Ready("healthz", healthz(state)),
        ("GET", "/stats") => Pending::Ready("stats", stats(state)),
        ("GET", "/metrics") => Pending::Ready("metrics", metrics(state)),
        ("GET", "/debug/traces") => {
            Pending::Ready("traces", traces(&req.target))
        }
        ("POST", "/v1/nn") => nn_begin(state, req, trace),
        ("POST", "/v1/embed") => match parse_body(req) {
            Err(resp) => Pending::Ready("embed", resp),
            Ok(body) => Pending::Deferred(
                "embed",
                Box::new(move |state| embed(state, &body)),
            ),
        },
        ("POST", "/admin/shutdown") => {
            state.stop.store(true, Ordering::Release);
            Pending::Ready(
                "shutdown",
                Response::json(
                    200,
                    &obj(vec![("status", Json::Str("draining".into()))]),
                ),
            )
        }
        (
            _,
            "/healthz" | "/stats" | "/metrics" | "/debug/traces"
            | "/v1/nn" | "/v1/embed" | "/admin/shutdown",
        ) => Pending::Ready(
            "other",
            error(405, &format!("method {} not allowed here", req.method)),
        ),
        (_, path) => {
            Pending::Ready("other", error(404, &format!("no route {path}")))
        }
    }
}

/// Phase 2: await the engine (for nn) and build the response.
pub(crate) fn finish(state: &AppState, pending: Pending) -> (&'static str, Response) {
    match pending {
        Pending::Ready(route, resp) => (route, resp),
        Pending::Deferred(route, work) => (route, work(state)),
        Pending::Nn { rx, _permit } => {
            let resp = match rx.recv() {
                Ok(Ok(neighbors)) => neighbors_response(state, &neighbors),
                // the engine rejected the query (bad id/vector) — the
                // client's fault, not the server's
                Ok(Err(msg)) => error(400, &msg),
                Err(_) => error(500, "serving engine stopped"),
            };
            ("nn", resp)
        }
    }
}

fn error(status: u16, msg: &str) -> Response {
    Response::json(
        status,
        &obj(vec![("error", Json::Str(msg.to_string()))]),
    )
}

fn parse_body(req: &Request) -> Result<Json, Response> {
    if req.body.is_empty() {
        return Err(error(400, "missing JSON request body"));
    }
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| error(400, "request body is not UTF-8"))?;
    Json::parse(text).map_err(|e| error(400, &format!("bad JSON body: {e}")))
}

/// Resolve a `{"id": N}` / `{"word": "w"}` body to a row id.
fn resolve_id(state: &AppState, body: &Json) -> Result<u32, Response> {
    match (body.get("id"), body.get("word")) {
        (Some(_), Some(_)) => {
            Err(error(400, "give exactly one of \"id\" and \"word\""))
        }
        (Some(id), None) => {
            let n = id
                .as_f64()
                .filter(|n| n.fract() == 0.0 && *n >= 0.0)
                .ok_or_else(|| {
                    error(400, "\"id\" must be a non-negative integer")
                })?;
            if n >= u32::MAX as f64 {
                return Err(error(400, "\"id\" out of range"));
            }
            Ok(n as u32)
        }
        (None, Some(word)) => {
            let word = word
                .as_str()
                .ok_or_else(|| error(400, "\"word\" must be a string"))?;
            let vocab = state.vocab.as_ref().ok_or_else(|| {
                error(400, "store has no vocabulary; query by \"id\"")
            })?;
            vocab.id(word).ok_or_else(|| {
                error(404, &format!("word '{word}' not in store vocabulary"))
            })
        }
        (None, None) => Err(error(400, "body needs \"id\" or \"word\"")),
    }
}

fn nn_begin(state: &AppState, req: &Request, trace: u64) -> Pending {
    let fail = |resp: Response| Pending::Ready("nn", resp);
    let body = match parse_body(req) {
        Ok(b) => b,
        Err(resp) => return fail(resp),
    };
    let k = match body.get("k") {
        None => state.default_k,
        Some(v) => match v.as_f64().filter(|n| n.fract() == 0.0 && *n >= 1.0)
        {
            Some(n) => n as usize,
            None => {
                return fail(error(400, "\"k\" must be a positive integer"))
            }
        },
    };
    // resolve the query source *before* admission so malformed requests
    // never consume a slot
    enum Source {
        Id(u32),
        Vector(Vec<f32>),
    }
    let source = if let Some(vec) = body.get("vector") {
        if body.get("id").is_some() || body.get("word").is_some() {
            return fail(error(
                400,
                "give exactly one of \"id\", \"word\", and \"vector\"",
            ));
        }
        let arr = match vec.as_arr() {
            Some(a) => a,
            None => {
                return fail(error(400, "\"vector\" must be a number array"))
            }
        };
        let mut v = Vec::with_capacity(arr.len());
        for x in arr {
            match x.as_f64() {
                Some(n) => v.push(n as f32),
                None => {
                    return fail(error(
                        400,
                        "\"vector\" must be a number array",
                    ))
                }
            }
        }
        Source::Vector(v)
    } else {
        match resolve_id(state, &body) {
            Ok(id) => Source::Id(id),
            Err(resp) => return fail(resp),
        }
    };

    // admission control: refuse instead of convoying on the bounded
    // engine queue.  The permit rides inside Pending::Nn so the slot
    // frees exactly when the response has been built.
    let permit = match state.gauge.try_acquire() {
        Some(p) => p,
        None => {
            // invariant: this is the ONE place a gauge refusal happens,
            // and it pairs the gauge's own shed count with the engine
            // report's (`/stats` exposes both, `net_integration`
            // asserts they agree) — keep them paired if admission ever
            // grows a second call site
            state.stats.note_shed();
            return fail(
                error(503, "engine saturated, retry later")
                    .with_header("Retry-After", "1"),
            );
        }
    };
    let rx = match source {
        Source::Id(id) => state.client.submit_id_traced(id, k, trace),
        Source::Vector(v) => {
            state.client.submit_vector_traced(v, k, trace)
        }
    };
    Pending::Nn { rx, _permit: permit }
}

fn neighbors_response(state: &AppState, neighbors: &[Neighbor]) -> Response {
    let arr = neighbors
        .iter()
        .map(|n| {
            let mut fields = vec![("id", Json::Num(n.id as f64))];
            if let Some(vocab) = &state.vocab {
                fields.push(("word", Json::Str(vocab.word(n.id).to_string())));
            }
            fields.push(("score", Json::Num(n.score as f64)));
            obj(fields)
        })
        .collect();
    Response::json(200, &obj(vec![("neighbors", Json::Arr(arr))]))
}

fn embed(state: &AppState, body: &Json) -> Response {
    let id = match resolve_id(state, body) {
        Ok(id) => id,
        Err(resp) => return resp,
    };
    if id as usize >= state.store.vocab_size() {
        return error(
            404,
            &format!(
                "row id {id} out of range (vocab {})",
                state.store.vocab_size()
            ),
        );
    }
    let mut row = vec![0.0f32; state.store.dim()];
    match state.store.fetch_row(id, &mut row) {
        Ok(Some(())) => {}
        Ok(None) => return error(404, &format!("row id {id} out of range")),
        Err(e) => return error(500, &format!("{e:#}")),
    }
    let mut fields = vec![("id", Json::Num(id as f64))];
    if let Some(vocab) = &state.vocab {
        fields.push(("word", Json::Str(vocab.word(id).to_string())));
    }
    fields.push(("dim", Json::Num(row.len() as f64)));
    fields.push((
        "vector",
        Json::Arr(row.iter().map(|x| Json::Num(*x as f64)).collect()),
    ));
    Response::json(200, &obj(fields))
}

fn healthz(state: &AppState) -> Response {
    if state.stop.load(Ordering::Acquire) {
        return Response::json(
            503,
            &obj(vec![("status", Json::Str("draining".into()))]),
        );
    }
    Response::json(
        200,
        &obj(vec![
            ("status", Json::Str("ok".into())),
            ("vocab", Json::Num(state.store.vocab_size() as f64)),
            ("dim", Json::Num(state.store.dim() as f64)),
            ("shards", Json::Num(state.store.num_shards() as f64)),
            (
                "precision",
                Json::Str(state.store.precision().name().to_string()),
            ),
        ]),
    )
}

/// `GET /metrics`: the whole observability surface in Prometheus text —
/// the process-global [`obs::registry`], the net layer's admission
/// gauges, the engine's counters and stage decomposition, and the
/// latency histograms (engine-side and per-route wire-side). Families
/// named here are what the CI smoke test and `net_integration` grep for.
fn metrics(state: &AppState) -> Response {
    // sample process self-metrics (RSS, thread count) so every scrape
    // sees fresh values without a background sampler thread
    obs::registry::refresh_process_metrics();
    let mut w = PromWriter::new();
    obs::registry::render(&mut w);
    w.gauge(
        "fullw2v_http_inflight",
        "engine-bound requests currently admitted",
        &[],
        state.gauge.inflight() as f64,
    );
    w.gauge(
        "fullw2v_http_inflight_max",
        "admission capacity (0 = unlimited)",
        &[],
        state.gauge.capacity() as f64,
    );
    w.counter(
        "fullw2v_http_shed_total",
        "requests refused with 503 by admission control",
        &[],
        state.gauge.shed_total() as f64,
    );
    w.counter(
        "fullw2v_http_admitted_total",
        "requests admitted past the inflight gauge",
        &[],
        state.gauge.admitted_total() as f64,
    );
    let report = state.stats.report();
    w.counter(
        "fullw2v_serve_queries_total",
        "queries answered by the engine",
        &[],
        report.queries as f64,
    );
    w.counter(
        "fullw2v_serve_batches_total",
        "micro-batches dispatched",
        &[],
        report.batches as f64,
    );
    w.counter(
        "fullw2v_serve_rows_scanned_total",
        "store rows scored across all batches",
        &[],
        report.rows_scanned as f64,
    );
    w.counter(
        "fullw2v_serve_cache_hits_total",
        "hot-cache row hits",
        &[],
        report.cache_hits as f64,
    );
    w.counter(
        "fullw2v_serve_cache_misses_total",
        "hot-cache row misses",
        &[],
        report.cache_misses as f64,
    );
    w.counter(
        "fullw2v_serve_shed_total",
        "queries shed before reaching the engine queue",
        &[],
        report.shed as f64,
    );
    for (stage, ns) in report.stages.iter() {
        w.counter(
            "fullw2v_serve_stage_seconds_total",
            "batch dispatch time decomposed by pipeline stage",
            &[("stage", stage)],
            ns as f64 * 1e-9,
        );
    }
    w.histogram(
        "fullw2v_serve_request_duration_seconds",
        "engine submit-to-reply latency",
        &[],
        &state.stats.latency_histogram(),
        1e-9,
    );
    for (route, hist) in state.routes.histograms() {
        w.histogram(
            "fullw2v_http_request_duration_seconds",
            "wire request service time by route",
            &[("route", route)],
            &hist,
            1e-9,
        );
    }
    let mut resp = Response::text(200, &w.finish());
    // scrapers content-negotiate on the exposition version, so the
    // generic text type from Response::text is not enough here
    resp.content_type = super::http::PROMETHEUS_CONTENT_TYPE;
    resp
}

/// Smallest useful query-string accessor: the value of `key` in
/// `?k=v&k2=v2`, no decoding (trace-export parameters are plain
/// integers/idents).  Never panics — L4 territory.
fn query_param<'a>(target: &'a str, key: &str) -> Option<&'a str> {
    let (_, query) = target.split_once('?')?;
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// Traces served newest-first by default (`?n=K` bounds the count,
/// `?format=chrome` switches to the Chrome trace-event export).
const DEFAULT_TRACES: usize = 32;

/// `GET /debug/traces`: recent request span trees from the global
/// trace ring ([`obs::trace`]).
fn traces(target: &str) -> Response {
    let n = query_param(target, "n")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_TRACES)
        .min(obs::trace::TRACE_RING_CAP);
    let snap = obs::trace::global().snapshot(n);
    let body = match query_param(target, "format") {
        Some("chrome") => obs::trace::to_chrome(&snap),
        _ => obs::trace::to_json(&snap),
    };
    Response::json(200, &body)
}

fn stats(state: &AppState) -> Response {
    let report = state.stats.report();
    Response::json(
        200,
        &obj(vec![
            ("serve", report.to_json()),
            (
                "net",
                obj(vec![
                    (
                        "inflight",
                        Json::Num(state.gauge.inflight() as f64),
                    ),
                    (
                        "max_inflight",
                        Json::Num(state.gauge.capacity() as f64),
                    ),
                    ("shed", Json::Num(state.gauge.shed_total() as f64)),
                    (
                        "admitted",
                        Json::Num(state.gauge.admitted_total() as f64),
                    ),
                    ("routes", state.routes.to_json()),
                ]),
            ),
        ]),
    )
}
