//! Incremental HTTP/1.1 request parsing and response framing.
//!
//! Hand-rolled against `std` only, the way [`crate::util::json`]
//! hand-rolls JSON: no hyper/tiny_http exists in this offline build, and
//! the subset of HTTP/1.1 the serving front-end needs — request line,
//! headers, `Content-Length` bodies, keep-alive — is small enough to
//! implement exactly and test hard.
//!
//! The parser is *incremental*: bytes are [`RequestParser::push`]ed as
//! they arrive off the socket and [`RequestParser::next_request`] yields
//! a complete [`Request`] only once its head and body are fully
//! buffered, so requests split across arbitrary read boundaries (or
//! several requests pipelined into one read) parse identically to a
//! single clean read.  Every dimension is hard-capped ([`Limits`]):
//! request line and header section (431), header count (431), declared
//! body size (413), with anything structurally malformed rejected as
//! 400.  A protocol error poisons the parser — framing is unrecoverable
//! after a bad head, so the connection must answer and close.
//!
//! Responses are `Content-Length`-framed (never chunked), which keeps
//! the writer a single [`Response::to_bytes`] call.

use crate::util::json::{obj, Json};
use std::io::{Read, Write};

/// Default request-line cap (method + target + version).
pub const MAX_REQUEST_LINE: usize = 8 * 1024;
/// Default cap on the whole head section (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Default cap on the number of header fields.
pub const MAX_HEADERS: usize = 64;
/// Default cap on a declared request body.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Trace-propagation header: a decimal `u64` trace id.  A client (or an
/// upstream router tier) sends it on a request to adopt its own id for
/// the request's span tree; the server echoes the effective id — sent or
/// freshly minted — on the response, so the caller can correlate against
/// `GET /debug/traces` either way.
pub const TRACE_HEADER: &str = "x-fullw2v-trace";

/// Prometheus text exposition format 0.0.4 — what `GET /metrics` must
/// declare for scrapers that content-negotiate.
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Hard caps enforced while parsing; crossing one is a protocol error
/// (431 for line/header caps, 413 for the body cap), not a truncation.
#[derive(Debug, Clone)]
pub struct Limits {
    pub max_request_line: usize,
    pub max_head_bytes: usize,
    pub max_headers: usize,
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_request_line: MAX_REQUEST_LINE,
            max_head_bytes: MAX_HEAD_BYTES,
            max_headers: MAX_HEADERS,
            max_body_bytes: MAX_BODY_BYTES,
        }
    }
}

/// A protocol-level rejection: the status to answer with and a message
/// for the JSON error body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    fn new(status: u16, msg: &str) -> HttpError {
        HttpError { status, msg: msg.to_string() }
    }
}

/// One parsed request.  Header names are lowercased at parse time so
/// lookups are case-insensitive the way RFC 9110 requires.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Request target as sent (path plus optional `?query`).
    pub target: String,
    /// True for HTTP/1.1 (keep-alive by default), false for HTTP/1.0.
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Target with any query string stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parsed [`TRACE_HEADER`] value: a nonzero decimal `u64` trace id
    /// minted by the caller.  Anything malformed (and the reserved id
    /// `0`, which reads as "no id" everywhere) is ignored rather than
    /// rejected — tracing must never fail a request.
    pub fn trace_id(&self) -> Option<u64> {
        self.header(TRACE_HEADER)
            .and_then(|v| v.trim().parse::<u64>().ok())
            .filter(|id| *id != 0)
    }

    /// Connection persistence: explicit `Connection` header wins,
    /// otherwise the version default (1.1 persists, 1.0 closes).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Incremental request parser over a growing byte buffer.
pub struct RequestParser {
    limits: Limits,
    buf: Vec<u8>,
    poisoned: bool,
    /// Bytes already scanned for the head terminator; the next scan
    /// resumes just before here so trickled reads stay O(bytes) overall
    /// instead of rescanning the whole head per read.
    scanned: usize,
    /// Head declared `Expect: 100-continue` and the body hasn't arrived:
    /// the connection layer must take this (once) and emit the interim
    /// response, or clients like curl withhold the body for ~a second.
    want_continue: bool,
    /// The current request's continue hint was already raised.
    continue_raised: bool,
}

impl RequestParser {
    pub fn new(limits: Limits) -> RequestParser {
        RequestParser {
            limits,
            buf: Vec::new(),
            poisoned: false,
            scanned: 0,
            want_continue: false,
            continue_raised: false,
        }
    }

    /// True exactly once per request that is waiting on its body behind
    /// an `Expect: 100-continue`; the caller must then write the
    /// `HTTP/1.1 100 Continue` interim response.
    pub fn take_want_continue(&mut self) -> bool {
        std::mem::take(&mut self.want_continue)
    }

    /// Append bytes read off the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a returned request.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Try to parse one complete request off the front of the buffer.
    /// `Ok(None)` means more bytes are needed.  An error poisons the
    /// parser: the connection must send the error response and close,
    /// because request framing cannot be trusted past a malformed head.
    pub fn next_request(&mut self) -> Result<Option<Request>, HttpError> {
        if self.poisoned {
            return Err(HttpError::new(400, "connection already failed"));
        }
        match self.try_parse() {
            Ok(r) => Ok(r),
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    fn try_parse(&mut self) -> Result<Option<Request>, HttpError> {
        // resume the terminator scan where the last one stopped (backed
        // off 2 bytes so a terminator split across pushes is still seen)
        let start = self.scanned.saturating_sub(2);
        // LINT: allow(panic-path): `scanned <= buf.len()` always (set to
        // len() on a partial scan, reset to 0 after drain), so `start..`
        // is in bounds for any peer input.
        let found = find_blank_line(&self.buf[start..])
            .map(|(h, c)| (start + h, start + c));
        let (head_len, head_consumed) = match found {
            Some(x) => x,
            None => {
                self.scanned = self.buf.len();
                // caps apply to the *incomplete* head too, or a peer
                // could stream an unbounded header section
                if self.buf.len() > self.limits.max_head_bytes {
                    return Err(HttpError::new(
                        431,
                        "header section too large",
                    ));
                }
                if !self.buf.contains(&b'\n')
                    && self.buf.len() > self.limits.max_request_line
                {
                    return Err(HttpError::new(431, "request line too long"));
                }
                return Ok(None);
            }
        };
        if head_consumed > self.limits.max_head_bytes {
            return Err(HttpError::new(431, "header section too large"));
        }
        // LINT: allow(panic-path): `head_len` came from find_blank_line
        // over this very buffer, so it is <= buf.len() by construction.
        let head = std::str::from_utf8(&self.buf[..head_len])
            .map_err(|_| HttpError::new(400, "non-UTF-8 request head"))?;
        let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));

        let request_line = lines.next().unwrap_or("");
        if request_line.len() > self.limits.max_request_line {
            return Err(HttpError::new(431, "request line too long"));
        }
        let mut parts = request_line.split(' ').filter(|p| !p.is_empty());
        let (method, target, version) =
            match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(m), Some(t), Some(v), None) => (m, t, v),
                _ => return Err(HttpError::new(400, "malformed request line")),
            };
        if !method.bytes().all(|b| b.is_ascii_uppercase() || b == b'-') {
            return Err(HttpError::new(400, "malformed method"));
        }
        if !(target.starts_with('/') || target == "*") {
            return Err(HttpError::new(400, "malformed request target"));
        }
        let http11 = match version {
            "HTTP/1.1" => true,
            "HTTP/1.0" => false,
            _ => return Err(HttpError::new(400, "unsupported HTTP version")),
        };

        let mut headers: Vec<(String, String)> = Vec::new();
        for line in lines {
            if headers.len() >= self.limits.max_headers {
                return Err(HttpError::new(431, "too many header fields"));
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| HttpError::new(400, "malformed header field"))?;
            if name.is_empty()
                || name.contains(' ')
                || name.contains('\t')
            {
                return Err(HttpError::new(400, "malformed header name"));
            }
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }

        if headers.iter().any(|(n, _)| n == "transfer-encoding") {
            // Content-Length framing only; a body we cannot frame is a
            // request we must not guess at
            return Err(HttpError::new(
                400,
                "transfer-encoding is not supported",
            ));
        }
        // conflicting Content-Length copies are a request-smuggling
        // vector behind any intermediary that picks the other one
        // (RFC 9112 §6.3: must reject); identical repeats collapse
        let mut content_len = 0usize;
        let mut seen_cl: Option<&str> = None;
        for (n, v) in &headers {
            if n == "content-length" {
                if let Some(prev) = seen_cl {
                    if prev != v.as_str() {
                        return Err(HttpError::new(
                            400,
                            "conflicting content-length headers",
                        ));
                    }
                } else {
                    seen_cl = Some(v.as_str());
                    // RFC 9110 grammar is 1*DIGIT: no sign, no empty —
                    // from_str alone would accept "+16", which a
                    // stricter intermediary frames differently
                    if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit())
                    {
                        return Err(HttpError::new(
                            400,
                            "invalid content-length",
                        ));
                    }
                    content_len = v.parse::<usize>().map_err(|_| {
                        HttpError::new(400, "invalid content-length")
                    })?;
                }
            }
        }
        if content_len > self.limits.max_body_bytes {
            return Err(HttpError::new(413, "request body too large"));
        }

        let total = head_consumed + content_len;
        if self.buf.len() < total {
            // body still in flight; raise the continue hint once so the
            // connection layer can unblock an Expect-ing client
            if !self.continue_raised
                && headers.iter().any(|(n, v)| {
                    n == "expect" && v.eq_ignore_ascii_case("100-continue")
                })
            {
                self.continue_raised = true;
                self.want_continue = true;
            }
            return Ok(None);
        }
        // LINT: allow(panic-path): the `buf.len() < total` early return
        // above guarantees the slice is in bounds, and
        // `head_consumed <= total` by construction.
        let body = self.buf[head_consumed..total].to_vec();
        self.buf.drain(..total);
        self.scanned = 0; // next request scans the shifted buffer afresh
        self.continue_raised = false;
        self.want_continue = false;
        Ok(Some(Request {
            method: method.to_string(),
            target: target.to_string(),
            http11,
            headers,
            body,
        }))
    }
}

/// Find the blank line ending the head section.  Returns
/// `(head_len, consumed)`: `buf[..head_len]` is the head content and
/// `consumed` includes the terminator.  Accepts CRLF and bare-LF line
/// endings (robustness principle; every real client sends CRLF).
fn find_blank_line(buf: &[u8]) -> Option<(usize, usize)> {
    let mut i = 0;
    while i + 1 < buf.len() {
        if buf[i] == b'\n' {
            if buf[i + 1] == b'\n' {
                return Some((i, i + 2));
            }
            if i + 2 < buf.len() && buf[i + 1] == b'\r' && buf[i + 2] == b'\n'
            {
                return Some((i, i + 3));
            }
        }
        i += 1;
    }
    None
}

/// An outgoing response: status + body, framed by [`Response::to_bytes`].
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub extra_headers: Vec<(String, String)>,
    /// Force `Connection: close` regardless of the request's preference
    /// (protocol errors, drain).
    pub close: bool,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
            extra_headers: Vec::new(),
            close: false,
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.as_bytes().to_vec(),
            extra_headers: Vec::new(),
            close: false,
        }
    }

    /// JSON error body for a parse-level rejection; always closes.
    pub fn from_error(e: &HttpError) -> Response {
        let mut r = Response::json(
            e.status,
            &obj(vec![("error", Json::Str(e.msg.clone()))]),
        );
        r.close = true;
        r
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.extra_headers.push((name.to_string(), value.to_string()));
        self
    }

    /// Re-emit the effective trace id on the wire ([`TRACE_HEADER`]),
    /// closing the propagation loop: request header in, response header
    /// out.
    pub fn with_trace(self, id: u64) -> Response {
        self.with_header(TRACE_HEADER, &id.to_string())
    }

    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            431 => "Request Header Fields Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Response",
        }
    }

    /// Serialize with framing headers.  `keep_alive` is the request's
    /// preference; a `close`-flagged response overrides it.
    pub fn to_bytes(&self, keep_alive: bool) -> Vec<u8> {
        let persist = keep_alive && !self.close;
        let mut out = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
             Connection: {}\r\n",
            self.status,
            Self::reason(self.status),
            self.content_type,
            self.body.len(),
            if persist { "keep-alive" } else { "close" },
        )
        .into_bytes();
        for (n, v) in &self.extra_headers {
            out.extend_from_slice(format!("{n}: {v}\r\n").as_bytes());
        }
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(&self.body);
        out
    }
}

/// Minimal blocking one-shot client: connect, send one request with
/// `Connection: close`, return `(status, body)`.  This is the test /
/// example / smoke-script counterpart of the server — not a production
/// client (no keep-alive, no redirects).
pub fn simple_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> std::io::Result<(u16, Vec<u8>)> {
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(std::time::Duration::from_secs(10)))?;
    let body_bytes =
        body.map(|j| j.to_string().into_bytes()).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body_bytes.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&body_bytes)?;
    read_response(&mut stream, &mut Vec::new())
}

/// Read one close-framed or `Content-Length`-framed response off `r`.
///
/// `carry` is the caller's read-ahead buffer: reads are chunked, so a
/// read can pull in bytes of the *next* pipelined response — those stay
/// in `carry` for the next call instead of being dropped.  Pass the
/// same (initially empty) buffer across calls on one connection; a
/// one-shot read can pass `&mut Vec::new()`.
pub fn read_response(
    r: &mut impl Read,
    carry: &mut Vec<u8>,
) -> std::io::Result<(u16, Vec<u8>)> {
    let bad = |msg: &str| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
    };
    let mut buf = std::mem::take(carry);
    let mut tmp = [0u8; 4096];
    let (status, consumed, content_len) = loop {
        let (head_len, consumed) = loop {
            if let Some(x) = find_blank_line(&buf) {
                break x;
            }
            let n = r.read(&mut tmp)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof before response head",
                ));
            }
            // LINT: allow(panic-path): read() returns n <= tmp.len() by
            // contract, so the slice is in bounds.
            buf.extend_from_slice(&tmp[..n]);
        };
        // LINT: allow(panic-path): `head_len` came from find_blank_line
        // over this very buffer, so it is <= buf.len() by construction.
        let head = std::str::from_utf8(&buf[..head_len])
            .map_err(|_| bad("non-UTF-8 response head"))?;
        let mut lines =
            head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
        let status: u16 = lines
            .next()
            .and_then(|l| l.split(' ').nth(1))
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| bad("malformed status line"))?;
        // interim responses (100 Continue) have no body: skip to the
        // final response of this exchange
        if (100..200).contains(&status) {
            buf.drain(..consumed);
            continue;
        }
        let mut content_len: Option<usize> = None;
        for line in lines {
            if let Some((n, v)) = line.split_once(':') {
                if n.eq_ignore_ascii_case("content-length") {
                    content_len =
                        Some(v.trim().parse().map_err(|_| {
                            bad("malformed response content-length")
                        })?);
                }
            }
        }
        break (status, consumed, content_len);
    };
    match content_len {
        Some(cl) => {
            while buf.len() < consumed + cl {
                let n = r.read(&mut tmp)?;
                if n == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "eof inside response body",
                    ));
                }
                // LINT: allow(panic-path): read() returns n <= tmp.len()
                // by contract, so the slice is in bounds.
                buf.extend_from_slice(&tmp[..n]);
            }
            // everything past this response belongs to the next one
            *carry = buf.split_off(consumed + cl);
            buf.drain(..consumed);
            Ok((status, buf))
        }
        None => {
            // close-framed: read to EOF
            loop {
                let n = r.read(&mut tmp)?;
                if n == 0 {
                    break;
                }
                // LINT: allow(panic-path): read() returns n <= tmp.len()
                // by contract, so the slice is in bounds.
                buf.extend_from_slice(&tmp[..n]);
            }
            buf.drain(..consumed);
            Ok((status, buf))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(bytes: &[u8]) -> Result<Vec<Request>, HttpError> {
        let mut p = RequestParser::new(Limits::default());
        p.push(bytes);
        let mut out = Vec::new();
        while let Some(r) = p.next_request()? {
            out.push(r);
        }
        Ok(out)
    }

    #[test]
    fn parses_simple_get() {
        let reqs =
            parse_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(reqs.len(), 1);
        let r = &reqs[0];
        assert_eq!(r.method, "GET");
        assert_eq!(r.path(), "/healthz");
        assert!(r.http11);
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.header("HOST"), Some("x"));
        assert!(r.body.is_empty());
        assert!(r.keep_alive(), "1.1 defaults to keep-alive");
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let reqs = parse_all(
            b"POST /v1/nn?trace=1 HTTP/1.1\r\nContent-Length: 9\r\n\r\n{\"id\":3}\n",
        )
        .unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].path(), "/v1/nn");
        assert_eq!(reqs[0].target, "/v1/nn?trace=1");
        assert_eq!(reqs[0].body, b"{\"id\":3}\n");
    }

    /// The incremental contract: any split of the byte stream parses
    /// identically — feed a request one byte at a time.
    #[test]
    fn byte_at_a_time_feed_parses_identically() {
        let wire =
            b"POST /v1/nn HTTP/1.1\r\nContent-Length: 8\r\nX-A: b\r\n\r\n{\"id\":7}";
        let mut p = RequestParser::new(Limits::default());
        let mut got = None;
        for (i, byte) in wire.iter().enumerate() {
            p.push(std::slice::from_ref(byte));
            match p.next_request().unwrap() {
                Some(r) => {
                    assert_eq!(i, wire.len() - 1, "complete only at the end");
                    got = Some(r);
                }
                None => assert!(i < wire.len() - 1),
            }
        }
        let r = got.expect("request parsed");
        assert_eq!(r.body, b"{\"id\":7}");
        assert_eq!(r.header("x-a"), Some("b"));
        assert_eq!(p.buffered(), 0, "everything consumed");
    }

    /// Trace propagation parsing: well-formed decimal ids are adopted,
    /// anything else (and the reserved 0) is ignored, and the response
    /// side re-emits the id as a header.
    #[test]
    fn trace_header_parses_and_reemits() {
        let r = &parse_all(
            b"GET / HTTP/1.1\r\nX-FullW2V-Trace: 4242\r\n\r\n",
        )
        .unwrap()[0];
        assert_eq!(r.trace_id(), Some(4242), "case-insensitive lookup");
        let r = &parse_all(
            b"GET / HTTP/1.1\r\nx-fullw2v-trace:  987654321  \r\n\r\n",
        )
        .unwrap()[0];
        assert_eq!(r.trace_id(), Some(987654321), "whitespace trimmed");
        for bad in ["0", "-3", "1.5", "abc", "", "18446744073709551616"] {
            let wire = format!(
                "GET / HTTP/1.1\r\n{TRACE_HEADER}: {bad}\r\n\r\n"
            );
            let r = &parse_all(wire.as_bytes()).unwrap()[0];
            assert_eq!(r.trace_id(), None, "malformed value {bad:?}");
        }
        let r = &parse_all(b"GET / HTTP/1.1\r\n\r\n").unwrap()[0];
        assert_eq!(r.trace_id(), None, "absent header");

        let resp = Response::text(200, "ok").with_trace(u64::MAX);
        let text = String::from_utf8(resp.to_bytes(true)).unwrap();
        assert!(
            text.contains("x-fullw2v-trace: 18446744073709551615\r\n"),
            "response echoes the id: {text}"
        );
    }

    #[test]
    fn pipelined_requests_parse_in_order() {
        let reqs = parse_all(
            b"GET /healthz HTTP/1.1\r\n\r\nPOST /v1/nn HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /stats HTTP/1.1\r\n\r\n",
        )
        .unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].path(), "/healthz");
        assert_eq!(reqs[1].body, b"hi");
        assert_eq!(reqs[2].path(), "/stats");
    }

    #[test]
    fn bare_lf_line_endings_accepted() {
        let reqs = parse_all(b"GET / HTTP/1.0\nHost: y\n\n").unwrap();
        assert_eq!(reqs.len(), 1);
        assert!(!reqs[0].http11);
        assert!(!reqs[0].keep_alive(), "1.0 defaults to close");
        assert_eq!(reqs[0].header("host"), Some("y"));
    }

    #[test]
    fn connection_header_overrides_version_default() {
        let r = &parse_all(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()[0];
        assert!(!r.keep_alive());
        let r = &parse_all(
            b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n",
        )
        .unwrap()[0];
        assert!(r.keep_alive());
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for wire in [
            &b"GARBAGE\r\n\r\n"[..],
            b"GET /\r\n\r\n",
            b"GET / HTTP/2.0\r\n\r\n",
            b"GET / HTTP/1.1 extra\r\n\r\n",
            b"get / HTTP/1.1\r\n\r\n",
            b"GET nopath HTTP/1.1\r\n\r\n",
            b"\r\n\r\n",
        ] {
            let err = parse_all(wire).unwrap_err();
            assert_eq!(err.status, 400, "{:?}", String::from_utf8_lossy(wire));
        }
    }

    #[test]
    fn malformed_headers_are_400() {
        assert_eq!(
            parse_all(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n")
                .unwrap_err()
                .status,
            400
        );
        assert_eq!(
            parse_all(b"GET / HTTP/1.1\r\nbad name: x\r\n\r\n")
                .unwrap_err()
                .status,
            400
        );
        assert_eq!(
            parse_all(b"GET / HTTP/1.1\r\nContent-Length: ten\r\n\r\n")
                .unwrap_err()
                .status,
            400
        );
        // 1*DIGIT only: a signed length is a framing-desync vector
        assert_eq!(
            parse_all(b"POST / HTTP/1.1\r\nContent-Length: +5\r\n\r\nhello")
                .unwrap_err()
                .status,
            400
        );
        assert_eq!(
            parse_all(
                b"GET / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"
            )
            .unwrap_err()
            .status,
            400
        );
    }

    /// `Expect: 100-continue` raises the hint exactly once per request
    /// (curl withholds >1 KB bodies until the interim response), and a
    /// fresh request on the same connection can raise it again.
    #[test]
    fn expect_100_continue_signals_once_per_request() {
        let mut p = RequestParser::new(Limits::default());
        let head =
            b"POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n";
        p.push(head);
        assert!(p.next_request().unwrap().is_none());
        assert!(p.take_want_continue());
        assert!(!p.take_want_continue(), "hint is taken once");
        assert!(p.next_request().unwrap().is_none());
        assert!(!p.take_want_continue(), "not re-raised per poll");
        p.push(b"hi");
        let r = p.next_request().unwrap().expect("body arrived");
        assert_eq!(r.body, b"hi");
        // next request on the same connection raises its own hint
        p.push(head);
        assert!(p.next_request().unwrap().is_none());
        assert!(p.take_want_continue());
        // a request whose body arrives with the head never raises it
        let mut p = RequestParser::new(Limits::default());
        p.push(b"POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nok");
        assert!(p.next_request().unwrap().is_some());
        assert!(!p.take_want_continue());
    }

    /// RFC 9112 §6.3: conflicting Content-Length copies must be
    /// rejected — an intermediary picking the other value desyncs
    /// request framing (smuggling).  Identical repeats collapse.
    #[test]
    fn conflicting_content_lengths_are_400() {
        assert_eq!(
            parse_all(
                b"POST / HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 2\r\n\r\nhi"
            )
            .unwrap_err()
            .status,
            400
        );
        let reqs = parse_all(
            b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi",
        )
        .unwrap();
        assert_eq!(reqs[0].body, b"hi");
    }

    #[test]
    fn oversized_body_is_413() {
        let mut p = RequestParser::new(Limits {
            max_body_bytes: 16,
            ..Limits::default()
        });
        p.push(b"POST / HTTP/1.1\r\nContent-Length: 17\r\n\r\n");
        assert_eq!(p.next_request().unwrap_err().status, 413);
        // boundary: exactly the cap is accepted
        let mut p = RequestParser::new(Limits {
            max_body_bytes: 16,
            ..Limits::default()
        });
        p.push(b"POST / HTTP/1.1\r\nContent-Length: 16\r\n\r\n0123456789abcdef");
        assert!(p.next_request().unwrap().is_some());
    }

    #[test]
    fn oversized_head_is_431_even_before_terminator() {
        let limits = Limits { max_head_bytes: 64, ..Limits::default() };
        let mut p = RequestParser::new(limits);
        // stream > 64 header bytes without ever finishing the head
        p.push(b"GET / HTTP/1.1\r\n");
        p.push(&b"X-Filler: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n"[..]);
        p.push(&b"X-More: bbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbbb\r\n"[..]);
        assert_eq!(p.next_request().unwrap_err().status, 431);
        // a poisoned parser stays failed
        assert!(p.next_request().is_err());
    }

    #[test]
    fn oversized_request_line_is_431() {
        let limits = Limits { max_request_line: 32, ..Limits::default() };
        let mut p = RequestParser::new(limits.clone());
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(64));
        p.push(long.as_bytes());
        assert_eq!(p.next_request().unwrap_err().status, 431);
        // and with no newline at all yet (cap on the unterminated line)
        let mut p = RequestParser::new(limits);
        p.push("GET /".as_bytes());
        p.push("a".repeat(64).as_bytes());
        assert_eq!(p.next_request().unwrap_err().status, 431);
    }

    #[test]
    fn too_many_headers_is_431() {
        let mut p = RequestParser::new(Limits {
            max_headers: 4,
            ..Limits::default()
        });
        let mut wire = String::from("GET / HTTP/1.1\r\n");
        for i in 0..5 {
            wire.push_str(&format!("X-{i}: v\r\n"));
        }
        wire.push_str("\r\n");
        p.push(wire.as_bytes());
        assert_eq!(p.next_request().unwrap_err().status, 431);
    }

    #[test]
    fn response_framing_roundtrips() {
        let resp = Response::json(
            200,
            &obj(vec![("ok", Json::Bool(true))]),
        )
        .with_header("Retry-After", "1");
        let bytes = resp.to_bytes(true);
        let text = String::from_utf8(bytes.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.ends_with("{\"ok\":true}"));
        // the client-side reader accepts the server's own framing, and
        // bytes past one response stay in the carry for the next call
        let mut wire = bytes.clone();
        wire.extend_from_slice(&resp.to_bytes(false));
        let mut carry = Vec::new();
        let mut r = &wire[..];
        let (status, body) = read_response(&mut r, &mut carry).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        let (status2, body2) = read_response(&mut r, &mut carry).unwrap();
        assert_eq!(status2, 200);
        assert_eq!(body2, b"{\"ok\":true}");
        assert!(carry.is_empty());
        // close override: an error response never persists
        let err = Response::from_error(&HttpError::new(431, "too big"));
        let text =
            String::from_utf8(err.to_bytes(true)).unwrap();
        assert!(text.starts_with(
            "HTTP/1.1 431 Request Header Fields Too Large\r\n"
        ));
        assert!(text.contains("Connection: close\r\n"));
    }
}
