//! Connection handling: accept loop, fixed worker pool, keep-alive, and
//! graceful drain.
//!
//! Shape: one nonblocking acceptor thread feeds accepted sockets into a
//! bounded channel drained by a **fixed pool** of connection workers
//! (thread-per-connection cannot bound memory under heavy traffic; a
//! full channel backpressures into the kernel accept backlog instead).
//! Each worker runs the keep-alive loop: read with a timeout, parse as
//! many complete requests as are buffered, `begin` them all (engine
//! submissions enter the micro-batcher together — the wire-level batch
//! window), then `finish` and write responses in order.
//!
//! Shutdown is a drain, not an abort: `POST /admin/shutdown` (or
//! [`NetServer::trigger_shutdown`]) flips the stop flag; the acceptor
//! stops accepting and closes the listener, workers answer what they
//! already own with `Connection: close`, and only after every worker
//! has exited does [`NetServer::join`] stop the engine — so every
//! admitted request completes before the final report is taken.

use super::http::{Limits, RequestParser, Response};
use super::router::{self, AppState};
use super::shed::InflightGauge;
use crate::corpus::vocab::Vocab;
use crate::metrics::RouteMetrics;
use crate::obs;
use crate::serve::{QueryClient, ServeEngine, ServeReport};
use crate::util::log::{self, Level};
use crate::util::sync::lock_unpoisoned;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Front-end tuning knobs.
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Connection worker threads (also the max concurrently *served*
    /// connections; more connections queue in the accept channel).
    pub workers: usize,
    /// Engine-bound requests admitted at once before shedding with 503
    /// (0 = unlimited).  See [`super::shed`].
    pub max_inflight: usize,
    /// Per-read socket timeout — also the keep-alive idle limit.
    pub read_timeout: Duration,
    /// Per-write socket timeout.
    pub write_timeout: Duration,
    /// Parser caps (line / header / body sizes).
    pub limits: Limits,
    /// Max pipelined requests begun as one submit window.
    pub max_pipeline: usize,
    /// Neighbors returned when an nn request body omits `"k"` (the
    /// CLI's `--k` flag in `serve --listen` mode).
    pub default_k: usize,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            workers: 8,
            max_inflight: crate::config::DEFAULT_MAX_INFLIGHT,
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            limits: Limits::default(),
            max_pipeline: 32,
            default_k: crate::serve::DEFAULT_TOP_K,
        }
    }
}

/// A running HTTP front-end over a [`ServeEngine`].
///
/// The server owns the engine: connection workers hold only cloneable
/// handles ([`QueryClient`], [`crate::serve::EngineStats`]), and
/// [`NetServer::join`] / [`NetServer::stop`] drain the front-end before
/// shutting the engine down and returning its final report.
pub struct NetServer {
    addr: SocketAddr,
    state: Arc<AppState>,
    engine: ServeEngine,
    acceptor: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting.  `vocab` enables by-word queries and
    /// word-annotated results.
    pub fn start(
        engine: ServeEngine,
        vocab: Option<Vocab>,
        listen: &str,
        opts: NetOptions,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding {listen}"))?;
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let addr = listener.local_addr().context("reading bound address")?;
        let state = Arc::new(AppState {
            client: engine.client(),
            stats: engine.stats(),
            store: engine.store(),
            vocab,
            gauge: InflightGauge::new(opts.max_inflight),
            routes: RouteMetrics::new(),
            stop: AtomicBool::new(false),
            default_k: opts.default_k.max(1),
        });
        let acceptor = {
            let state = state.clone();
            std::thread::spawn(move || accept_loop(listener, state, opts))
        };
        Ok(NetServer { addr, state, engine, acceptor: Some(acceptor) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A query handle onto the underlying engine — what loopback tests
    /// compare wire answers against.
    pub fn client(&self) -> QueryClient {
        self.engine.client()
    }

    /// The admission gauge (shared) — exposed so operators and tests can
    /// observe or pre-empt capacity.
    pub fn gauge(&self) -> Arc<InflightGauge> {
        self.state.gauge.clone()
    }

    /// Begin a graceful drain without blocking (idempotent; same effect
    /// as `POST /admin/shutdown`).
    pub fn trigger_shutdown(&self) {
        self.state.stop.store(true, Ordering::Release);
    }

    /// Block until a drain is triggered, finish every admitted request,
    /// stop the engine, and return the final report.
    pub fn join(mut self) -> ServeReport {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join(); // exits only after all workers exit
        }
        self.engine.shutdown()
    }

    /// Trigger a drain and [`NetServer::join`] it.
    pub fn stop(self) -> ServeReport {
        self.trigger_shutdown();
        self.join()
    }
}

/// How often the acceptor re-checks the stop flag while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

fn accept_loop(listener: TcpListener, state: Arc<AppState>, opts: NetOptions) {
    let workers = opts.workers.max(1);
    let (tx, rx) = sync_channel::<TcpStream>(workers * 2);
    // mpsc receivers are single-consumer; the pool shares one behind a
    // mutex (each recv is one queue pop — contention is negligible next
    // to request service time)
    let rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let rx = rx.clone();
        let state = state.clone();
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || worker_loop(rx, state, opts)));
    }

    while !state.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // some platforms hand accepted sockets the listener's
                // nonblocking flag; the workers expect blocking reads
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(opts.read_timeout));
                let _ = stream.set_write_timeout(Some(opts.write_timeout));
                let mut pending = stream;
                // bounded handoff: when every worker is busy and the
                // channel is full, poll rather than block so the stop
                // flag stays responsive
                loop {
                    match tx.try_send(pending) {
                        Ok(()) => break,
                        Err(TrySendError::Full(s)) => {
                            if state.stop.load(Ordering::Acquire) {
                                drop(s); // drain started: refuse
                                break;
                            }
                            pending = s;
                            std::thread::sleep(ACCEPT_POLL);
                        }
                        Err(TrySendError::Disconnected(_)) => return,
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    drop(listener); // close the socket: connects now fail fast
    drop(tx); // workers see channel EOF after draining queued conns
    for h in handles {
        let _ = h.join();
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<TcpStream>>>,
    state: Arc<AppState>,
    opts: NetOptions,
) {
    loop {
        // hold the lock only for the pop, never while serving; a poisoned
        // lock (another worker panicked mid-pop) must not take this
        // worker down too — the receiver is still valid
        let stream = {
            let guard = lock_unpoisoned(&rx);
            guard.recv()
        };
        match stream {
            Ok(s) => handle_conn(s, &state, &opts),
            Err(_) => return, // acceptor dropped the sender: drain done
        }
    }
}

/// Process-wide request id mint (starts at 1; 0 would read as "no id").
/// The id follows the request everywhere it is observable: the engine's
/// slow-query log (via [`router::begin`]'s trace argument), the served-
/// request debug log, and — in JSON log mode — a top-level `req_id` key.
static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(1);

/// Fixed label set for a route name — label sets in the
/// [`obs::registry`] must be `'static`, so each known route maps to a
/// promoted constant (anything else folds into `other`).
fn route_labels(route: &'static str) -> obs::registry::LabelSet {
    match route {
        "nn" => &[("route", "nn")],
        "embed" => &[("route", "embed")],
        "healthz" => &[("route", "healthz")],
        "stats" => &[("route", "stats")],
        "metrics" => &[("route", "metrics")],
        "traces" => &[("route", "traces")],
        "shutdown" => &[("route", "shutdown")],
        _ => &[("route", "other")],
    }
}

/// One connection's keep-alive loop.  Exits on peer close, idle/read
/// timeout, write failure, protocol error, or drain.
fn handle_conn(mut stream: TcpStream, state: &Arc<AppState>, opts: &NetOptions) {
    let mut parser = RequestParser::new(opts.limits.clone());
    let mut rbuf = [0u8; 8192];
    'conn: loop {
        // gather a window: every request already buffered (up to the
        // pipeline cap), reading from the socket only while nothing is
        // parseable
        let mut window = Vec::new();
        let mut proto_err = None;
        loop {
            match parser.next_request() {
                Ok(Some(req)) => {
                    window.push(req);
                    if window.len() >= opts.max_pipeline.max(1) {
                        break;
                    }
                }
                Ok(None) if window.is_empty() => {
                    // drain check between reads: without it, a peer
                    // trickling an incomplete request (or just idling)
                    // would pin this worker past shutdown for as long
                    // as it keeps the read timeout fed.  With it, drain
                    // latency is bounded by one read_timeout.
                    if state.stop.load(Ordering::Acquire) {
                        break 'conn;
                    }
                    // a head waiting on its body behind Expect: the
                    // interim response is what unblocks the client
                    if parser.take_want_continue()
                        && stream
                            .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                            .is_err()
                    {
                        break 'conn;
                    }
                    match stream.read(&mut rbuf) {
                        Ok(0) => break 'conn, // peer closed
                        // LINT: allow(panic-path): read() returns n <=
                        // rbuf.len() by contract, so the slice is in
                        // bounds for any peer input.
                        Ok(n) => parser.push(&rbuf[..n]),
                        // timeout, reset, ... — nothing mid-flight, close
                        Err(_) => break 'conn,
                    }
                }
                Ok(None) => break, // serve what we have
                Err(e) => {
                    proto_err = Some(e);
                    break;
                }
            }
        }

        // phase 1 for the whole window: nn submissions enter the
        // engine queue together and micro-batch.  Each request gets its
        // own start stamp at submit; the recorded latency still includes
        // any wait on earlier responses, deliberately — HTTP/1.1
        // responses are ordered, so head-of-line time is time the
        // client really waited for this request.
        let keep_pref: Vec<bool> =
            window.iter().map(|r| r.keep_alive()).collect();
        let mut starts = Vec::with_capacity(window.len());
        let mut pendings = Vec::with_capacity(window.len());
        for req in &window {
            let rid = NEXT_REQ_ID.fetch_add(1, Ordering::Relaxed);
            // trace id: adopt the caller's `x-fullw2v-trace` header so
            // an upstream tier can nest this node's spans under its
            // own; otherwise the fresh request id doubles as one
            let tid = req.trace_id().unwrap_or(rid);
            starts.push((rid, tid, Instant::now()));
            pendings.push(router::begin(state, req, tid));
        }
        drop(window);
        // read the stop flag *after* begin: a window containing
        // /admin/shutdown must answer `Connection: close`, not promise
        // keep-alive on a socket about to be dropped.  A pending
        // protocol error closes the connection the same way — every
        // response in this window must say so, or a pooling client
        // trusts a keep-alive header on a socket about to die.
        let closing =
            state.stop.load(Ordering::Acquire) || proto_err.is_some();

        // phase 2: answer in order
        let mut close_after = closing;
        for ((pending, keep_pref), (rid, tid, started)) in
            pendings.into_iter().zip(keep_pref).zip(starts)
        {
            let (route, resp) = router::finish(state, pending);
            // close the propagation loop: the effective trace id rides
            // back on every response, matching GET /debug/traces
            let resp = resp.with_trace(tid);
            let took = started.elapsed();
            state.routes.record(route, took);
            obs::registry::counter_with(
                "fullw2v_http_requests_total",
                "HTTP requests served by route",
                route_labels(route),
            )
            .inc();
            if log::enabled(Level::Debug) {
                log::log_with(
                    Level::Debug,
                    &[
                        ("req_id", &rid.to_string()),
                        ("route", route),
                        ("status", &resp.status.to_string()),
                    ],
                    format_args!(
                        "served in {:.1}us",
                        took.as_secs_f64() * 1e6
                    ),
                );
            }
            let keep_alive = keep_pref && !closing && !resp.close;
            if !keep_alive {
                close_after = true;
            }
            if stream.write_all(&resp.to_bytes(keep_alive)).is_err() {
                break 'conn;
            }
        }
        if let Some(e) = proto_err {
            // the head could not be framed: answer the error and close
            let _ = stream.write_all(&Response::from_error(&e).to_bytes(false));
            break 'conn;
        }
        if close_after || state.stop.load(Ordering::Acquire) {
            break 'conn;
        }
    }
}
