//! Admission control: a bounded in-flight gauge for engine-bound work.
//!
//! The engine's request queue is bounded for backpressure, which means a
//! saturated engine *blocks* submitters.  Left unchecked, every incoming
//! HTTP request would join that convoy and overload would show up as
//! unbounded latency on all of them.  The gauge converts that failure
//! mode into load shedding: at most `max` engine-bound requests are
//! admitted concurrently, and everything past that is answered `503` +
//! `Retry-After` immediately — admitted requests keep their latency,
//! shed requests fail fast, and the shed count lands in
//! [`crate::serve::ServeReport::shed`] so overload is measured rather
//! than inferred from tail latency.
//!
//! Admission is a [`Permit`]: RAII, released on drop, held from submit
//! until the response is written.  Sizing rule of thumb: a few multiples
//! of the engine's `queue_depth` — enough to keep the micro-batcher
//! full, small enough that a blocked queue sheds instead of convoying.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Bounded concurrent-admissions gauge (`max == 0` disables the bound).
#[derive(Debug)]
pub struct InflightGauge {
    max: usize,
    current: AtomicUsize,
    shed: AtomicU64,
    admitted: AtomicU64,
}

impl InflightGauge {
    pub fn new(max: usize) -> Arc<InflightGauge> {
        Arc::new(InflightGauge {
            max,
            current: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
        })
    }

    /// Admit one request, or refuse (counting the shed) if `max` are
    /// already in flight.  The returned permit releases on drop.
    pub fn try_acquire(self: &Arc<Self>) -> Option<Permit> {
        // ORDERING: AcqRel — the increment must be a single RMW ordered
        // against the paired release in Permit::drop, so a freed slot is
        // observed before the next admit decision (no overshoot beyond
        // the documented transient).
        let prev = self.current.fetch_add(1, Ordering::AcqRel);
        if self.max != 0 && prev >= self.max {
            // ORDERING: AcqRel — undo of the optimistic increment, same
            // pairing discipline as the acquire above.
            self.current.fetch_sub(1, Ordering::AcqRel);
            // ORDERING: Relaxed — pure statistic; admission correctness
            // never reads it.
            self.shed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        // ORDERING: Relaxed — pure statistic, as with `shed` above.
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Some(Permit { gauge: self.clone() })
    }

    /// Requests currently admitted and not yet released.
    pub fn inflight(&self) -> usize {
        // ORDERING: Acquire — pairs with the AcqRel RMWs so a reader
        // polling for drain (inflight == 0) also observes the work those
        // releases published.
        self.current.load(Ordering::Acquire)
    }

    /// Configured bound (0 = unlimited).
    pub fn capacity(&self) -> usize {
        self.max
    }

    /// Total refusals so far.
    pub fn shed_total(&self) -> u64 {
        // ORDERING: Relaxed — statistic read for reports/metrics only.
        self.shed.load(Ordering::Relaxed)
    }

    /// Total admissions so far.
    pub fn admitted_total(&self) -> u64 {
        // ORDERING: Relaxed — statistic read for reports/metrics only.
        self.admitted.load(Ordering::Relaxed)
    }
}

/// An admitted request's slot; dropping it frees the slot.
#[derive(Debug)]
pub struct Permit {
    gauge: Arc<InflightGauge>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        // ORDERING: AcqRel — the release half of the admission pairing:
        // publishes this request's completed work to the acquire in
        // try_acquire/inflight before the slot is reusable.
        self.gauge.current.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_capacity_then_sheds() {
        let g = InflightGauge::new(2);
        let a = g.try_acquire().expect("slot 1");
        let b = g.try_acquire().expect("slot 2");
        assert_eq!(g.inflight(), 2);
        assert!(g.try_acquire().is_none(), "third must shed");
        assert!(g.try_acquire().is_none());
        assert_eq!(g.shed_total(), 2);
        assert_eq!(g.admitted_total(), 2);
        drop(a);
        let c = g.try_acquire().expect("freed slot readmits");
        assert_eq!(g.inflight(), 2);
        drop(b);
        drop(c);
        assert_eq!(g.inflight(), 0);
        assert_eq!(g.shed_total(), 2, "sheds are cumulative");
    }

    #[test]
    fn zero_capacity_means_unlimited() {
        let g = InflightGauge::new(0);
        let permits: Vec<_> =
            (0..100).map(|_| g.try_acquire().unwrap()).collect();
        assert_eq!(g.inflight(), 100);
        assert_eq!(g.shed_total(), 0);
        drop(permits);
        assert_eq!(g.inflight(), 0);
    }

    /// Hammer the gauge from many threads: every acquire is either
    /// admitted or shed (no lost updates) and the gauge drains to zero.
    /// (`inflight()` can transiently overshoot `max` while a failing
    /// acquire is between its increment and its decrement, so the
    /// mid-flight reading is deliberately not asserted.)
    #[test]
    fn concurrent_acquires_account_every_attempt() {
        let g = InflightGauge::new(4);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = g.clone();
                s.spawn(move || {
                    for _ in 0..500 {
                        if let Some(p) = g.try_acquire() {
                            drop(p);
                        }
                    }
                });
            }
        });
        assert_eq!(g.inflight(), 0);
        assert_eq!(g.admitted_total() + g.shed_total(), 8 * 500);
    }
}
