//! HTTP serving front-end over the micro-batching engine.
//!
//! PR 1–3 built the serving stack — sharded store, hot-word cache,
//! IVF-probed batched tile scans — but drove it offline from a queries
//! file.  This module is the network front of that stack: a
//! **dependency-free HTTP/1.1 server** on `std::net`, hand-rolled the
//! way [`crate::util::json`] hand-rolls JSON, because this build has no
//! registry access and the needed protocol subset (request line,
//! headers, `Content-Length` bodies, keep-alive) is small enough to
//! implement exactly and fuzz with byte-split tests.  No TLS, no HTTP/2,
//! no chunked encoding — a reverse proxy terminates those in any real
//! deployment; what must live *here* is the part a proxy cannot do:
//! feeding the engine whole micro-batches and shedding load before the
//! engine queue convoys.
//!
//! Layout:
//!
//! * [`http`] — incremental request parser (hard caps → 400/413/431)
//!   and `Content-Length`-framed responses.
//! * [`conn`] — nonblocking acceptor + fixed worker pool, keep-alive
//!   with read/write timeouts, graceful drain ([`NetServer`]).
//! * [`router`] — `POST /v1/nn`, `POST /v1/embed`, `GET /healthz`,
//!   `GET /stats`, `GET /metrics`, `GET /debug/traces`,
//!   `POST /admin/shutdown`.
//! * [`shed`] — bounded in-flight gauge; saturation answers 503 +
//!   `Retry-After` and lands in [`crate::serve::ServeReport::shed`].
//!
//! Observability rides on [`crate::obs`]: every request gets a
//! process-unique id (threaded into the engine's slow-query log and the
//! served-request debug logs; JSON log mode via `FULLW2V_LOG_FORMAT=json`
//! carries it as a `req_id` key), and `GET /metrics` exposes the whole
//! surface as Prometheus text — `fullw2v_http_*` request counters and
//! admission gauges, `fullw2v_serve_*` engine counters, a
//! `fullw2v_serve_stage_seconds_total{stage=...}` latency decomposition
//! (queue-wait / batch-fill / ivf-probe / shard-scan / top-k-merge), and
//! `_bucket`/`_sum`/`_count` histogram series for engine and per-route
//! wire latency.  The benches persist the same numbers as
//! `BENCH_*.json` artifacts (`--artifact`; schema in
//! [`crate::obs::artifact`]) so CI can upload the perf trajectory and
//! gate it with `fullw2v benchdiff`.
//!
//! **Trace propagation** (the per-request view the aggregate metrics
//! can't give): every request carries an `x-fullw2v-trace` header — a
//! nonzero decimal `u64` trace id.  A client-sent id is adopted
//! verbatim (so a caller can correlate across services); absent or
//! malformed values fall back to the server's own request id, and the
//! resolved id is echoed on the response in the same header.  Traced
//! engine requests record a span tree (root `request` span + one child
//! per [`crate::serve::SERVE_STAGES`] stage interval) into the bounded
//! global ring in [`crate::obs::trace`], exported at
//! `GET /debug/traces?n=K` (JSON, newest first) and
//! `GET /debug/traces?format=chrome` (Chrome trace-event JSON, loadable
//! in `about:tracing` / Perfetto).
//!
//! The transport-level reuse lesson (Ji et al., arXiv:1604.04661, and
//! the FULL-W2V batching thesis) is wired in at two points: requests
//! pipelined on one connection are *all submitted* to the engine before
//! any response is awaited, and concurrent connections submit through
//! the same bounded queue — so the dispatcher's micro-batches stay full
//! under network traffic and every shard row loaded is reused across
//! the whole wire-side batch.
//!
//! ```ignore
//! let engine = ServeEngine::start(store, ServeOptions::default());
//! let server = NetServer::start(engine, Some(vocab), "127.0.0.1:0",
//!                               NetOptions::default())?;
//! println!("listening on http://{}", server.local_addr());
//! let report = server.join(); // returns after POST /admin/shutdown
//! ```

pub mod conn;
pub mod http;
pub mod router;
pub mod shed;

pub use conn::{NetOptions, NetServer};
pub use http::{
    read_response, simple_request, HttpError, Limits, Request, RequestParser,
    Response,
};
pub use shed::{InflightGauge, Permit};
