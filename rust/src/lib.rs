//! # FULL-W2V — Rust + JAX + Pallas reproduction
//!
//! Reproduction of *"FULL-W2V: Fully Exploiting Data Reuse for W2V on
//! GPU-Accelerated Systems"* (Randall, Allen, Ge — ICS'21) as a
//! three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: corpus/vocab pipeline,
//!   multi-stream batching with backpressure, PJRT runtime, training
//!   loop with Hogwild-style delta scatter, CPU baselines, evaluation
//!   harness, and the analytical GPU models that regenerate the paper's
//!   tables.
//! * **L2 (python/compile/model.py)** — the batched SGNS step in JAX,
//!   AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas sentence kernels
//!   implementing the paper's data-reuse optimizations.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod batcher;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod cpu_baseline;
pub mod eval;
pub mod gpusim;
pub mod memmodel;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sampler;
pub mod util;
pub mod workbench;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
