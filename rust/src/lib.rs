//! # FULL-W2V — Rust + JAX + Pallas reproduction
//!
//! Reproduction of *"FULL-W2V: Fully Exploiting Data Reuse for W2V on
//! GPU-Accelerated Systems"* (Randall, Allen, Ge — ICS'21) as a
//! three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: corpus/vocab pipeline,
//!   multi-stream batching with backpressure, PJRT runtime, training
//!   loop with Hogwild-style delta scatter, CPU baselines, evaluation
//!   harness, and the analytical GPU models that regenerate the paper's
//!   tables.
//! * **L2 (python/compile/model.py)** — the batched SGNS step in JAX,
//!   AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas sentence kernels
//!   implementing the paper's data-reuse optimizations.
//!
//! Beyond training, the crate now covers the online half of an embedding
//! system's life: [`serve`] turns a trained [`model::EmbeddingModel`]
//! into a query engine — an on-disk sharded store (f32 + int8-quantized
//! shards), a frequency-aware hot-word cache for the Zipf head, and a
//! micro-batching top-k front-end that reports p50/p99 latency and QPS.
//! It applies the paper's locality-hierarchy insight to inference; see
//! the [`serve`] module docs for the tier-by-tier mapping.  [`net`] puts
//! that engine on the wire: a dependency-free HTTP/1.1 front-end
//! (`serve --listen`) whose connection layer submits whole request
//! windows into the micro-batcher and sheds load with 503s once the
//! engine queue saturates.
//!
//! All f32/int8 hot loops — the serving scan, the CPU baselines'
//! dot/axpy, evaluation — share one kernel layer, [`vecops`]: unrolled
//! scalar kernels plus Q×R *tile kernels* that score a block of queries
//! against a block of store rows with each row loaded once (batch-way
//! data reuse, the paper's context-window reuse applied to inference).
//! The serving engine scans every shard **once per micro-batch** through
//! these tiles rather than once per query.
//!
//! The CPU training side mirrors that discipline: [`trainer`] holds the
//! FULL-W2V reference trainer (chunk-lifetime negative block + sliding
//! context-window ring, the paper's two reuse axes) and the Hogwild
//! epoch driver that shards any chunk kernel — the three comparator
//! baselines included — across worker threads over one shared model
//! (`train --impl fullw2v --threads T`).  See the [`trainer`] module
//! docs for the memory-tier mapping.
//!
//! Both hot paths are instrumented through [`obs`]: constant-memory
//! log2-bucketed latency histograms, a process-global counter/gauge
//! registry, and stage timers that decompose per-batch serving latency
//! and per-epoch training time the way the paper's Tables 4-6 decompose
//! memory traffic. The HTTP front-end exposes it all at `GET /metrics`
//! (Prometheus text), and the benches persist `BENCH_*.json` artifacts.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod analysis;
pub mod batcher;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod cpu_baseline;
pub mod eval;
pub mod gpusim;
pub mod memmodel;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod trainer;
pub mod util;
pub mod vecops;
pub mod workbench;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
