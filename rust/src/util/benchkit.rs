//! Minimal benchmark harness (criterion is unavailable offline):
//! warmup + repeated timing with trimmed-mean reporting.  Every
//! `cargo bench` target uses this so results are comparable.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchStats {
    /// Rate given work items per iteration.
    pub fn rate(&self, items_per_iter: f64) -> f64 {
        if self.mean_secs > 0.0 {
            items_per_iter / self.mean_secs
        } else {
            0.0
        }
    }
}

/// Run `f` `warmup` times untimed, then `iters` times timed; returns the
/// trimmed mean (drops the single slowest run when iters >= 3).
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let min = times[0];
    let max = *times.last().unwrap();
    let use_n = if iters >= 3 { iters - 1 } else { iters };
    let mean = times[..use_n].iter().sum::<f64>() / use_n as f64;
    BenchStats { iters, mean_secs: mean, min_secs: min, max_secs: max }
}

/// Standard bench banner so outputs are greppable in bench_output.txt.
pub fn banner(name: &str, what: &str) {
    println!("\n################################################");
    println!("# BENCH {name}: {what}");
    println!("################################################");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let mut count = 0;
        let stats = bench(2, 5, || {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, 7); // 2 warmup + 5 timed
        assert!(stats.mean_secs >= 0.0);
        assert!(stats.min_secs <= stats.max_secs);
        assert!(stats.rate(100.0) > 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_iters_panics() {
        bench(0, 0, || {});
    }
}
