//! Deterministic pseudo-random number generators.
//!
//! Three generators, each matched to its consumer:
//!
//! * [`SplitMix64`] — seeding / stream-splitting.
//! * [`Pcg32`] — general-purpose draws in the batcher and corpus generator
//!   (small state, excellent statistical quality).
//! * [`W2vLcg`] — the exact 64-bit LCG word2vec.c uses
//!   (`next = next * 25214903917 + 11`), kept for the scalar CPU baseline so
//!   its sampling sequence matches the original implementation family.
//!
//! No external `rand` crate is available offline; these are self-contained
//! and unit-tested against reference values.

/// SplitMix64 (Steele et al.) — used to derive independent stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 32-bit output, 64-bit state (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const MULT: u64 = 6364136223846793005;

    /// Create from a seed; the stream id is fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Create with an explicit stream id (distinct streams are independent).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// The exact LCG of word2vec.c: `next_random = next_random * 25214903917 + 11`.
#[derive(Debug, Clone)]
pub struct W2vLcg {
    state: u64,
}

impl W2vLcg {
    pub fn new(seed: u64) -> Self {
        W2vLcg { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(25214903917)
            .wrapping_add(11);
        self.state
    }

    /// word2vec.c draws table indices with `(next_random >> 16) % size`.
    #[inline]
    pub fn next_index(&mut self, size: usize) -> usize {
        ((self.next_u64() >> 16) % size as u64) as usize
    }

    /// Uniform f32 in [0,1) the way word2vec.c derives probabilities
    /// (`(next_random & 0xFFFF) / 65536`).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() & 0xFFFF) as f32 / 65536.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::new(1234567);
            (0..4).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::new(1234567);
            (0..4).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = SplitMix64::new(1234568);
            (0..4).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
        // outputs are well-mixed: no two consecutive draws equal
        assert!(a.windows(2).all(|w| w[0] != w[1]));
    }

    #[test]
    fn pcg_determinism_and_stream_independence() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        assert_eq!(a.next_u32(), b.next_u32());
        let mut c = Pcg32::with_stream(42, 1);
        let mut d = Pcg32::with_stream(42, 2);
        let sc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        let sd: Vec<u32> = (0..8).map(|_| d.next_u32()).collect();
        assert_ne!(sc, sd);
    }

    #[test]
    fn bounded_is_in_range_and_covers() {
        let mut r = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_bounded(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval_mean() {
        let mut r = Pcg32::new(99);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn w2v_lcg_matches_closed_form() {
        let mut r = W2vLcg::new(1);
        assert_eq!(r.next_u64(), 25214903928); // 1*25214903917 + 11
        assert_eq!(
            r.next_u64(),
            25214903928u64.wrapping_mul(25214903917).wrapping_add(11)
        );
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }
}
