//! Panic-free locking for the request paths.
//!
//! `Mutex::lock` only errors when another thread panicked while
//! holding the lock.  On the `net/`/`serve/` request paths that must
//! not cascade into more panics (the L4 panic-path invariant): the
//! protected values here are latency/slow-query telemetry that is
//! valid at every step, so recovering the guard from a poisoned lock
//! is always sound.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Use this instead of `.lock().unwrap()` wherever a poisoned mutex
/// should degrade (keep serving with whatever state the panicking
/// thread left — by construction always consistent) rather than take
/// the whole worker down.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // poison the lock by panicking while holding it
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn plain_lock_still_works() {
        let m = Mutex::new(vec![1, 2, 3]);
        lock_unpoisoned(&m).push(4);
        assert_eq!(lock_unpoisoned(&m).len(), 4);
    }
}
