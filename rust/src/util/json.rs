//! Minimal JSON parser + writer.
//!
//! Used to read `artifacts/manifest.json` (written by the Python AOT
//! driver) and to emit machine-readable metrics/experiment rows.  No serde
//! is available offline; this implements the complete JSON grammar (RFC
//! 8259) minus some exotic escapes, which is all the manifest needs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access: `v.get("a")` -> Option<&Json>.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code)
                                    .unwrap_or('\u{FFFD}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builder for object literals in metrics code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_whitespace_and_unicode() {
        let v = Json::parse(" { \"k\" : \"π\\u00e9\" } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("πé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":1,"executables":[{"name":"x","b":64,
            "inputs":[{"name":"syn0","dtype":"f32","shape":[64,32,128]}]}]}"#;
        let v = Json::parse(src).unwrap();
        let exes = v.get("executables").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = exes[0].get("inputs").unwrap().as_arr().unwrap()
            [0]
        .get("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();
        assert_eq!(shape, vec![64, 32, 128]);
    }
}
