//! ASCII table rendering for bench output — every bench prints its paper
//! table/figure in this format so EXPERIMENTS.md rows can be pasted
//! directly.

/// A simple column-aligned table with a title.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:width$} |", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        let sep = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Render as a Markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("**{}**\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Convenience: format an f64 with fixed decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| a      | 1     |"));
        assert!(s.contains("| longer | 2.5   |"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("M", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn wrong_width_panics() {
        let mut t = Table::new("X", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
