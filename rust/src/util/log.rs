//! Tiny leveled stderr logger (no `log` crate facade needed at this scale).
//!
//! Level is process-global, settable from the CLI (`-v`, `-q`) or the
//! `FULLW2V_LOG` environment variable (`error|warn|info|debug|trace`).
//!
//! Output format is also process-global: the default human-readable text
//! lines, or JSON-lines (`FULLW2V_LOG_FORMAT=json`) where every record is
//! one `{"level":...,"msg":...}` object — structured fields such as the
//! HTTP layer's request id become top-level keys, so served-request logs
//! are grep- and jq-able without a parser. [`log_with`] attaches fields;
//! the `log_*!` macros (including `log_trace!`) stay field-free.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::json::{obj, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Line layout for every record this process emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Format {
    /// `[LEVEL] message key=value`
    Text = 0,
    /// `{"level":"info","msg":"message","key":"value"}` — one object per
    /// line, fields flattened to top level.
    Json = 1,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static FORMAT: AtomicU8 = AtomicU8::new(Format::Text as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn set_format(format: Format) {
    FORMAT.store(format as u8, Ordering::Relaxed);
}

pub fn format() -> Format {
    match FORMAT.load(Ordering::Relaxed) {
        0 => Format::Text,
        _ => Format::Json,
    }
}

pub fn init_from_env() {
    if let Ok(v) = std::env::var("FULLW2V_LOG") {
        if let Some(l) = parse_level(&v) {
            set_level(l);
        }
    }
    if let Ok(v) = std::env::var("FULLW2V_LOG_FORMAT") {
        if let Some(f) = parse_format(&v) {
            set_format(f);
        }
    }
}

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn parse_format(s: &str) -> Option<Format> {
    match s.to_ascii_lowercase().as_str() {
        "text" => Some(Format::Text),
        "json" => Some(Format::Json),
        _ => None,
    }
}

pub fn enabled(level: Level) -> bool {
    level <= self::level()
}

fn tag(level: Level) -> &'static str {
    match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

/// Level name as it appears in JSON records (trimmed, lowercase).
fn name(level: Level) -> &'static str {
    match level {
        Level::Error => "error",
        Level::Warn => "warn",
        Level::Info => "info",
        Level::Debug => "debug",
        Level::Trace => "trace",
    }
}

/// Render one record in the current format — separated from the print so
/// tests can assert on layout without capturing stderr.
fn render(
    level: Level,
    fields: &[(&'static str, &str)],
    args: std::fmt::Arguments<'_>,
) -> String {
    match format() {
        Format::Text => {
            let mut line = std::format!("[{}] {args}", tag(level));
            for (k, v) in fields {
                line.push_str(&std::format!(" {k}={v}"));
            }
            line
        }
        Format::Json => {
            let mut kv = vec![
                ("level", Json::Str(name(level).to_string())),
                ("msg", Json::Str(args.to_string())),
            ];
            for (k, v) in fields {
                kv.push((k, Json::Str(v.to_string())));
            }
            obj(kv).to_string()
        }
    }
}

/// Log with structured fields (e.g. `&[("req_id", "42")]`). Fields ride
/// as ` k=v` suffixes in text mode and top-level keys in JSON mode.
pub fn log_with(
    level: Level,
    fields: &[(&'static str, &str)],
    args: std::fmt::Arguments<'_>,
) {
    if enabled(level) {
        eprintln!("{}", render(level, fields, args));
    }
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    log_with(level, &[], args);
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn parse_formats() {
        assert_eq!(parse_format("json"), Some(Format::Json));
        assert_eq!(parse_format("TEXT"), Some(Format::Text));
        assert_eq!(parse_format("logfmt"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn text_lines_carry_fields_as_suffix() {
        let line = render(
            Level::Debug,
            &[("req_id", "42"), ("route", "nn")],
            format_args!("served in {}us", 17),
        );
        assert_eq!(line, "[DEBUG] served in 17us req_id=42 route=nn");
    }

    #[test]
    fn json_lines_are_parseable_objects() {
        // other tests share the process-global format: render directly
        // in Json via a scoped flip, restoring Text before asserting
        set_format(Format::Json);
        let line = render(
            Level::Info,
            &[("req_id", "7")],
            format_args!("served \"q\""),
        );
        set_format(Format::Text);
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.get("level").unwrap().as_str(), Some("info"));
        assert_eq!(doc.get("msg").unwrap().as_str(), Some("served \"q\""));
        assert_eq!(doc.get("req_id").unwrap().as_str(), Some("7"));
    }

    /// Regression guard: field values are caller-controlled strings (the
    /// HTTP layer logs request targets verbatim), so quotes, newlines,
    /// backslashes, and control characters must all survive the JSON
    /// escaper — one record per line, parseable, values intact.
    #[test]
    fn json_mode_escapes_hostile_field_values() {
        let hostile = "a\"b\\c\nd\te\rf\u{1}g";
        set_format(Format::Json);
        let line = render(
            Level::Warn,
            &[("target", hostile), ("note", "\u{0}leading-nul")],
            format_args!("bad query {}", "\"quoted\"\nline2"),
        );
        set_format(Format::Text);
        // the record must stay a single line: embedded newlines would
        // split one log record into two and break line-oriented readers
        assert!(!line.contains('\n'), "record spans lines: {line:?}");
        assert!(!line.contains('\r'));
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.get("target").unwrap().as_str(), Some(hostile));
        assert_eq!(
            doc.get("note").unwrap().as_str(),
            Some("\u{0}leading-nul")
        );
        assert_eq!(
            doc.get("msg").unwrap().as_str(),
            Some("bad query \"quoted\"\nline2")
        );
    }
}
