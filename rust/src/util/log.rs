//! Tiny leveled stderr logger (no `log` crate facade needed at this scale).
//!
//! Level is process-global, settable from the CLI (`-v`, `-q`) or the
//! `FULLW2V_LOG` environment variable (`error|warn|info|debug|trace`).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn init_from_env() {
    if let Ok(v) = std::env::var("FULLW2V_LOG") {
        if let Some(l) = parse_level(&v) {
            set_level(l);
        }
    }
}

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn enabled(level: Level) -> bool {
    level <= self::level()
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
