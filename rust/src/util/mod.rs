//! Shared utilities: RNG, minimal JSON, logging, ASCII tables, timing.

pub mod benchkit;
pub mod json;
pub mod log;
pub mod rng;
pub mod sync;
pub mod tables;

use std::time::Instant;

/// Simple stopwatch used by benches and the coordinator's metering.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Format a rate (items/sec) with engineering suffixes, e.g. "12.3M".
pub fn fmt_rate(rate: f64) -> String {
    if rate >= 1e9 {
        format!("{:.3}G", rate / 1e9)
    } else if rate >= 1e6 {
        format!("{:.3}M", rate / 1e6)
    } else if rate >= 1e3 {
        format!("{:.3}K", rate / 1e3)
    } else {
        format!("{:.3}", rate)
    }
}

/// Format a byte count, e.g. "1.50 GB".
pub fn fmt_bytes(bytes: f64) -> String {
    const KB: f64 = 1024.0;
    if bytes >= KB * KB * KB {
        format!("{:.3} GB", bytes / (KB * KB * KB))
    } else if bytes >= KB * KB {
        format!("{:.3} MB", bytes / (KB * KB))
    } else if bytes >= KB {
        format!("{:.3} KB", bytes / KB)
    } else {
        format!("{:.0} B", bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_suffixes() {
        assert_eq!(fmt_rate(1_500.0), "1.500K");
        assert_eq!(fmt_rate(2_500_000.0), "2.500M");
        assert_eq!(fmt_rate(3.25e9), "3.250G");
        assert_eq!(fmt_rate(12.0), "12.000");
    }

    #[test]
    fn byte_suffixes() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.000 KB");
        assert!(fmt_bytes(3.0 * 1024.0 * 1024.0 * 1024.0).ends_with("GB"));
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
    }
}
