//! Online serving: sharded embedding store + hot cache + top-k engine.
//!
//! FULL-W2V's thesis is that W2V is memory-bound and that a locality
//! hierarchy (registers → shared memory → HBM) recovers the lost
//! throughput.  Serving a trained model has the same shape: nearest-
//! neighbor traffic is dominated by row reads, and query frequency
//! follows the corpus's Zipf law.  This subsystem maps the hierarchy
//! onto the inference side:
//!
//! | training (paper)        | serving (this module)                    |
//! |-------------------------|------------------------------------------|
//! | registers: center word  | [`crate::vecops`] tile kernels — a row   |
//! |                         | feeds Q query accumulators per load      |
//! | shared memory: ctx/negs | [`cache::HotCache`] — pinned Zipf head — |
//! |                         | and the [`ivf`] centroid table: a small  |
//! |                         | hot working set consulted every batch    |
//! | HBM: embedding tables   | [`store::ShardedStore`] — lazy shards;   |
//! |                         | probing touches only `nprobe` clusters   |
//! | host memory / NVMe      | [`mmapfile`] cold tier — shards are      |
//! |                         | demand-paged `mmap`s, scanned zero-copy; |
//! |                         | bytes never transit a heap copy          |
//! | kernel params / consts  | the binary IVF sidecar (`ivf.bin`) — a   |
//! |                         | v3 store's metadata loads in O(clusters),|
//! |                         | never an O(vocab) JSON parse             |
//! | CUDA streams / batches  | [`engine::ServeEngine`] micro-batches    |
//!
//! The scan path is *batched end to end*: the engine hands whole
//! micro-batches to shard workers, [`ann::search_shard_batch`] walks
//! each shard once per batch through [`crate::vecops`] tile kernels
//! over zero-copy [`store::RowBlock`] views, and every query's top-k
//! heap advances in that single pass.  Row loads drop from
//! `O(batch x rows)` to `O(rows)` — the serving analogue of the
//! paper's context-window reuse — and the realized reuse is reported
//! as [`engine::ServeReport::rows_loaded_per_query`].
//!
//! On top of that, a clustered store carries an [`ivf`] coarse index:
//! rows are reordered by k-means cluster at export, each batch scores
//! once against the centroid table (int8 prescore, exact-f32 rescore
//! of the shortlist), and queries are grouped into **per-query probe
//! lists** — co-probing queries share one scan over their cluster
//! set's contiguous row blocks, and no query's heap advances over
//! another's probe rows ([`ivf::plan_probes_per_query`]).  That takes
//! row traffic **sublinear in vocabulary size** — at a recall cost
//! measured against the exhaustive scan in `bench_serve`, which also
//! compares per-query vs batch-union planning via
//! [`engine::ServeReport::rows_advanced`].  `nprobe = 0` (the default)
//! and flat v1 stores keep the exact exhaustive scan.
//!
//! Store formats: v1 = flat shards, v2 = + IVF metadata in
//! `store.json`, v3 (the `export-store` default) = IVF metadata in the
//! binary sidecar [`store::SIDECAR_FILE`].  All three open through the
//! same [`store::ShardedStore::open`] and answer bit-identically at
//! `nprobe = 0`; mmap and heap-fallback paths (`FULLW2V_NO_MMAP=1`)
//! are bit-identical too, pinned by the integration suite.
//!
//! Typical flow:
//!
//! ```ignore
//! let manifest = serve::export_store(&model, &vocab, dir, 4)?;
//! let store = Arc::new(ShardedStore::open(dir, Precision::Exact)?);
//! let engine = ServeEngine::start(store, ServeOptions::default());
//! let client = engine.client();
//! let neighbors = client.query_id(word_id, 10)?;
//! drop(client);
//! let report = engine.shutdown(); // p50/p99/QPS, cache hit rate
//! ```
//!
//! The store also writes int8-quantized shards (~4x smaller); open with
//! [`store::Precision::Quantized`] to trade ≤ `max_abs/254` per-component
//! error for footprint.  `examples/serve_query.rs` measures the top-k
//! agreement between the two precisions end to end.

pub mod ann;
pub mod cache;
pub mod engine;
pub mod ivf;
pub mod mmapfile;
pub mod store;

pub use ann::{
    search_rows, search_shard, search_shard_batch, search_shards_batch,
    search_shards_batch_groups, search_shards_batch_ranges, BatchQuery,
    Neighbor, TopK,
};
pub use cache::{CacheStats, HotCache};
pub use engine::{
    EngineStats, QueryClient, QueryResponse, ServeEngine, ServeOptions,
    ServeReport, SlowQuery, SERVE_STAGES,
};
pub use ivf::{
    plan_probes_per_query, ClusterRange, IvfMeta, PerQueryPlan, ProbeGroup,
    ProbePlan,
};
pub use store::{
    export_store, export_store_clustered, export_store_clustered_as,
    Precision, RowBlock, Shard, ShardedStore, StoreFormat, StoreManifest,
    SIDECAR_FILE,
};

/// Default top-k for neighbor queries — the single source behind the
/// CLI's `--k` default and the HTTP layer's `"k"`-less request bodies.
pub const DEFAULT_TOP_K: usize = 10;

/// Head-skewed query-id stream for benches and examples.  Vocabulary ids
/// are frequency ranks in this codebase, so cubing a uniform draw
/// concentrates traffic on the Zipf head the cache tier is built for.
pub fn zipf_ids(n: usize, vocab_size: usize, seed: u64) -> Vec<u32> {
    assert!(vocab_size > 0, "zipf_ids needs a non-empty vocabulary");
    let mut rng = crate::util::rng::Pcg32::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.next_f64();
            (((u * u * u) * vocab_size as f64) as usize).min(vocab_size - 1)
                as u32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::zipf_ids;

    #[test]
    fn zipf_ids_are_head_heavy_and_in_range() {
        let ids = zipf_ids(2000, 100, 3);
        assert_eq!(ids.len(), 2000);
        assert!(ids.iter().all(|&i| i < 100));
        let head = ids.iter().filter(|&&i| i < 10).count();
        // cubing the draw puts ~46% of traffic on the top decile
        assert!(head > 600, "only {head}/2000 queries hit the head");
        // deterministic per seed
        assert_eq!(ids, zipf_ids(2000, 100, 3));
        assert_ne!(ids, zipf_ids(2000, 100, 4));
    }
}
