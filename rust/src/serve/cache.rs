//! Hot-word cache: the RAM tier between queries and the sharded store.
//!
//! The paper's lifetime analysis shows W2V's row accesses follow the
//! corpus's Zipf law — a small head of words accounts for most touches —
//! and exploits it with registers/shared memory.  Serving sees the same
//! skew in query traffic, so this tier keeps the head resident:
//!
//! * an exact LRU over recently fetched rows (intrusive doubly-linked
//!   list, O(1) touch/insert), and
//! * a *protected* set: vocabulary ids below `protected` are pinned once
//!   inserted and never evicted.  Ids in this codebase are assigned in
//!   descending frequency order, so `id < protected` **is** the Zipf
//!   head — no separate frequency table is needed.
//!
//! Rows are held as `Arc<[f32]>`, so a hit hands back a reference-
//! counted handle (one atomic increment) instead of copying the row —
//! the row itself is loaded from the cold tier once and then shared
//! with every batch that queries it.
//!
//! The cache is owned by the engine's dispatcher thread, so it needs no
//! interior locking.

use std::collections::HashMap;
use std::sync::Arc;

const NIL: usize = usize::MAX;

struct Node {
    id: u32,
    prev: usize,
    next: usize,
    pinned: bool,
    row: Arc<[f32]>,
}

/// Hit/miss counters (monotonic over the cache's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub len: usize,
    pub pinned: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Bounded LRU row cache with a pinned frequency head.
pub struct HotCache {
    dim: usize,
    capacity: usize,
    protected: u32,
    map: HashMap<u32, usize>,
    nodes: Vec<Node>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
    stats: CacheStats,
}

impl HotCache {
    /// `capacity` rows total (0 disables caching); ids `< protected` are
    /// never evicted once inserted.  `protected` is clamped to
    /// `capacity` so pinning can never exceed the budget — note that
    /// `protected == capacity` deliberately dedicates the whole cache
    /// to the head: tail rows are then never cached (see
    /// `full_pinned_cache_skips_inserts`), which is the right trade
    /// when the head dominates traffic.
    pub fn new(dim: usize, capacity: usize, protected: usize) -> Self {
        HotCache {
            dim,
            capacity,
            protected: protected.min(capacity) as u32,
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            stats: CacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats { len: self.len(), ..self.stats }
    }

    pub fn contains(&self, id: u32) -> bool {
        self.map.contains_key(&id)
    }

    /// Look up a row, counting a hit or miss and refreshing recency.
    /// A hit returns an `Arc` clone of the resident row — no copy.
    pub fn get(&mut self, id: u32) -> Option<Arc<[f32]>> {
        match self.map.get(&id).copied() {
            Some(i) => {
                self.stats.hits += 1;
                self.detach(i);
                self.push_front(i);
                Some(self.nodes[i].row.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert a row fetched from the cold tier, evicting the LRU
    /// unpinned entry when full.  A full cache of pinned rows (or
    /// capacity 0) silently skips the insert.  The caller keeps (a
    /// clone of) the same `Arc`, so cache and in-flight batches share
    /// one allocation.
    pub fn insert(&mut self, id: u32, row: Arc<[f32]>) {
        assert_eq!(row.len(), self.dim, "row width mismatch");
        if self.capacity == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&id) {
            self.nodes[i].row = row;
            self.detach(i);
            self.push_front(i);
            return;
        }
        if self.map.len() == self.capacity && !self.evict_one() {
            return; // everything pinned
        }
        let pinned = id < self.protected;
        if pinned {
            self.stats.pinned += 1;
        }
        let node = Node { id, prev: NIL, next: NIL, pinned, row };
        let i = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.push_front(i);
        self.map.insert(id, i);
    }

    /// Pre-load the protected head from a row source (e.g. the store at
    /// startup), so the first wave of hot queries doesn't fault.
    pub fn warm<F: FnMut(u32, &mut [f32]) -> bool>(&mut self, mut fetch: F) {
        for id in 0..self.protected {
            if self.contains(id) {
                continue;
            }
            let mut buf = vec![0.0f32; self.dim];
            if fetch(id, &mut buf) {
                self.insert(id, buf.into());
            }
        }
    }

    /// Evict the least-recently-used unpinned entry; false if none.
    fn evict_one(&mut self) -> bool {
        let mut i = self.tail;
        while i != NIL && self.nodes[i].pinned {
            i = self.nodes[i].prev;
        }
        if i == NIL {
            return false;
        }
        self.detach(i);
        self.map.remove(&self.nodes[i].id);
        // drop our reference now; in-flight batches holding a clone
        // keep the row alive until they finish
        self.nodes[i].row = Vec::new().into();
        self.free.push(i);
        self.stats.evictions += 1;
        true
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.nodes[i].prev, self.nodes[i].next);
        if prev != NIL {
            self.nodes[prev].next = next;
        } else if self.head == i {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next].prev = prev;
        } else if self.tail == i {
            self.tail = prev;
        }
        self.nodes[i].prev = NIL;
        self.nodes[i].next = NIL;
    }

    fn push_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f32, d: usize) -> Arc<[f32]> {
        vec![v; d].into()
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = HotCache::new(2, 3, 0);
        c.insert(10, row(1.0, 2));
        c.insert(11, row(2.0, 2));
        c.insert(12, row(3.0, 2));
        // touch 10 so 11 becomes LRU
        assert!(c.get(10).is_some());
        c.insert(13, row(4.0, 2));
        assert!(c.contains(10));
        assert!(!c.contains(11), "LRU entry should have been evicted");
        assert!(c.contains(12) && c.contains(13));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn pinned_head_survives_pressure() {
        // ids < 2 are protected
        let mut c = HotCache::new(2, 3, 2);
        c.insert(0, row(0.0, 2));
        c.insert(1, row(1.0, 2));
        for id in 100..120 {
            c.insert(id, row(id as f32, 2));
        }
        assert!(c.contains(0) && c.contains(1), "pinned rows evicted");
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn full_pinned_cache_skips_inserts() {
        let mut c = HotCache::new(2, 2, 2);
        c.insert(0, row(0.0, 2));
        c.insert(1, row(1.0, 2));
        c.insert(50, row(5.0, 2));
        assert!(!c.contains(50));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = HotCache::new(2, 2, 0);
        assert!(c.get(7).is_none());
        c.insert(7, row(7.0, 2));
        assert_eq!(&c.get(7).unwrap()[..], &[7.0, 7.0]);
        assert!(c.get(8).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_zero_disables() {
        let mut c = HotCache::new(4, 0, 10);
        c.insert(1, row(1.0, 4));
        assert!(c.get(1).is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn reinsert_updates_payload() {
        let mut c = HotCache::new(2, 2, 0);
        c.insert(3, row(1.0, 2));
        c.insert(3, row(9.0, 2));
        assert_eq!(c.len(), 1);
        assert_eq!(&c.get(3).unwrap()[..], &[9.0, 9.0]);
    }

    #[test]
    fn warm_prefills_protected_head() {
        let mut c = HotCache::new(2, 4, 3);
        c.warm(|id, out| {
            out.fill(id as f32);
            true
        });
        assert_eq!(c.len(), 3);
        for id in 0..3 {
            assert_eq!(&c.get(id).unwrap()[..], &[id as f32, id as f32]);
        }
    }

    #[test]
    fn hit_shares_the_allocation() {
        let mut c = HotCache::new(2, 2, 0);
        let r = row(4.0, 2);
        c.insert(4, r.clone());
        let got = c.get(4).unwrap();
        assert!(
            Arc::ptr_eq(&r, &got),
            "a hit must clone the handle, not copy the row"
        );
    }

    #[test]
    fn eviction_reuses_slots() {
        let mut c = HotCache::new(2, 2, 0);
        for id in 0..50 {
            c.insert(id, row(id as f32, 2));
        }
        assert_eq!(c.len(), 2);
        assert!(c.nodes.len() <= 3, "slab should recycle freed slots");
        assert!(c.contains(48) && c.contains(49));
    }
}
