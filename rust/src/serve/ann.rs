//! Top-k cosine search over sharded, normalized rows.
//!
//! Rows are L2-normalized at export, so cosine similarity is a plain dot
//! product here.  Each shard is scanned with a bounded min-heap (only the
//! current k-th best is ever compared against), and per-shard heaps merge
//! associatively — which is what lets the engine give each worker thread
//! a disjoint shard range and combine partial results at the end.
//!
//! Ordering is fully deterministic: ties in score break toward the
//! smaller word id, in both the heap and the final sort.

use super::store::Shard;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// One search result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub score: f32,
}

/// Heap entry ordered by (score asc, id desc) so that among equal scores
/// the *larger* id is considered smaller and evicted first.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    score: f32,
    id: u32,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bounded top-k accumulator (min-heap of at most k entries).
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    heap: BinaryHeap<Reverse<Entry>>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        // the capacity is only a hint: cap it so a huge caller-supplied
        // k cannot force an allocation crash (the heap grows on demand,
        // and holds at most k entries)
        let hint = k.saturating_add(1).min(1024);
        TopK { k, heap: BinaryHeap::with_capacity(hint) }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer a candidate; keeps only the best k seen so far.
    #[inline]
    pub fn consider(&mut self, id: u32, score: f32) {
        if self.k == 0 {
            return;
        }
        let e = Entry { score, id };
        if self.heap.len() < self.k {
            self.heap.push(Reverse(e));
        } else if e > self.heap.peek().expect("non-empty").0 {
            self.heap.pop();
            self.heap.push(Reverse(e));
        }
    }

    /// Merge another accumulator into this one (associative, so partial
    /// per-shard results can be combined in any order).
    pub fn merge(&mut self, other: TopK) {
        for Reverse(e) in other.heap {
            self.consider(e.id, e.score);
        }
    }

    /// Consume into a descending-score (then ascending-id) list.
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = self
            .heap
            .into_iter()
            .map(|Reverse(e)| Neighbor { id: e.id, score: e.score })
            .collect();
        out.sort_by(|a, b| {
            b.score.total_cmp(&a.score).then_with(|| a.id.cmp(&b.id))
        });
        out
    }
}

/// Scan one shard, accumulating into `topk`.  `query` must be normalized
/// for scores to be cosines; `exclude` drops one id (typically the query
/// word itself).
pub fn search_shard(
    shard: &Shard,
    query: &[f32],
    exclude: Option<u32>,
    topk: &mut TopK,
) {
    match exclude {
        None => shard.for_each_score(query, |id, s| topk.consider(id, s)),
        Some(x) => shard.for_each_score(query, |id, s| {
            if id != x {
                topk.consider(id, s);
            }
        }),
    }
}

/// Brute-force reference over a flat row-major matrix (tests and the
/// exact/quantized agreement check in `examples/serve_query.rs`).
pub fn search_rows(
    rows: &[f32],
    dim: usize,
    query: &[f32],
    k: usize,
    exclude: Option<u32>,
) -> Vec<Neighbor> {
    let mut topk = TopK::new(k);
    for (i, row) in rows.chunks_exact(dim).enumerate() {
        let id = i as u32;
        if exclude == Some(id) {
            continue;
        }
        topk.consider(id, super::store::dot(row, query));
    }
    topk.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_best_k() {
        let mut t = TopK::new(3);
        for (id, s) in
            [(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7), (4, 0.2), (5, 0.8)]
        {
            t.consider(id, s);
        }
        let got = t.into_sorted();
        assert_eq!(
            got.iter().map(|n| n.id).collect::<Vec<_>>(),
            vec![1, 5, 3]
        );
        assert!(got[0].score >= got[1].score && got[1].score >= got[2].score);
    }

    #[test]
    fn ties_break_toward_smaller_id() {
        let mut t = TopK::new(2);
        t.consider(9, 0.5);
        t.consider(3, 0.5);
        t.consider(6, 0.5);
        let got = t.into_sorted();
        assert_eq!(got.iter().map(|n| n.id).collect::<Vec<_>>(), vec![3, 6]);
    }

    #[test]
    fn merge_equals_single_pass() {
        let scores: Vec<(u32, f32)> =
            (0..40).map(|i| (i, ((i * 13 % 17) as f32) / 17.0)).collect();
        let mut whole = TopK::new(5);
        for &(id, s) in &scores {
            whole.consider(id, s);
        }
        let mut left = TopK::new(5);
        let mut right = TopK::new(5);
        for &(id, s) in &scores[..20] {
            left.consider(id, s);
        }
        for &(id, s) in &scores[20..] {
            right.consider(id, s);
        }
        left.merge(right);
        assert_eq!(whole.into_sorted(), left.into_sorted());
    }

    #[test]
    fn k_zero_and_fewer_candidates() {
        let mut t = TopK::new(0);
        t.consider(1, 1.0);
        assert!(t.into_sorted().is_empty());

        let mut t = TopK::new(10);
        t.consider(1, 0.5);
        t.consider(2, 0.9);
        let got = t.into_sorted();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 2);
    }

    #[test]
    fn search_rows_excludes_and_ranks() {
        // 4 rows in 2-d, unit-ish
        let rows: Vec<f32> = vec![
            1.0, 0.0, //
            0.0, 1.0, //
            0.9, 0.1, //
            -1.0, 0.0,
        ];
        let got = search_rows(&rows, 2, &[1.0, 0.0], 3, Some(0));
        assert_eq!(got[0].id, 2);
        assert_eq!(got.last().unwrap().id, 3);
        assert!(!got.iter().any(|n| n.id == 0));
    }
}
