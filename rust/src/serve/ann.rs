//! Top-k cosine search over sharded, normalized rows.
//!
//! Rows are L2-normalized at export, so cosine similarity is a plain dot
//! product here.  Each shard is scanned with a bounded min-heap (only the
//! current k-th best is ever compared against), and per-shard heaps merge
//! associatively — which is what lets the engine give each worker thread
//! a disjoint shard range and combine partial results at the end.
//!
//! The engine's hot path is [`search_shard_batch`]: one pass over the
//! shard for a whole micro-batch of queries, rows flowing through the
//! [`crate::vecops`] tile kernels with batch-way reuse.
//! [`search_shard`] is the per-query path, kept as the reference the
//! batched scan is tested against (and for single-query callers).
//! [`search_shards_batch_ranges`] is the IVF-probed mode: the same tile
//! machinery restricted to a probe plan's row ranges, so row traffic
//! goes sublinear in vocabulary size (see [`super::ivf`]).
//! [`search_shards_batch_groups`] layers per-query probe lists on top:
//! one ranges-scan per group of co-probing queries, so each query's
//! heap only advances over its own probe rows.
//!
//! Ordering is fully deterministic: ties in score break toward the
//! smaller word id, in both the heap and the final sort.  For cluster-
//! reordered (v2) stores the reported ids go through the shard's
//! row→id permutation, so tie order is still by word id, not by row
//! position.

use super::ivf::ProbeGroup;
use super::store::{RowBlock, Shard};
use crate::vecops::{self, ROW_TILE};
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// One search result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub score: f32,
}

/// Heap entry ordered by (score asc, id desc) so that among equal scores
/// the *larger* id is considered smaller and evicted first.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    score: f32,
    id: u32,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bounded top-k accumulator (min-heap of at most k entries).
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    heap: BinaryHeap<Reverse<Entry>>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        // the capacity is only a hint: cap it so a huge caller-supplied
        // k cannot force an allocation crash (the heap grows on demand,
        // and holds at most k entries)
        let hint = k.saturating_add(1).min(1024);
        TopK { k, heap: BinaryHeap::with_capacity(hint) }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer a candidate; keeps only the best k seen so far.
    #[inline]
    pub fn consider(&mut self, id: u32, score: f32) {
        if self.k == 0 {
            return;
        }
        let e = Entry { score, id };
        if self.heap.len() < self.k {
            self.heap.push(Reverse(e));
        } else if self.heap.peek().is_some_and(|worst| e > worst.0) {
            self.heap.pop();
            self.heap.push(Reverse(e));
        }
    }

    /// Merge another accumulator into this one (associative, so partial
    /// per-shard results can be combined in any order).
    pub fn merge(&mut self, other: TopK) {
        for Reverse(e) in other.heap {
            self.consider(e.id, e.score);
        }
    }

    /// Consume into a descending-score (then ascending-id) list.
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = self
            .heap
            .into_iter()
            .map(|Reverse(e)| Neighbor { id: e.id, score: e.score })
            .collect();
        out.sort_by(|a, b| {
            b.score.total_cmp(&a.score).then_with(|| a.id.cmp(&b.id))
        });
        out
    }
}

/// Scan one shard, accumulating into `topk`.  `query` must be normalized
/// for scores to be cosines; `exclude` drops one id (typically the query
/// word itself).
pub fn search_shard(
    shard: &Shard,
    query: &[f32],
    exclude: Option<u32>,
    topk: &mut TopK,
) {
    match exclude {
        None => shard.for_each_score(query, |id, s| topk.consider(id, s)),
        Some(x) => shard.for_each_score(query, |id, s| {
            if id != x {
                topk.consider(id, s);
            }
        }),
    }
}

/// One query of a batched shard scan.
#[derive(Debug, Clone, Copy)]
pub struct BatchQuery<'a> {
    /// Normalized query vector, store-dim wide.
    pub vector: &'a [f32],
    /// Drop this id from the results (typically the query word itself).
    pub exclude: Option<u32>,
}

/// Scan one shard **once** for a whole batch of queries, maintaining
/// every query's top-k heap in the same pass.
///
/// Rows stream through the [`crate::vecops`] tile kernels in
/// [`ROW_TILE`]-row blocks borrowed straight from shard memory
/// ([`Shard::row_block`]) — each row is loaded once per batch and
/// reused across all queries, instead of once per query as in
/// [`search_shard`].  Scores are bit-identical to the per-query path
/// (the kernels' contract), so the two paths return identical top-k
/// lists, ties included.
pub fn search_shard_batch(
    shard: &Shard,
    queries: &[BatchQuery<'_>],
    topks: &mut [TopK],
) {
    search_shards_batch(std::iter::once(shard), queries, topks);
}

/// Batched scan over several shards — [`search_shard_batch`] with the
/// query-vector table and score scratch hoisted out of the shard loop,
/// so a whole worker range costs two allocations per batch regardless
/// of shard count.  Returns the number of rows scanned (the engine's
/// memory-traffic accounting).
pub fn search_shards_batch<'s>(
    shards: impl IntoIterator<Item = &'s Shard>,
    queries: &[BatchQuery<'_>],
    topks: &mut [TopK],
) -> u64 {
    assert_eq!(queries.len(), topks.len(), "one heap per query");
    if queries.is_empty() {
        return 0;
    }
    let vectors: Vec<&[f32]> = queries.iter().map(|q| q.vector).collect();
    // one scratch tile for all shards — no per-row or per-shard allocation
    let mut scores = vec![0.0f32; queries.len() * ROW_TILE];
    let mut rows_scanned = 0u64;
    for shard in shards {
        scan_shard_tiles(shard, 0, shard.rows, &vectors, queries, topks, &mut scores);
        rows_scanned += shard.rows as u64;
    }
    rows_scanned
}

/// IVF-probed batched scan: like [`search_shards_batch`], but only the
/// global row `ranges` (sorted, disjoint — a probe plan's cluster
/// lists, see [`super::ivf::plan_probes`]) are touched, clipped to each
/// shard's span.  Cluster lists are contiguous row blocks in a v2
/// store, so they stream through the same tile kernels with the same
/// batch-way reuse; rows outside the plan are never loaded, which is
/// what takes per-query row traffic below vocabulary size.  Returns the
/// number of rows scanned.
pub fn search_shards_batch_ranges<'s>(
    shards: impl IntoIterator<Item = &'s Shard>,
    ranges: &[(usize, usize)],
    queries: &[BatchQuery<'_>],
    topks: &mut [TopK],
) -> u64 {
    assert_eq!(queries.len(), topks.len(), "one heap per query");
    if queries.is_empty() || ranges.is_empty() {
        return 0;
    }
    let vectors: Vec<&[f32]> = queries.iter().map(|q| q.vector).collect();
    let mut scores = vec![0.0f32; queries.len() * ROW_TILE];
    let mut rows_scanned = 0u64;
    for shard in shards {
        let s0 = shard.start_row;
        let s1 = s0 + shard.rows;
        for &(r0, rlen) in ranges {
            let r1 = r0.saturating_add(rlen);
            if r1 <= s0 {
                continue;
            }
            if r0 >= s1 {
                break; // ranges are sorted: nothing further overlaps
            }
            let lo = r0.max(s0) - s0;
            let hi = r1.min(s1) - s0;
            scan_shard_tiles(
                shard, lo, hi - lo, &vectors, queries, topks, &mut scores,
            );
            rows_scanned += (hi - lo) as u64;
        }
    }
    rows_scanned
}

/// Per-query probed scan: each [`ProbeGroup`]'s ranges are scanned once
/// for just that group's queries ([`search_shards_batch_ranges`] per
/// group), so a query's heap advances only over rows its **own** probe
/// list selected — co-probing queries still share their group's row
/// loads.  Returns `(rows_loaded, rows_advanced)`: physical tile loads
/// summed across groups, and the per-query heap-advance total (Σ group
/// rows x group size).  A union scan of the same batch advances
/// `union_rows x batch_size`; the gap between the two is exactly what
/// per-query planning saves.
pub fn search_shards_batch_groups(
    shards: &[&Shard],
    groups: &[ProbeGroup],
    queries: &[BatchQuery<'_>],
    topks: &mut [TopK],
) -> (u64, u64) {
    assert_eq!(queries.len(), topks.len(), "one heap per query");
    let mut rows_loaded = 0u64;
    let mut rows_advanced = 0u64;
    for g in groups {
        if g.queries.is_empty() || g.ranges.is_empty() {
            continue;
        }
        let sub_queries: Vec<BatchQuery<'_>> =
            g.queries.iter().map(|&q| queries[q]).collect();
        // move the group's heaps out, scan, move them back — the borrow
        // checker can't prove the index subsets disjoint, and an empty
        // TopK placeholder costs nothing
        let mut sub_topks: Vec<TopK> = g
            .queries
            .iter()
            .map(|&q| std::mem::replace(&mut topks[q], TopK::new(0)))
            .collect();
        let loaded = search_shards_batch_ranges(
            shards.iter().copied(),
            &g.ranges,
            &sub_queries,
            &mut sub_topks,
        );
        rows_loaded += loaded;
        rows_advanced += loaded * g.queries.len() as u64;
        for (&q, t) in g.queries.iter().zip(sub_topks) {
            topks[q] = t;
        }
    }
    (rows_loaded, rows_advanced)
}

/// One shard's tile loop over local rows `[from, from + len)` (shared
/// by the exhaustive and probed entry points); `scores` is the caller's
/// `queries.len() * ROW_TILE` scratch.
fn scan_shard_tiles(
    shard: &Shard,
    from: usize,
    len: usize,
    vectors: &[&[f32]],
    queries: &[BatchQuery<'_>],
    topks: &mut [TopK],
    scores: &mut [f32],
) {
    let end = from + len; // row_block re-checks bounds per tile
    let mut start = from;
    while start < end {
        let n = ROW_TILE.min(end - start);
        let tile = &mut scores[..queries.len() * n];
        match shard.row_block(start, n) {
            RowBlock::F32(rows) => {
                vecops::tile_scores_f32(rows, shard.dim, vectors, tile);
            }
            RowBlock::I8 { scales, codes } => {
                vecops::tile_scores_i8(codes, scales, shard.dim, vectors, tile);
            }
        }
        // flat stores derive ids from the row position; reordered (v2)
        // stores read the permutation — dispatch hoisted out of the
        // row loop like the precision match above
        let ids = shard.ids_block(start, n);
        let base = (shard.start_row + start) as u32;
        for ((q, topk), row_scores) in
            queries.iter().zip(topks.iter_mut()).zip(tile.chunks_exact(n))
        {
            match (q.exclude, ids) {
                (None, None) => {
                    for (r, &s) in row_scores.iter().enumerate() {
                        topk.consider(base + r as u32, s);
                    }
                }
                (Some(x), None) => {
                    for (r, &s) in row_scores.iter().enumerate() {
                        let id = base + r as u32;
                        if id != x {
                            topk.consider(id, s);
                        }
                    }
                }
                (None, Some(ids)) => {
                    for (r, &s) in row_scores.iter().enumerate() {
                        topk.consider(ids[r], s);
                    }
                }
                (Some(x), Some(ids)) => {
                    for (r, &s) in row_scores.iter().enumerate() {
                        if ids[r] != x {
                            topk.consider(ids[r], s);
                        }
                    }
                }
            }
        }
        start += n;
    }
}

/// Brute-force reference over a flat row-major matrix (tests and the
/// exact/quantized agreement check in `examples/serve_query.rs`).
pub fn search_rows(
    rows: &[f32],
    dim: usize,
    query: &[f32],
    k: usize,
    exclude: Option<u32>,
) -> Vec<Neighbor> {
    let mut topk = TopK::new(k);
    for (i, row) in rows.chunks_exact(dim).enumerate() {
        let id = i as u32;
        if exclude == Some(id) {
            continue;
        }
        topk.consider(id, vecops::dot(row, query));
    }
    topk.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_best_k() {
        let mut t = TopK::new(3);
        for (id, s) in
            [(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7), (4, 0.2), (5, 0.8)]
        {
            t.consider(id, s);
        }
        let got = t.into_sorted();
        assert_eq!(
            got.iter().map(|n| n.id).collect::<Vec<_>>(),
            vec![1, 5, 3]
        );
        assert!(got[0].score >= got[1].score && got[1].score >= got[2].score);
    }

    /// Regression for the panic-path fix in `consider`: once the heap
    /// is at capacity the worst-entry comparison goes through a
    /// non-panicking peek, and candidates on both sides of the floor
    /// still resolve correctly at the k == heap-len boundary.
    #[test]
    fn consider_at_capacity_replaces_without_panicking() {
        let mut t = TopK::new(1);
        t.consider(7, 0.3); // fills the heap: len == k == 1
        t.consider(8, 0.1); // below the floor: dropped via the peek path
        t.consider(9, 0.6); // above the floor: replaces via the peek path
        let got = t.into_sorted();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 9);
    }

    #[test]
    fn ties_break_toward_smaller_id() {
        let mut t = TopK::new(2);
        t.consider(9, 0.5);
        t.consider(3, 0.5);
        t.consider(6, 0.5);
        let got = t.into_sorted();
        assert_eq!(got.iter().map(|n| n.id).collect::<Vec<_>>(), vec![3, 6]);
    }

    #[test]
    fn merge_equals_single_pass() {
        let scores: Vec<(u32, f32)> =
            (0..40).map(|i| (i, ((i * 13 % 17) as f32) / 17.0)).collect();
        let mut whole = TopK::new(5);
        for &(id, s) in &scores {
            whole.consider(id, s);
        }
        let mut left = TopK::new(5);
        let mut right = TopK::new(5);
        for &(id, s) in &scores[..20] {
            left.consider(id, s);
        }
        for &(id, s) in &scores[20..] {
            right.consider(id, s);
        }
        left.merge(right);
        assert_eq!(whole.into_sorted(), left.into_sorted());
    }

    #[test]
    fn k_zero_and_fewer_candidates() {
        let mut t = TopK::new(0);
        t.consider(1, 1.0);
        assert!(t.into_sorted().is_empty());

        let mut t = TopK::new(10);
        t.consider(1, 0.5);
        t.consider(2, 0.9);
        let got = t.into_sorted();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 2);
    }

    #[test]
    fn search_rows_excludes_and_ranks() {
        // 4 rows in 2-d, unit-ish
        let rows: Vec<f32> = vec![
            1.0, 0.0, //
            0.0, 1.0, //
            0.9, 0.1, //
            -1.0, 0.0,
        ];
        let got = search_rows(&rows, 2, &[1.0, 0.0], 3, Some(0));
        assert_eq!(got[0].id, 2);
        assert_eq!(got.last().unwrap().id, 3);
        assert!(!got.iter().any(|n| n.id == 0));
    }
}
