//! Read-only memory-mapped files for the store's cold tier.
//!
//! This is the **only** module in `serve/` allowed to contain `unsafe`:
//! it wraps raw `mmap`/`munmap` (declared directly against the platform
//! libc that std already links — the crate has no libc dependency)
//! behind a safe RAII [`Mmap`] owner, and confines the one other unsafe
//! operation the cold tier needs — reinterpreting a validated byte
//! range of the mapping as `&[f32]` / `&[i8]` — to [`MappedShard`],
//! whose constructor checks bounds and alignment up front so the
//! accessors can't go wrong later.  Every unsafe site carries a
//! `// SAFETY:` comment and is counted in `analysis/unsafe_budget.txt`;
//! the unsafe-audit lint reconciles the two.
//!
//! Policy, not mechanism, lives in `store.rs`: it decides *whether* to
//! map (precision, header validation, non-finite payload scan) and
//! falls back to the heap loader whenever [`map`] declines — on
//! non-linux targets, on big-endian hosts (the zero-copy cast assumes
//! the on-disk little-endian layout is the in-memory layout), when
//! `FULLW2V_NO_MMAP=1` forces the fallback, or when the syscall itself
//! fails.  The two paths must answer bit-identically; the integration
//! suite pins that.

use std::path::Path;

/// An owned read-only private mapping of a whole file.  `Drop` unmaps.
///
/// Constructed only by [`map`]; on targets where mapping is unsupported
/// the constructor declines and no value of this type ever exists.
pub struct Mmap {
    base: *const u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ and MAP_PRIVATE — immutable shared
// bytes, never written through after construction — so moving the owner
// across threads cannot race.
unsafe impl Send for Mmap {}

// SAFETY: same argument as Send — all access is through `&self` reads
// of immutable mapped bytes.
unsafe impl Sync for Mmap {}

impl Mmap {
    pub fn len(&self) -> usize {
        self.len
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(target_os = "linux", target_endian = "little"))]
        // SAFETY: `base`/`len` are exactly what mmap returned for this
        // still-live mapping, and no slice borrowed from it can outlive
        // `self` (every accessor ties the slice lifetime to `&self`).
        // The result is ignored: failing to unmap at teardown leaks
        // address space but breaks no safety invariant.
        unsafe {
            let _ = sys::munmap(self.base as *mut sys::CVoid, self.len);
        }
    }
}

/// Mapping is compiled in and not disabled by `FULLW2V_NO_MMAP`.
pub fn enabled() -> bool {
    cfg!(all(target_os = "linux", target_endian = "little"))
        && std::env::var_os("FULLW2V_NO_MMAP").is_none()
}

/// Map `path` read-only, or decline (`None`) so the caller heap-loads
/// instead: unsupported target, `FULLW2V_NO_MMAP=1`, empty file, or
/// any open/stat/mmap failure.  Never errors — the fallback is the
/// error path.
pub fn map(path: &Path) -> Option<Mmap> {
    if !enabled() {
        return None;
    }
    map_impl(path)
}

#[cfg(all(target_os = "linux", target_endian = "little"))]
fn map_impl(path: &Path) -> Option<Mmap> {
    sys::map_file(path)
}

#[cfg(not(all(target_os = "linux", target_endian = "little")))]
fn map_impl(path: &Path) -> Option<Mmap> {
    let _ = path;
    None
}

#[cfg(all(target_os = "linux", target_endian = "little"))]
mod sys {
    use super::Mmap;
    use std::fs::File;
    use std::os::fd::AsRawFd;
    use std::path::Path;

    /// Stand-in for libc's `void`: only ever used behind a pointer.
    #[repr(C)]
    pub struct CVoid {
        _opaque: [u8; 0],
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        // Both symbols come from the libc std already links; the
        // signatures match the linux x86_64/aarch64 ABI (off_t = i64).
        fn mmap(
            addr: *mut CVoid,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut CVoid;
        pub fn munmap(addr: *mut CVoid, len: usize) -> i32;
    }

    pub fn map_file(path: &Path) -> Option<Mmap> {
        let file = File::open(path).ok()?;
        let len = file.metadata().ok()?.len();
        // zero-length mappings are EINVAL, and usize::try_from guards
        // the (theoretical) 32-bit truncation
        let len = usize::try_from(len).ok()?;
        if len == 0 {
            return None;
        }
        // SAFETY: fd is a live, owned descriptor for the whole call;
        // addr = null lets the kernel pick placement; len > 0 and the
        // offset 0 is trivially page-aligned.  A read-only private
        // mapping of a regular file has no aliasing obligations for us
        // to uphold.  MAP_FAILED (-1) is checked before the pointer is
        // kept; the file may close after mmap returns (the mapping
        // keeps its own reference).
        let base = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if base as isize == -1 || base.is_null() {
            return None;
        }
        Some(Mmap { base: base as *const u8, len })
    }
}

/// A shard's payload views over one mapping: an f32 region (the exact
/// payload, or the quantized scales) and an i8 region (the quantized
/// codes; empty for exact shards).  Construction validates bounds and
/// alignment once, with checked arithmetic, so the accessors are
/// infallible afterwards.
pub struct MappedShard {
    map: Mmap,
    f32_off: usize,
    f32_len: usize,
    i8_off: usize,
    i8_len: usize,
}

impl MappedShard {
    /// `None` if either region falls outside the mapping or the f32
    /// region is misaligned (offsets are counted in bytes, lengths in
    /// elements).
    pub fn new(
        map: Mmap,
        f32_off: usize,
        f32_len: usize,
        i8_off: usize,
        i8_len: usize,
    ) -> Option<MappedShard> {
        let f32_bytes = f32_len.checked_mul(4)?;
        let f32_end = f32_off.checked_add(f32_bytes)?;
        let i8_end = i8_off.checked_add(i8_len)?;
        if f32_end > map.len || i8_end > map.len {
            return None;
        }
        // mmap returns page-aligned bases, so this only trips on a
        // misaligned offset — but check the sum anyway
        if (map.base as usize).checked_add(f32_off)? % 4 != 0 {
            return None;
        }
        Some(MappedShard { map, f32_off, f32_len, i8_off, i8_len })
    }

    /// Bytes of file behind this mapping (for traffic accounting).
    pub fn mapped_bytes(&self) -> usize {
        self.map.len
    }

    /// Payload bytes the two typed regions cover.
    pub fn payload_bytes(&self) -> usize {
        self.f32_len * 4 + self.i8_len
    }

    pub fn f32s(&self) -> &[f32] {
        // SAFETY: `new` checked that `f32_off + 4 * f32_len` lies inside
        // the mapping and that `base + f32_off` is 4-aligned; the bytes
        // are immutable (PROT_READ) for the mapping's lifetime, every
        // bit pattern is a valid f32, and the little-endian on-disk
        // layout equals the in-memory layout on the little-endian
        // targets this path is compiled for.  The returned lifetime is
        // tied to `&self`, which owns the mapping.
        unsafe {
            std::slice::from_raw_parts(
                self.map.base.add(self.f32_off) as *const f32,
                self.f32_len,
            )
        }
    }

    pub fn i8s(&self) -> &[i8] {
        // SAFETY: `new` checked `i8_off + i8_len` lies inside the
        // mapping; i8 has the same size/alignment as the mapped u8
        // bytes and every bit pattern is valid.  Immutability and
        // lifetime as in `f32s`.
        unsafe {
            std::slice::from_raw_parts(
                self.map.base.add(self.i8_off) as *const i8,
                self.i8_len,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fullw2v_mmapfile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(bytes).unwrap();
        p
    }

    #[test]
    fn maps_round_trip_typed_views() {
        let vals: Vec<f32> = (0..16).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut bytes: Vec<u8> = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[1u8, 255, 0, 7]);
        let p = tmpfile("roundtrip.bin", &bytes);
        let Some(m) = map(&p) else {
            // non-linux or FULLW2V_NO_MMAP: nothing to verify here
            return;
        };
        assert_eq!(m.len(), bytes.len());
        let shard = MappedShard::new(m, 0, vals.len(), vals.len() * 4, 4)
            .expect("in-bounds regions");
        assert_eq!(shard.f32s(), &vals[..]);
        assert_eq!(shard.i8s(), &[1i8, -1, 0, 7]);
        assert_eq!(shard.payload_bytes(), vals.len() * 4 + 4);
        assert_eq!(shard.mapped_bytes(), bytes.len());
    }

    #[test]
    fn rejects_out_of_bounds_and_misaligned_regions() {
        let p = tmpfile("oob.bin", &[0u8; 64]);
        let Some(m) = map(&p) else { return };
        assert!(MappedShard::new(m, 0, 17, 0, 0).is_none(), "f32 overrun");
        let m = map(&p).unwrap();
        assert!(MappedShard::new(m, 0, 0, 60, 5).is_none(), "i8 overrun");
        let m = map(&p).unwrap();
        assert!(MappedShard::new(m, 2, 4, 0, 0).is_none(), "misaligned f32");
        let m = map(&p).unwrap();
        assert!(
            MappedShard::new(m, usize::MAX, 1, 0, 0).is_none(),
            "offset overflow"
        );
    }

    #[test]
    fn declines_missing_and_empty_files() {
        let dir = std::env::temp_dir().join("fullw2v_mmapfile_test");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(map(&dir.join("does_not_exist.bin")).is_none());
        let p = tmpfile("empty.bin", &[]);
        assert!(map(&p).is_none(), "empty files fall back to the heap");
    }
}
