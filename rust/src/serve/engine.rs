//! Serving engine: micro-batched top-k queries over a sharded store.
//!
//! Mirrors the training pipeline's CPU/GPU split (`batcher::pipeline`):
//! clients push requests into one *bounded* channel (backpressure — a
//! slow engine blocks producers instead of ballooning memory), a
//! dispatcher thread drains up to `batch_max` pending requests into a
//! micro-batch, resolves query vectors through the [`HotCache`] tier,
//! and fans the *whole batch* out to worker threads that each own a
//! disjoint shard range.  Each worker scans its shards **once per
//! batch** ([`search_shard_batch`]) — every loaded row is reused across
//! all queries in the batch, so the dominant cost drops from
//! `O(batch x rows)` row loads to `O(rows)` with batch-way reuse.
//! Per-worker partial top-k heaps merge associatively at the front,
//! and the rows-scanned count is reported so the reuse factor is
//! measurable ([`ServeReport::rows_loaded_per_query`]).
//!
//! With an IVF index and `nprobe > 0` the dispatcher plans **per-query
//! probe lists** ([`ivf::plan_probes_per_query`]): queries that picked
//! the same cluster set share one scan, but a query's heap never
//! advances over another query's probe rows.  `ServeOptions::
//! union_probes` restores the old batch-union plan; the two are
//! compared by [`ServeReport::rows_advanced`] (per-query heap-advance
//! traffic) vs `rows_scanned` (physical row loads).
//!
//! Per-request latency (enqueue to reply) is recorded into a
//! constant-memory [`Histogram`], and the dispatcher decomposes every
//! batch's wall time into [`SERVE_STAGES`] (queue-wait / batch-fill /
//! IVF-probe / shard-scan / top-k-merge) measured as contiguous laps of
//! one clock — so the batch-side stage sums reconcile with the busy time
//! by construction.  Both are summarized as a [`ServeReport`] via
//! [`crate::metrics::LatencyStats`], alongside a bounded slow-query log
//! whose entries carry the request ids the HTTP router propagates.
//!
//! Requests submitted with a trace id (`submit_*_traced`) additionally
//! get a per-request **span tree** recorded into the global
//! [`crate::obs::trace`] ring: a `request` root plus children reusing
//! the [`SERVE_STAGES`] vocabulary that tile the request's share of its
//! batch — followable end to end from `GET /debug/traces`.

use super::ann::{
    search_shards_batch, search_shards_batch_groups,
    search_shards_batch_ranges, BatchQuery, Neighbor, TopK,
};
use super::cache::HotCache;
use super::ivf;
use super::store::ShardedStore;
use crate::metrics::LatencyStats;
use crate::obs::trace::{self, SpanRec};
use crate::obs::{Histogram, Span, StageTimes};
use crate::util::json::{obj, Json};
use crate::util::sync::lock_unpoisoned;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Stage names of the per-batch latency decomposition, in pipeline
/// order.  `queue_wait` is summed per request (time between enqueue and
/// its batch starting); the other four are dispatcher laps that tile
/// each batch's processing time, so their sums reconcile with
/// [`ServeReport::busy_seconds`].
pub const SERVE_STAGES: &[&str] =
    &["queue_wait", "batch_fill", "ivf_probe", "shard_scan", "topk_merge"];

const ST_QUEUE_WAIT: usize = 0;
const ST_BATCH_FILL: usize = 1;
const ST_IVF_PROBE: usize = 2;
const ST_SHARD_SCAN: usize = 3;
const ST_TOPK_MERGE: usize = 4;

/// Entries kept in the slow-query ring (oldest evicted first).
const SLOW_LOG_CAP: usize = 32;

/// One slow request: everything needed to correlate it with the HTTP
/// access log (`trace` is the request id `net/router` propagates; `None`
/// for direct in-process clients).
#[derive(Debug, Clone)]
pub struct SlowQuery {
    pub trace: Option<u64>,
    pub micros: f64,
    pub k: usize,
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads; 0 = one per shard, capped at the core count.
    pub workers: usize,
    /// Max requests folded into one micro-batch.
    pub batch_max: usize,
    /// Bounded request-queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Hot-cache capacity in rows; 0 disables the cache tier.
    pub cache_capacity: usize,
    /// Ids below this are pinned in the cache (the Zipf head; vocabulary
    /// ids are frequency-ranked, so this is a rank threshold).
    pub protected_rows: usize,
    /// Pre-load the protected head at startup.
    pub warm_cache: bool,
    /// IVF probe width: each query scans only its own top-`nprobe`
    /// cluster list (sublinear row traffic, approximate results; an
    /// aggressive setting can return fewer than `k` neighbors when the
    /// probed clusters hold fewer than `k` rows).  `0` keeps the exact
    /// exhaustive scan; a store without an index (flat v1 export) also
    /// falls back to exhaustive.
    pub nprobe: usize,
    /// Plan probes as one batch-wide cluster union (the pre-v3
    /// behavior) instead of per-query lists: every query's heap then
    /// advances over every probed row in the batch.  Kept as the
    /// baseline arm for the `rows_advanced` comparison in `bench_serve`.
    pub union_probes: bool,
    /// Requests slower than this (microseconds, enqueue to reply) land
    /// in the bounded slow-query log. 0 logs everything (test/debug).
    pub slow_query_us: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 0,
            batch_max: 32,
            queue_depth: 64,
            cache_capacity: 4096,
            protected_rows: 512,
            warm_cache: true,
            nprobe: 0,
            union_probes: false,
            slow_query_us: 10_000,
        }
    }
}

/// Per-query outcome: ranked neighbors, or a message for malformed
/// queries (out-of-range id, zero vector) and engine failures.
pub type QueryResponse = Result<Vec<Neighbor>, String>;

enum QueryKind {
    ById(u32),
    ByVector(Vec<f32>),
}

struct Request {
    kind: QueryKind,
    k: usize,
    reply: SyncSender<QueryResponse>,
    enqueued: Instant,
    /// Request id propagated from the HTTP front-end for slow-query
    /// correlation; `None` for direct in-process clients.
    trace: Option<u64>,
}

/// Channel message: a query, or the engine telling the dispatcher to
/// exit even while cloned clients still hold senders (their later
/// queries then fail with "serving engine stopped" instead of the
/// engine's Drop blocking on them forever).
enum Msg {
    Req(Request),
    Shutdown,
}

struct ResolvedQuery {
    vector: Arc<[f32]>,
    k: usize,
    exclude: Option<u32>,
}

struct BatchJob {
    queries: Vec<ResolvedQuery>,
    /// Batch-union IVF probe plan (sorted global row ranges); used when
    /// [`ServeOptions::union_probes`] is set.  `None` with no `groups`
    /// scans exhaustively.
    ranges: Option<Vec<(usize, usize)>>,
    /// Per-query probe plan: one scan per group of queries that picked
    /// the same cluster set.  Takes precedence over `ranges`.
    groups: Option<Vec<ivf::ProbeGroup>>,
}

/// Per-batch worker outcome: partial heaps, rows loaded from shards,
/// and rows the queries' heaps advanced over (the per-query compute
/// traffic — equals `loaded x batch` on union/exhaustive scans, less
/// under per-query probe lists).
type WorkerResult = Result<(Vec<TopK>, u64, u64), String>;

struct EngineShared {
    /// Constant-memory latency distribution (replaces the old unbounded
    /// sample reservoir): O(1) record under a short lock, exact count /
    /// sum / max, log2-bucketed quantiles.
    latency: Mutex<Histogram>,
    /// Per-stage nanoseconds, indexed by [`SERVE_STAGES`] position.
    stage_ns: [AtomicU64; 5],
    /// Dispatcher busy time (sum over batches of first-recv to last
    /// reply) — what the batch-side stage laps tile.
    busy_ns: AtomicU64,
    /// Bounded ring of recent slow queries.
    slow: Mutex<VecDeque<SlowQuery>>,
    queries: AtomicU64,
    batches: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    /// Store rows scanned across all workers (a batch of B queries
    /// scans each row once, not B times).
    rows_scanned: AtomicU64,
    /// Sum over queries of rows their top-k heaps advanced over — the
    /// per-query compute traffic that per-query probe lists shrink.
    rows_advanced: AtomicU64,
    /// Batches that went through an IVF probe plan (vs exhaustive).
    probed_batches: AtomicU64,
    /// Total clusters in those batches' probe unions.
    clusters_probed: AtomicU64,
    /// Probe groups dispatched (union plans count one per batch).
    probe_groups: AtomicU64,
    /// Cache inserts skipped because the row is mmap-resident (the page
    /// cache already holds it; pinning a heap copy would only evict
    /// rows that actually need one).
    cache_pins_avoided: AtomicU64,
    /// Requests refused by admission control before reaching the queue
    /// (the network front-end's 503 path; see [`crate::net::shed`]).
    shed: AtomicU64,
    /// Serving window, as nanos since engine start: set at the first
    /// batch's start and advanced past each batch's end, so reported QPS
    /// covers time actually spent serving, not engine lifetime.
    window_first_ns: AtomicU64,
    window_last_ns: AtomicU64,
}

impl Default for EngineShared {
    fn default() -> Self {
        EngineShared {
            latency: Mutex::new(Histogram::new()),
            stage_ns: Default::default(),
            busy_ns: AtomicU64::new(0),
            slow: Mutex::new(VecDeque::new()),
            queries: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            rows_scanned: AtomicU64::new(0),
            rows_advanced: AtomicU64::new(0),
            probed_batches: AtomicU64::new(0),
            clusters_probed: AtomicU64::new(0),
            probe_groups: AtomicU64::new(0),
            cache_pins_avoided: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            window_first_ns: AtomicU64::new(u64::MAX),
            window_last_ns: AtomicU64::new(0),
        }
    }
}

impl EngineShared {
    fn window_seconds(&self) -> f64 {
        let first = self.window_first_ns.load(Ordering::Relaxed);
        let last = self.window_last_ns.load(Ordering::Relaxed);
        if first == u64::MAX || last <= first {
            0.0
        } else {
            (last - first) as f64 / 1e9
        }
    }
}

/// Aggregate serving metrics, built at [`ServeEngine::report`] /
/// [`ServeEngine::shutdown`].
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub latency: LatencyStats,
    pub queries: u64,
    pub batches: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// Rows loaded from shards across the run; divided by `queries`
    /// this is the per-query memory traffic the batched scan amortizes.
    pub rows_scanned: u64,
    /// Sum over queries of rows their heaps advanced over.  On
    /// union/exhaustive scans this is `rows_scanned x batch_fill`; with
    /// per-query probe lists each query only pays its own probe rows,
    /// so [`Self::rows_advanced_per_query`] drops below the union
    /// plan's at equal recall.
    pub rows_advanced: u64,
    /// Probe groups dispatched (union plans count one per batch; the
    /// per-query planner emits one per distinct cluster set).
    pub probe_groups: u64,
    /// Cache inserts skipped because the row was mmap-resident.
    pub cache_pins_avoided: u64,
    /// Shard bytes served zero-copy from mappings vs heap copies (the
    /// cold-tier split; see `store::ShardedStore`).
    pub bytes_mapped: u64,
    pub bytes_heap_loaded: u64,
    pub workers: usize,
    pub shards: usize,
    pub loaded_shards: usize,
    pub precision: String,
    /// Configured probe width (0 = exhaustive scans).
    pub nprobe: usize,
    /// IVF clusters in the store's index (0 = no index / flat store).
    pub clusters: usize,
    /// Batches served through a probe plan, and the total clusters in
    /// their probe unions — the recall-side accounting: together with
    /// `rows_scanned` they say how much of the store each answer
    /// actually consulted (recall@k itself is measured against the
    /// exhaustive scan, e.g. in `bench_serve`).
    pub probed_batches: u64,
    pub clusters_probed: u64,
    /// Requests shed by admission control (answered 503 at the network
    /// front-end instead of joining a saturated queue).  Shed requests
    /// never reach the dispatcher, so they are *not* part of `queries`:
    /// overload shows up here instead of as queue-depth latency on
    /// every admitted request.
    pub shed: u64,
    /// Per-stage latency decomposition ([`SERVE_STAGES`]): `queue_wait`
    /// sums per-request waits; the other four tile `busy_seconds`.
    pub stages: StageTimes,
    /// Dispatcher busy seconds (time actually spent processing batches).
    pub busy_seconds: f64,
    /// Most recent slow queries (bounded ring; see
    /// [`ServeOptions::slow_query_us`]).
    pub slow: Vec<SlowQuery>,
}

impl ServeReport {
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean requests per micro-batch (the batching win).
    pub fn batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.queries as f64 / self.batches as f64
        }
    }

    /// Shard rows loaded per answered query.  A per-query scan pays
    /// the full row count for every query; the batched scan pays it
    /// once per batch, so this approaches `rows / batch_fill` — the
    /// data-reuse factor, measured rather than asserted.  With probing
    /// it drops further, below the vocabulary size itself.
    pub fn rows_loaded_per_query(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.rows_scanned as f64 / self.queries as f64
        }
    }

    /// Rows each query's heap advanced over, on average — the
    /// per-query cost probe-list planning minimizes.  Compare with
    /// [`Self::rows_loaded_per_query`]: loads are paid once per scan
    /// group, advances once per (query, row in its probe list).
    pub fn rows_advanced_per_query(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.rows_advanced as f64 / self.queries as f64
        }
    }

    /// Mean clusters in a probed batch's union (0 when exhaustive).
    pub fn mean_clusters_probed(&self) -> f64 {
        if self.probed_batches == 0 {
            0.0
        } else {
            self.clusters_probed as f64 / self.probed_batches as f64
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("latency", self.latency.to_json()),
            ("queries", Json::Num(self.queries as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("batch_fill", Json::Num(self.batch_fill())),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate())),
            ("cache_evictions", Json::Num(self.cache_evictions as f64)),
            ("rows_scanned", Json::Num(self.rows_scanned as f64)),
            (
                "rows_loaded_per_query",
                Json::Num(self.rows_loaded_per_query()),
            ),
            ("rows_advanced", Json::Num(self.rows_advanced as f64)),
            (
                "rows_advanced_per_query",
                Json::Num(self.rows_advanced_per_query()),
            ),
            ("probe_groups", Json::Num(self.probe_groups as f64)),
            (
                "cache_pins_avoided",
                Json::Num(self.cache_pins_avoided as f64),
            ),
            ("bytes_mapped", Json::Num(self.bytes_mapped as f64)),
            (
                "bytes_heap_loaded",
                Json::Num(self.bytes_heap_loaded as f64),
            ),
            ("workers", Json::Num(self.workers as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("loaded_shards", Json::Num(self.loaded_shards as f64)),
            ("precision", Json::Str(self.precision.clone())),
            ("nprobe", Json::Num(self.nprobe as f64)),
            ("clusters", Json::Num(self.clusters as f64)),
            ("probed_batches", Json::Num(self.probed_batches as f64)),
            (
                "mean_clusters_probed",
                Json::Num(self.mean_clusters_probed()),
            ),
            ("shed", Json::Num(self.shed as f64)),
            ("stages", self.stages.to_json()),
            ("busy_seconds", Json::Num(self.busy_seconds)),
            (
                "slow_queries",
                Json::Arr(
                    self.slow
                        .iter()
                        .map(|s| {
                            obj(vec![
                                (
                                    "trace",
                                    s.trace
                                        .map(|t| Json::Num(t as f64))
                                        .unwrap_or(Json::Null),
                                ),
                                ("micros", Json::Num(s.micros)),
                                ("k", Json::Num(s.k as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line human summary for CLI/example output.
    pub fn summary(&self) -> String {
        let probe = if self.nprobe > 0 && self.clusters > 0 {
            format!(
                " | probe {:.1}/{} clusters",
                self.mean_clusters_probed(),
                self.clusters
            )
        } else {
            String::new()
        };
        let shed = if self.shed > 0 {
            format!(" | shed {}", self.shed)
        } else {
            String::new()
        };
        format!(
            "{} queries in {} batches (fill {:.1}) | p50 {:.0}us p99 {:.0}us \
             {:.0} qps | cache hit {:.0}% | {:.0} rows/query{}{} | {}/{} \
             shards loaded ({})",
            self.queries,
            self.batches,
            self.batch_fill(),
            self.latency.p50_us,
            self.latency.p99_us,
            self.latency.qps,
            100.0 * self.cache_hit_rate(),
            self.rows_loaded_per_query(),
            probe,
            shed,
            self.loaded_shards,
            self.shards,
            self.precision,
        )
    }
}

/// Cloneable handle for submitting queries.  Outliving the engine is
/// safe: once the engine shuts down, queries fail with
/// "serving engine stopped".
#[derive(Clone)]
pub struct QueryClient {
    tx: SyncSender<Msg>,
}

impl QueryClient {
    fn submit(
        &self,
        kind: QueryKind,
        k: usize,
        trace: Option<u64>,
    ) -> Receiver<QueryResponse> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request {
            kind,
            k,
            reply: rtx,
            enqueued: Instant::now(),
            trace,
        };
        // a failed send drops `req` (and its reply sender), so the
        // receiver observes a hangup and query_* maps it to an error
        let _ = self.tx.send(Msg::Req(req));
        rrx
    }

    /// Asynchronous submit by word id; received results are ranked
    /// neighbors excluding the query word itself.
    pub fn submit_id(&self, id: u32, k: usize) -> Receiver<QueryResponse> {
        self.submit(QueryKind::ById(id), k, None)
    }

    /// [`Self::submit_id`] tagged with a request id for slow-query
    /// correlation (the HTTP router's per-request id).
    pub fn submit_id_traced(
        &self,
        id: u32,
        k: usize,
        trace: u64,
    ) -> Receiver<QueryResponse> {
        self.submit(QueryKind::ById(id), k, Some(trace))
    }

    /// Asynchronous submit of a raw (not necessarily normalized) vector.
    pub fn submit_vector(
        &self,
        vector: Vec<f32>,
        k: usize,
    ) -> Receiver<QueryResponse> {
        self.submit(QueryKind::ByVector(vector), k, None)
    }

    /// [`Self::submit_vector`] tagged with a request id.
    pub fn submit_vector_traced(
        &self,
        vector: Vec<f32>,
        k: usize,
        trace: u64,
    ) -> Receiver<QueryResponse> {
        self.submit(QueryKind::ByVector(vector), k, Some(trace))
    }

    /// Blocking query by word id.
    pub fn query_id(&self, id: u32, k: usize) -> QueryResponse {
        recv_response(self.submit_id(id, k))
    }

    /// Blocking query by vector.
    pub fn query_vector(&self, vector: Vec<f32>, k: usize) -> QueryResponse {
        recv_response(self.submit_vector(vector, k))
    }
}

fn recv_response(rx: Receiver<QueryResponse>) -> QueryResponse {
    rx.recv()
        .unwrap_or_else(|_| Err("serving engine stopped".to_string()))
}

/// A running engine: dispatcher + workers over an opened store.
pub struct ServeEngine {
    tx: Option<SyncSender<Msg>>,
    dispatcher: Option<JoinHandle<()>>,
    shared: Arc<EngineShared>,
    store: Arc<ShardedStore>,
    workers: usize,
    nprobe: usize,
}

impl ServeEngine {
    pub fn start(store: Arc<ShardedStore>, opts: ServeOptions) -> ServeEngine {
        let batch_max = opts.batch_max.max(1);
        let queue_depth = opts.queue_depth.max(1);
        let shards = store.num_shards();
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let workers = if opts.workers == 0 {
            shards.clamp(1, cores)
        } else {
            opts.workers.clamp(1, shards.max(1))
        };

        let (tx, rx) = sync_channel::<Msg>(queue_depth);
        let shared = Arc::new(EngineShared::default());
        let epoch = Instant::now();
        let nprobe = opts.nprobe;
        let dispatcher = {
            let store = store.clone();
            let shared = shared.clone();
            let opts = opts.clone();
            std::thread::spawn(move || {
                dispatch_loop(
                    rx, store, shared, opts, workers, batch_max, epoch,
                )
            })
        };
        ServeEngine {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            shared,
            store,
            workers,
            nprobe,
        }
    }

    pub fn client(&self) -> QueryClient {
        // LINT: allow(panic-path): `tx` is only `None` after `stop()`,
        // and `stop()` is reachable only via `shutdown(self)`/`Drop`,
        // both of which consume the engine — so `client(&self)` can
        // never observe the stopped state.
        QueryClient { tx: self.tx.clone().expect("engine running") }
    }

    /// Cheap cloneable metrics/accounting handle: lets front-end threads
    /// snapshot reports and record sheds without sharing `&ServeEngine`
    /// itself across threads (the engine stays owned by whoever will
    /// eventually [`ServeEngine::shutdown`] it).
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            shared: self.shared.clone(),
            store: self.store.clone(),
            workers: self.workers,
            nprobe: self.nprobe,
        }
    }

    /// The store this engine serves (shared handle).
    pub fn store(&self) -> Arc<ShardedStore> {
        self.store.clone()
    }

    /// Snapshot of the metrics so far.  QPS is computed over the serving
    /// window (first batch start to last batch end), not engine lifetime.
    pub fn report(&self) -> ServeReport {
        self.stats().report()
    }

    /// Stop the engine and return the final report.  In-flight batches
    /// finish; [`QueryClient`]s still alive afterwards get
    /// "serving engine stopped" errors on later queries.
    pub fn shutdown(mut self) -> ServeReport {
        self.stop();
        self.report()
    }

    fn stop(&mut self) {
        if let Some(tx) = self.tx.take() {
            // sentinel wakes the dispatcher even while cloned clients
            // still hold senders; send only fails if it already exited
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Metrics/accounting handle onto a running (or stopped) engine.
///
/// Clones share the same counters, so a handle held by an HTTP worker
/// thread sees exactly what [`ServeEngine::report`] sees.  Outliving the
/// engine is safe: the handle only reads counters and the store, both of
/// which are reference-counted.
#[derive(Clone)]
pub struct EngineStats {
    shared: Arc<EngineShared>,
    store: Arc<ShardedStore>,
    workers: usize,
    nprobe: usize,
}

impl EngineStats {
    /// Count one request refused by admission control (reported as
    /// [`ServeReport::shed`]).  The request never reached the queue, so
    /// nothing else in the report moves.
    pub fn note_shed(&self) {
        self.shared.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// The store behind the engine (shared handle).
    pub fn store(&self) -> Arc<ShardedStore> {
        self.store.clone()
    }

    /// Clone of the engine's latency histogram (for the Prometheus
    /// exposition) — a fixed-size copy under a short lock.
    pub fn latency_histogram(&self) -> Histogram {
        lock_unpoisoned(&self.shared.latency).clone()
    }

    /// Snapshot of the metrics so far — see [`ServeEngine::report`].
    pub fn report(&self) -> ServeReport {
        // the histogram is constant-size, so a report clones it whole
        // under a short lock (the dispatcher takes the same lock once
        // per batch) — no subsampling needed, quantiles cover every
        // request ever recorded
        let hist = self.latency_histogram();
        let wall = self.shared.window_seconds();
        let queries = self.shared.queries.load(Ordering::Relaxed);
        let mut latency = LatencyStats::from_hist(&hist, wall);
        // a report taken between a batch's histogram update and its
        // query-counter update could disagree by one batch; the atomic
        // counter is the authoritative total
        latency.count = queries;
        latency.qps =
            if wall > 0.0 { queries as f64 / wall } else { 0.0 };
        let mut stages = StageTimes::new(SERVE_STAGES);
        for (i, cell) in self.shared.stage_ns.iter().enumerate() {
            stages.add(i, cell.load(Ordering::Relaxed));
        }
        ServeReport {
            latency,
            queries,
            batches: self.shared.batches.load(Ordering::Relaxed),
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self
                .shared
                .cache_evictions
                .load(Ordering::Relaxed),
            rows_scanned: self.shared.rows_scanned.load(Ordering::Relaxed),
            rows_advanced: self
                .shared
                .rows_advanced
                .load(Ordering::Relaxed),
            probe_groups: self.shared.probe_groups.load(Ordering::Relaxed),
            cache_pins_avoided: self
                .shared
                .cache_pins_avoided
                .load(Ordering::Relaxed),
            bytes_mapped: self.store.bytes_mapped(),
            bytes_heap_loaded: self.store.bytes_heap_loaded(),
            workers: self.workers,
            shards: self.store.num_shards(),
            loaded_shards: self.store.loaded_shards(),
            precision: self.store.precision().name().to_string(),
            nprobe: self.nprobe,
            clusters: self
                .store
                .ivf()
                .map(|m| m.num_clusters())
                .unwrap_or(0),
            probed_batches: self
                .shared
                .probed_batches
                .load(Ordering::Relaxed),
            clusters_probed: self
                .shared
                .clusters_probed
                .load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            stages,
            busy_seconds: self.shared.busy_ns.load(Ordering::Relaxed)
                as f64
                / 1e9,
            slow: lock_unpoisoned(&self.shared.slow)
                .iter()
                .cloned()
                .collect(),
        }
    }
}

/// Advance the batch's stage clock: book the lap into `stage[idx]` and,
/// when the batch carries at least one traced request, also record the
/// absolute interval (epoch-relative ns) so traced requests' span trees
/// can tile the batch stages ([`crate::obs::trace`]).  The untraced
/// path records nothing and allocates nothing.
fn lap(
    span: &mut Span,
    stage: &mut [u64; 5],
    idx: usize,
    cursor: &mut u64,
    traced: bool,
    intervals: &mut Vec<(&'static str, u64, u64)>,
) {
    let ns = span.lap_ns();
    stage[idx] += ns;
    if traced && ns > 0 {
        intervals.push((SERVE_STAGES[idx], *cursor, *cursor + ns));
    }
    *cursor += ns;
}

/// Split `shards` into `workers` near-equal contiguous ranges.
fn shard_ranges(shards: usize, workers: usize) -> Vec<(usize, usize)> {
    let base = shards / workers;
    let extra = shards % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn dispatch_loop(
    rx: Receiver<Msg>,
    store: Arc<ShardedStore>,
    shared: Arc<EngineShared>,
    opts: ServeOptions,
    workers: usize,
    batch_max: usize,
    epoch: Instant,
) {
    let dim = store.dim();
    let mut cache =
        HotCache::new(dim, opts.cache_capacity, opts.protected_rows);
    if opts.warm_cache {
        cache.warm(|id, out| {
            matches!(store.fetch_row(id, out), Ok(Some(())))
        });
    }

    // one job + one result channel PER worker (depth 1 is enough — the
    // dispatcher processes a single batch at a time).  Per-worker result
    // channels are what make a worker death detectable: a thread that
    // panics drops its own result sender, so the dispatcher's recv on
    // that worker errors immediately instead of waiting forever on a
    // channel other workers keep alive.
    struct WorkerLink {
        job_tx: SyncSender<Arc<BatchJob>>,
        result_rx: Receiver<WorkerResult>,
    }
    let mut links = Vec::with_capacity(workers);
    let mut handles = Vec::with_capacity(workers);
    for (lo, hi) in shard_ranges(store.num_shards(), workers) {
        let (job_tx, job_rx) = sync_channel::<Arc<BatchJob>>(1);
        let (result_tx, result_rx) = channel::<WorkerResult>();
        links.push(WorkerLink { job_tx, result_rx });
        let store = store.clone();
        handles.push(std::thread::spawn(move || {
            for job in job_rx.iter() {
                let out = scan_range(&store, lo, hi, &job);
                if result_tx.send(out).is_err() {
                    break;
                }
            }
        }));
    }

    struct Pending {
        reply: SyncSender<QueryResponse>,
        enqueued: Instant,
        slot: Result<usize, String>,
        trace: Option<u64>,
        k: usize,
    }

    let slow_ns = opts.slow_query_us.saturating_mul(1_000);
    let mut warned_no_index = false;
    let mut stopping = false;
    while !stopping {
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            // engine Drop/shutdown sentinel, or every sender dropped
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let batch_start_ns = epoch.elapsed().as_nanos() as u64;
        // stage decomposition: contiguous laps of one clock tile the
        // batch's processing time, so stage sums reconcile with busy
        // time by construction
        let batch_start = Instant::now();
        let mut span = Span::start();
        let mut stage = [0u64; 5];
        // absolute (epoch-relative) stage intervals for this batch,
        // recorded only when at least one request carries a trace id
        let mut intervals: Vec<(&'static str, u64, u64)> = Vec::new();
        let mut cursor_ns = batch_start_ns;
        let mut reqs = vec![first];
        while reqs.len() < batch_max {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => reqs.push(r),
                Ok(Msg::Shutdown) => {
                    stopping = true; // finish this batch, then exit
                    break;
                }
                Err(_) => break,
            }
        }

        let mut resolved: Vec<ResolvedQuery> = Vec::new();
        let mut pendings: Vec<Pending> = Vec::with_capacity(reqs.len());
        for req in reqs {
            let Request { kind, k, reply, enqueued, trace } = req;
            // a store can never return more than V neighbors; clamping
            // here also bounds every downstream heap allocation against
            // absurd client-supplied k
            let k = k.min(store.vocab_size());
            let slot = match resolve(
                kind,
                &store,
                &mut cache,
                &shared.cache_pins_avoided,
            ) {
                Ok((vector, exclude)) => {
                    resolved.push(ResolvedQuery { vector, k, exclude });
                    Ok(resolved.len() - 1)
                }
                Err(e) => Err(e),
            };
            pendings.push(Pending { reply, enqueued, slot, trace, k });
        }
        let traced = pendings.iter().any(|p| p.trace.is_some());
        lap(
            &mut span,
            &mut stage,
            ST_BATCH_FILL,
            &mut cursor_ns,
            traced,
            &mut intervals,
        );

        let mut results: Vec<Option<QueryResponse>> = Vec::new();
        if !resolved.is_empty() {
            // IVF probe plan for the batch: score every query against
            // the centroid table once (int8 prescore + exact rescore),
            // then either group queries by their picked cluster sets
            // (default) or take the batch-wide union (`union_probes`).
            // Stores without an index (flat v1 exports) serve
            // exhaustively.
            let mut ranges = None;
            let mut groups = None;
            if opts.nprobe > 0 {
                match store.ivf() {
                    Some(meta) => {
                        let qrefs: Vec<&[f32]> =
                            resolved.iter().map(|q| &q.vector[..]).collect();
                        let clusters_probed;
                        if opts.union_probes {
                            let plan = ivf::plan_probes(
                                meta,
                                store.dim(),
                                &qrefs,
                                opts.nprobe,
                            );
                            clusters_probed = plan.clusters_probed;
                            shared
                                .probe_groups
                                .fetch_add(1, Ordering::Relaxed);
                            ranges = Some(plan.ranges);
                        } else {
                            let plan = ivf::plan_probes_per_query(
                                meta,
                                store.dim(),
                                &qrefs,
                                opts.nprobe,
                            );
                            clusters_probed = plan.clusters_probed;
                            shared.probe_groups.fetch_add(
                                plan.groups.len() as u64,
                                Ordering::Relaxed,
                            );
                            groups = Some(plan.groups);
                        }
                        shared.probed_batches.fetch_add(1, Ordering::Relaxed);
                        shared
                            .clusters_probed
                            .fetch_add(clusters_probed as u64, Ordering::Relaxed);
                    }
                    None => {
                        if !warned_no_index {
                            warned_no_index = true;
                            crate::log_warn!(
                                "serve: nprobe set but the store has no IVF \
                                 index (flat v1 export?); scanning \
                                 exhaustively"
                            );
                        }
                    }
                }
            }
            lap(
                &mut span,
                &mut stage,
                ST_IVF_PROBE,
                &mut cursor_ns,
                traced,
                &mut intervals,
            );
            let job = Arc::new(BatchJob { queries: resolved, ranges, groups });
            let mut sent = vec![false; links.len()];
            for (link, s) in links.iter().zip(sent.iter_mut()) {
                *s = link.job_tx.send(job.clone()).is_ok();
            }
            let mut merged: Vec<TopK> =
                job.queries.iter().map(|q| TopK::new(q.k)).collect();
            // a dead worker means its shard range would be silently
            // missing from every result: that is a hard error, not a
            // degraded answer
            let mut failure: Option<String> = None;
            let mut batch_rows = 0u64;
            let mut batch_advanced = 0u64;
            lap(
                &mut span,
                &mut stage,
                ST_SHARD_SCAN,
                &mut cursor_ns,
                traced,
                &mut intervals,
            );
            for (link, s) in links.iter().zip(&sent) {
                if !*s {
                    failure =
                        Some("worker thread died (job rejected)".into());
                    continue;
                }
                // the scan stage is the wait for this worker's result;
                // folding its partial heaps in is the merge stage
                let received = link.result_rx.recv();
                lap(
                    &mut span,
                    &mut stage,
                    ST_SHARD_SCAN,
                    &mut cursor_ns,
                    traced,
                    &mut intervals,
                );
                match received {
                    Ok(Ok((parts, rows, advanced))) => {
                        batch_rows += rows;
                        batch_advanced += advanced;
                        for (m, p) in merged.iter_mut().zip(parts) {
                            m.merge(p);
                        }
                    }
                    Ok(Err(e)) => failure = Some(e),
                    // the worker accepted the job then died: its result
                    // sender is dropped, so this errors immediately
                    Err(_) => {
                        failure =
                            Some("worker thread died mid-batch".into());
                    }
                }
                lap(
                    &mut span,
                    &mut stage,
                    ST_TOPK_MERGE,
                    &mut cursor_ns,
                    traced,
                    &mut intervals,
                );
            }
            results = match failure {
                None => merged
                    .into_iter()
                    .map(|t| Some(Ok(t.into_sorted())))
                    .collect(),
                Some(e) => job
                    .queries
                    .iter()
                    .map(|_| Some(Err(e.clone())))
                    .collect(),
            };
            shared.rows_scanned.fetch_add(batch_rows, Ordering::Relaxed);
            shared
                .rows_advanced
                .fetch_add(batch_advanced, Ordering::Relaxed);
        }

        // account the whole batch *before* any reply goes out, so a
        // report() taken right after the last reply arrives always
        // includes this batch
        let mut outbox = Vec::with_capacity(pendings.len());
        let mut slow_entries: Vec<SlowQuery> = Vec::new();
        let mut traces: Vec<(u64, Vec<SpanRec>)> = Vec::new();
        // the tail between the last recorded lap and this accounting
        // point is merge-stage work (the final span.lap_ns() below books
        // it there); close the interval now so traced requests' spans
        // tile right up to where their latency is measured
        let acct_ns = epoch.elapsed().as_nanos() as u64;
        if traced && acct_ns > cursor_ns {
            intervals.push((
                SERVE_STAGES[ST_TOPK_MERGE],
                cursor_ns,
                acct_ns,
            ));
        }
        {
            let mut lat = lock_unpoisoned(&shared.latency);
            for p in pendings {
                let response = match p.slot {
                    // each Ok slot index was handed out exactly once, so
                    // a missing or doubly-taken slot is an internal bug;
                    // surface it as a per-request error, never a panic
                    // on the serving path
                    Ok(i) => results
                        .get_mut(i)
                        .and_then(Option::take)
                        .unwrap_or_else(|| {
                            Err("internal: reply slot mismatch".into())
                        }),
                    Err(e) => Err(e),
                };
                // queue wait: enqueue to this batch starting (zero for
                // requests drained mid-fill)
                let wait_ns = batch_start
                    .saturating_duration_since(p.enqueued)
                    .as_nanos() as u64;
                stage[ST_QUEUE_WAIT] += wait_ns;
                let nanos = p.enqueued.elapsed().as_nanos() as u64;
                lat.record(nanos);
                if let Some(tid) = p.trace {
                    // span tree: a `request` root over enqueue-to-reply
                    // plus children reusing the SERVE_STAGES vocabulary
                    // — the request's own queue wait, then the batch's
                    // stage intervals it shared.  Children tile the
                    // root, so per-trace sums reconcile with the
                    // recorded latency (same contract the aggregate
                    // stage timers keep with busy_seconds).
                    let enq_ns = batch_start_ns.saturating_sub(wait_ns);
                    let mut spans =
                        Vec::with_capacity(intervals.len() + 2);
                    spans.push(SpanRec {
                        name: "request",
                        parent: None,
                        start_ns: enq_ns,
                        end_ns: enq_ns.saturating_add(nanos),
                    });
                    spans.push(SpanRec {
                        name: SERVE_STAGES[ST_QUEUE_WAIT],
                        parent: Some(0),
                        start_ns: enq_ns,
                        end_ns: batch_start_ns,
                    });
                    for &(name, s, e) in &intervals {
                        spans.push(SpanRec {
                            name,
                            parent: Some(0),
                            start_ns: s,
                            end_ns: e,
                        });
                    }
                    traces.push((tid, spans));
                }
                if nanos >= slow_ns {
                    slow_entries.push(SlowQuery {
                        trace: p.trace,
                        micros: nanos as f64 / 1e3,
                        k: p.k,
                    });
                }
                outbox.push((p.reply, response));
            }
        }
        // publish span trees outside the latency lock: the ring has its
        // own sharded locks and readers (/debug/traces) must never
        // contend with the histogram
        if !traces.is_empty() {
            let ring = trace::global();
            for (tid, spans) in traces {
                ring.record(tid, spans);
            }
        }
        if !slow_entries.is_empty() {
            let mut slow = lock_unpoisoned(&shared.slow);
            for entry in slow_entries {
                crate::log_debug!(
                    "serve: slow query {:.0}us k={} trace={}",
                    entry.micros,
                    entry.k,
                    entry
                        .trace
                        .map(|t| t.to_string())
                        .unwrap_or_else(|| "-".into()),
                );
                if slow.len() == SLOW_LOG_CAP {
                    slow.pop_front();
                }
                slow.push_back(entry);
            }
        }
        shared.queries.fetch_add(outbox.len() as u64, Ordering::Relaxed);
        shared.batches.fetch_add(1, Ordering::Relaxed);
        let cs = cache.stats();
        shared.cache_hits.store(cs.hits, Ordering::Relaxed);
        shared.cache_misses.store(cs.misses, Ordering::Relaxed);
        shared.cache_evictions.store(cs.evictions, Ordering::Relaxed);
        shared
            .window_first_ns
            .fetch_min(batch_start_ns, Ordering::Relaxed);
        shared
            .window_last_ns
            .fetch_max(epoch.elapsed().as_nanos() as u64, Ordering::Relaxed);
        for (reply, response) in outbox {
            let _ = reply.send(response);
        }
        // accounting + replies close out the merge stage; publish the
        // batch's stage laps and independently-measured busy time
        stage[ST_TOPK_MERGE] += span.lap_ns();
        for (i, ns) in stage.into_iter().enumerate() {
            if ns > 0 {
                shared.stage_ns[i].fetch_add(ns, Ordering::Relaxed);
            }
        }
        shared.busy_ns.fetch_add(
            batch_start.elapsed().as_nanos() as u64,
            Ordering::Relaxed,
        );
    }

    drop(links); // workers see job-channel EOF
    for h in handles {
        let _ = h.join();
    }
}

/// Turn a request into a normalized query vector + exclusion id,
/// serving `ById` lookups through the hot-cache tier.  Rows resident in
/// an mmap-backed shard are *not* pinned into the cache on a miss — the
/// page cache already holds them, so a heap pin would only evict rows
/// that need one; each skip is counted (`cache_pins_avoided`).  Cache
/// warming still pins the protected head unconditionally: those rows
/// are queried often enough that the Arc-clone hit path beats repeated
/// shard lookups even over a mapping.
fn resolve(
    kind: QueryKind,
    store: &ShardedStore,
    cache: &mut HotCache,
    pins_avoided: &AtomicU64,
) -> Result<(Arc<[f32]>, Option<u32>), String> {
    match kind {
        QueryKind::ById(id) => {
            // range-check before the cache: a malformed id counted as a
            // cache miss would deflate the reported hit rate under bad
            // traffic
            if id as usize >= store.vocab_size() {
                return Err(format!(
                    "row id {id} out of range (vocab {})",
                    store.vocab_size()
                ));
            }
            // a hit is an Arc clone of the resident row — no copy
            if let Some(row) = cache.get(id) {
                return Ok((row, Some(id)));
            }
            let mut buf = vec![0.0f32; store.dim()];
            match store.fetch_row(id, &mut buf) {
                Ok(Some(())) => {
                    let row: Arc<[f32]> = buf.into();
                    if store.row_is_mapped(id) {
                        pins_avoided.fetch_add(1, Ordering::Relaxed);
                    } else {
                        cache.insert(id, row.clone());
                    }
                    Ok((row, Some(id)))
                }
                // unreachable after the range check, kept as defense
                Ok(None) => Err(format!(
                    "row id {id} out of range (vocab {})",
                    store.vocab_size()
                )),
                Err(e) => Err(format!("{e:#}")),
            }
        }
        QueryKind::ByVector(mut v) => {
            if v.len() != store.dim() {
                return Err(format!(
                    "query dim {} != store dim {}",
                    v.len(),
                    store.dim()
                ));
            }
            let norm = crate::vecops::dot_f64(&v, &v).sqrt() as f32;
            if norm == 0.0 || !norm.is_finite() {
                return Err(
                    "query vector must be non-zero and finite".to_string()
                );
            }
            for x in v.iter_mut() {
                *x /= norm;
            }
            Ok((v.into(), None))
        }
    }
}

/// Worker body: scan shards [lo, hi) **once** for the whole batch —
/// every query's heap advances in the same pass over each shard.  With
/// a union probe plan, only the plan's row ranges (clipped to this
/// worker's shards) are touched; with per-query groups, each group's
/// queries share one pass over that group's ranges and no other
/// query's heap advances over them.
fn scan_range(
    store: &ShardedStore,
    lo: usize,
    hi: usize,
    job: &BatchJob,
) -> WorkerResult {
    let mut parts: Vec<TopK> =
        job.queries.iter().map(|q| TopK::new(q.k)).collect();
    let queries: Vec<BatchQuery<'_>> = job
        .queries
        .iter()
        .map(|q| BatchQuery { vector: &q.vector, exclude: q.exclude })
        .collect();
    let shards = (lo..hi)
        .map(|si| store.shard(si).map_err(|e| format!("{e:#}")))
        .collect::<Result<Vec<_>, _>>()?;
    let (rows_scanned, rows_advanced) = match (&job.groups, &job.ranges) {
        (Some(groups), _) => {
            search_shards_batch_groups(&shards, groups, &queries, &mut parts)
        }
        (None, Some(ranges)) => {
            let rows = search_shards_batch_ranges(
                shards.into_iter(),
                ranges,
                &queries,
                &mut parts,
            );
            (rows, rows * queries.len() as u64)
        }
        (None, None) => {
            let rows =
                search_shards_batch(shards.into_iter(), &queries, &mut parts);
            (rows, rows * queries.len() as u64)
        }
    };
    Ok((parts, rows_scanned, rows_advanced))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::vocab::Vocab;
    use crate::model::EmbeddingModel;
    use crate::serve::ann::search_rows;
    use crate::serve::store::{export_store, Precision};
    use std::path::PathBuf;

    fn setup(name: &str, v: usize, d: usize) -> (EmbeddingModel, PathBuf) {
        let vocab = Vocab::from_counts(
            (0..v).map(|i| (format!("w{i:03}"), (v - i) as u64 * 10)),
            1,
        );
        let model = EmbeddingModel::init(v, d, 42);
        let dir =
            std::env::temp_dir().join("fullw2v_engine_test").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        export_store(&model, &vocab, &dir, 4).unwrap();
        (model, dir)
    }

    fn opts() -> ServeOptions {
        ServeOptions {
            workers: 2,
            batch_max: 8,
            queue_depth: 16,
            cache_capacity: 16,
            protected_rows: 4,
            warm_cache: true,
            nprobe: 0,
            ..ServeOptions::default()
        }
    }

    #[test]
    fn engine_matches_brute_force() {
        let (model, dir) = setup("brute", 30, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        let rows = model.normalized_rows();
        for id in [0u32, 7, 15, 29] {
            let got = client.query_id(id, 5).unwrap();
            let want =
                search_rows(&rows, 8, &rows[id as usize * 8..][..8], 5, Some(id));
            assert_eq!(
                got.iter().map(|n| n.id).collect::<Vec<_>>(),
                want.iter().map(|n| n.id).collect::<Vec<_>>(),
                "query {id}"
            );
        }
        drop(client);
        let report = engine.shutdown();
        assert_eq!(report.queries, 4);
        assert!(report.latency.count == 4);
        assert_eq!(report.loaded_shards, 4);
    }

    /// Regression for the panic-path fix in the dispatcher's reply
    /// loop: a batch mixing resolve-failures (out-of-range ids) with
    /// valid queries must route every reply to its own request — the
    /// Err slots shift the reply-slot indices of the Ok ones, which is
    /// exactly the alignment the old `results[i].take().expect(..)`
    /// asserted and the rewrite must preserve without panicking.
    #[test]
    fn mixed_valid_and_invalid_queries_each_get_their_reply() {
        let (_model, dir) = setup("mixed", 30, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        // enqueue before receiving so the dispatcher can drain several
        // into one micro-batch (interleaving either way is correct)
        let rxs = vec![
            client.submit_id(3, 4),
            client.submit_id(999, 4), // out of range: Err slot
            client.submit_id(7, 4),
            client.submit_id(500, 4), // out of range: Err slot
            client.submit_id(11, 4),
        ];
        let replies: Vec<QueryResponse> =
            rxs.into_iter().map(recv_response).collect();
        for (i, want_id) in [(0usize, 3u32), (2, 7), (4, 11)] {
            let got = replies[i].as_ref().expect("valid query succeeds");
            assert_eq!(got.len(), 4, "k neighbors for request {i}");
            assert!(
                got.iter().all(|n| n.id != want_id),
                "self-match excluded for request {i}"
            );
        }
        for i in [1usize, 3] {
            let err = replies[i].as_ref().expect_err("invalid id fails");
            assert!(err.contains("out of range"), "got: {err}");
        }
        drop(client);
        let report = engine.shutdown();
        assert_eq!(report.queries, 5, "failed queries still counted");
    }

    #[test]
    fn concurrent_clients_batch_up() {
        let (_, dir) = setup("concurrent", 40, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let mut joins = Vec::new();
        for t in 0..4 {
            let client = engine.client();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25u32 {
                    let id = (i * 7 + t) % 40;
                    if client.query_id(id, 3).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 100);
        let report = engine.shutdown();
        assert_eq!(report.queries, 100);
        assert!(report.batches <= 100);
        assert!(report.cache_hits > 0, "repeated ids must hit the cache");
    }

    #[test]
    fn bad_queries_get_errors_not_hangs() {
        let (_, dir) = setup("bad", 10, 4);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        assert!(client.query_id(10, 3).is_err()); // out of range
        assert!(client.query_id(u32::MAX, 3).is_err());
        // malformed ids are range-checked *before* the cache tier, so
        // they must not register as misses and skew the hit rate
        let stats = engine.report();
        assert_eq!(
            (stats.cache_hits, stats.cache_misses),
            (0, 0),
            "out-of-range ids must leave cache stats untouched"
        );
        assert!(client.query_vector(vec![0.0; 4], 3).is_err()); // zero
        assert!(client.query_vector(vec![1.0; 3], 3).is_err()); // bad dim
        // non-finite vectors are rejected, not served as NaN scores
        assert!(client
            .query_vector(vec![f32::INFINITY, 0.0, 0.0, 0.0], 3)
            .is_err());
        assert!(client.query_vector(vec![f32::NAN; 4], 3).is_err());
        // absurd k is clamped to the vocabulary, not allocated
        let all = client.query_id(0, usize::MAX).unwrap();
        assert_eq!(all.len(), 9); // V=10 minus the excluded query word
        let ok = client.query_vector(vec![1.0, 0.0, 0.0, 0.0], 3).unwrap();
        assert_eq!(ok.len(), 3);
        drop(client);
        engine.shutdown();
    }

    #[test]
    fn vector_query_has_no_exclusion() {
        let (model, dir) = setup("noexcl", 12, 4);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        // query with row 3's own vector: row 3 itself must rank first
        let rows = model.normalized_rows();
        let got =
            client.query_vector(rows[3 * 4..4 * 4].to_vec(), 1).unwrap();
        assert_eq!(got[0].id, 3);
        drop(client);
        engine.shutdown();
    }

    #[test]
    fn client_outliving_engine_gets_errors_not_hangs() {
        let (_, dir) = setup("outlive", 10, 4);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        assert!(client.query_id(1, 2).is_ok());
        // dropping the engine with a live client must not deadlock...
        drop(engine);
        // ...and the orphaned client fails cleanly afterwards
        assert!(client.query_id(1, 2).is_err());
    }

    /// report() under live traffic: must never deadlock against the
    /// dispatcher (the latency lock is taken every batch), must stay
    /// monotonic, and must keep count consistent with queries even
    /// though quantiles come from a bounded snapshot.
    #[test]
    fn report_under_concurrent_load_is_consistent() {
        let (_, dir) = setup("reportload", 40, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        std::thread::scope(|s| {
            for t in 0..3u32 {
                let client = engine.client();
                s.spawn(move || {
                    for i in 0..60u32 {
                        client.query_id((i * 3 + t) % 40, 3).unwrap();
                    }
                });
            }
            let mut last = 0u64;
            for _ in 0..50 {
                let r = engine.report();
                assert!(r.queries >= last, "query count went backwards");
                last = r.queries;
                assert_eq!(r.latency.count, r.queries);
                assert!(r.latency.p50_us <= r.latency.p99_us + 1e-9);
            }
        });
        let report = engine.shutdown();
        assert_eq!(report.queries, 180);
        assert_eq!(report.latency.count, 180);
    }

    /// A flat (v1) store asked to probe serves exhaustively — correct
    /// answers, zero probed batches — instead of erroring out.
    #[test]
    fn nprobe_on_flat_store_falls_back_to_exhaustive() {
        let (model, dir) = setup("flatprobe", 20, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        assert!(store.ivf().is_none());
        let engine = ServeEngine::start(
            store,
            ServeOptions { nprobe: 4, ..opts() },
        );
        let client = engine.client();
        let rows = model.normalized_rows();
        let got = client.query_id(3, 5).unwrap();
        let want = search_rows(&rows, 8, &rows[3 * 8..4 * 8], 5, Some(3));
        assert_eq!(
            got.iter().map(|n| n.id).collect::<Vec<_>>(),
            want.iter().map(|n| n.id).collect::<Vec<_>>()
        );
        drop(client);
        let report = engine.shutdown();
        assert_eq!(report.nprobe, 4);
        assert_eq!(report.clusters, 0);
        assert_eq!(report.probed_batches, 0);
        // full exhaustive scan: one query, all 20 rows
        assert_eq!(report.rows_scanned, 20);
    }

    /// Shed accounting: `note_shed` on a stats handle shows up in every
    /// report (engine- and handle-side) without touching `queries`, and
    /// the handle keeps working after the engine stops.
    #[test]
    fn shed_counts_flow_into_reports() {
        let (_, dir) = setup("shed", 10, 4);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let stats = engine.stats();
        assert_eq!(engine.report().shed, 0);
        let client = engine.client();
        client.query_id(1, 2).unwrap();
        stats.note_shed();
        stats.note_shed();
        let rep = engine.report();
        assert_eq!(rep.shed, 2);
        assert_eq!(rep.queries, 1, "sheds are not queries");
        assert!(rep.summary().contains("shed 2"));
        assert_eq!(
            rep.to_json().get("shed").and_then(|j| j.as_f64()),
            Some(2.0)
        );
        drop(client);
        let final_rep = engine.shutdown();
        assert_eq!(final_rep.shed, 2);
        // the handle outlives the engine and still reads the counters
        stats.note_shed();
        assert_eq!(stats.report().shed, 3);
    }

    /// The stage breakdown's batch-side sums must reconcile with the
    /// dispatcher's independently-measured busy time: the stages are
    /// contiguous laps of one clock, so any drift is clock-read jitter.
    #[test]
    fn stage_sums_reconcile_with_busy_time() {
        let (_, dir) = setup("stages", 40, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        for i in 0..50u32 {
            client.query_id(i % 40, 5).unwrap();
        }
        drop(client);
        let report = engine.shutdown();
        assert_eq!(report.stages.names(), SERVE_STAGES);
        // batch-side stages (everything but queue_wait) tile busy time
        let batch_side_ns: u64 = report
            .stages
            .iter()
            .filter(|(name, _)| *name != "queue_wait")
            .map(|(_, ns)| ns)
            .sum();
        let busy_ns = (report.busy_seconds * 1e9) as u64;
        assert!(busy_ns > 0, "busy time must be recorded");
        let drift = busy_ns.abs_diff(batch_side_ns);
        assert!(
            drift < 2_000_000 || drift * 50 < busy_ns,
            "stage sums {batch_side_ns}ns vs busy {busy_ns}ns"
        );
        // the scan stage does the real work on this path
        assert!(report.stages.get_ns(ST_SHARD_SCAN) > 0);
        // stages round-trip through the report JSON
        let j = report.to_json();
        let stages = j.get("stages").expect("stages key");
        for name in SERVE_STAGES {
            assert!(stages.get(name).is_some(), "missing stage {name}");
        }
        assert!(j.get("busy_seconds").is_some());
    }

    /// With the threshold at zero every query lands in the slow log,
    /// the ring stays bounded, and trace ids propagate end to end.
    #[test]
    fn slow_query_log_is_bounded_and_traced() {
        let (_, dir) = setup("slowlog", 20, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(
            store,
            ServeOptions { slow_query_us: 0, ..opts() },
        );
        let client = engine.client();
        for i in 0..(SLOW_LOG_CAP as u32 + 10) {
            let rx = client.submit_id_traced(i % 20, 3, 1000 + i as u64);
            rx.recv().unwrap().unwrap();
        }
        client.query_id(0, 3).unwrap(); // untraced
        drop(client);
        let report = engine.shutdown();
        assert_eq!(report.slow.len(), SLOW_LOG_CAP, "ring stays bounded");
        // the newest entry is the untraced direct query...
        assert!(report.slow.last().unwrap().trace.is_none());
        // ...and the rest carry the propagated ids, newest last
        let traced = &report.slow[report.slow.len() - 2];
        assert_eq!(traced.trace, Some(1000 + SLOW_LOG_CAP as u64 + 9));
        assert!(report.slow.iter().all(|s| s.micros > 0.0 && s.k == 3));
        let j = report.to_json().to_string();
        assert!(j.contains("\"slow_queries\""));

        // default threshold: microsecond-scale queries never log
        let (_, dir2) = setup("slowlog_default", 10, 8);
        let store2 =
            Arc::new(ShardedStore::open(&dir2, Precision::Exact).unwrap());
        let engine2 = ServeEngine::start(store2, opts());
        let c2 = engine2.client();
        c2.query_id(1, 2).unwrap();
        drop(c2);
        let r2 = engine2.shutdown();
        assert!(
            r2.slow.is_empty() || r2.slow[0].micros >= 10_000.0,
            "fast queries must not spam the slow log"
        );
    }

    /// Traced requests leave span trees in the global trace ring: a
    /// `request` root, children restricted to the SERVE_STAGES
    /// vocabulary, and child durations that tile the root within the
    /// same drift tolerance the aggregate stage timers are held to.
    #[test]
    fn traced_requests_record_span_trees_in_the_ring() {
        let (_, dir) = setup("tracering", 30, 8);
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        let engine = ServeEngine::start(store, opts());
        let client = engine.client();
        // id range chosen to never collide with other tests recording
        // into the same process-global ring
        let base = 0x00E2_E000_0001u64;
        for i in 0..10u64 {
            let rx =
                client.submit_id_traced((i % 30) as u32, 4, base + i);
            rx.recv().unwrap().unwrap();
        }
        // untraced queries take the no-allocation path and must not
        // record (asserted below by exact-count on this test's id range;
        // other tests share the process-global ring, so only the ids
        // minted here are safe to reason about)
        client.query_id(0, 3).unwrap();
        drop(client);
        engine.shutdown();
        let snap = trace::global().snapshot(trace::TRACE_RING_CAP);
        let mine: Vec<_> = snap
            .iter()
            .filter(|t| t.id >= base && t.id < base + 10)
            .collect();
        assert_eq!(mine.len(), 10, "every traced request recorded");
        for t in &mine {
            let root = t.root().expect("non-empty span tree");
            assert_eq!(root.name, "request");
            assert!(root.parent.is_none());
            let mut child_ns = 0u64;
            for s in &t.spans[1..] {
                assert!(
                    SERVE_STAGES.contains(&s.name),
                    "unknown stage name {}",
                    s.name
                );
                assert_eq!(s.parent, Some(0), "children hang off root");
                assert!(s.end_ns >= s.start_ns);
                assert!(s.start_ns >= root.start_ns);
                child_ns += s.duration_ns();
            }
            // children tile the root: same reconciliation contract as
            // stage_sums_reconcile_with_busy_time
            let total = root.duration_ns().max(1);
            let drift = total.abs_diff(child_ns);
            assert!(
                drift < 2_000_000 || drift * 50 < total,
                "trace {} children {child_ns}ns vs root {total}ns",
                t.id
            );
        }
    }

    #[test]
    fn shard_ranges_cover_all() {
        assert_eq!(shard_ranges(4, 2), vec![(0, 2), (2, 4)]);
        assert_eq!(shard_ranges(5, 2), vec![(0, 3), (3, 5)]);
        assert_eq!(shard_ranges(2, 2), vec![(0, 1), (1, 2)]);
        let r = shard_ranges(7, 3);
        assert_eq!(r.last().unwrap().1, 7);
        let covered: usize = r.iter().map(|(a, b)| b - a).sum();
        assert_eq!(covered, 7);
    }
}
