//! IVF coarse index: a k-means quantizer over the store's normalized
//! rows, turning the exhaustive shard scan sublinear in vocabulary size.
//!
//! The batched tile scan (PR 2) made each row load pay for a whole
//! micro-batch, but every query still touched every row — per-query row
//! traffic floors at `rows / batch_fill`.  The matrix-blocking line of
//! work (Ji et al.) shows the batching trick composes with restricting
//! *which* rows are touched; this module is that restriction for the
//! serving side:
//!
//! * at `export-store`, [`train_kmeans`] runs plain Lloyd iterations
//!   (spherical: rows and centroids are L2-normalized, assignment is
//!   argmax dot) through the existing [`crate::vecops`] tile kernels,
//!   and [`build_layout`] reorders the store's rows by cluster so each
//!   cluster's inverted list is a **contiguous row block**;
//! * the manifest (format v2) persists the centroid table, per-cluster
//!   row ranges, and the row→id permutation as an [`IvfMeta`];
//! * at query time [`plan_probes`] scores the whole micro-batch against
//!   the centroid table with one [`crate::vecops::tile_scores_f32`]
//!   pass and returns the union of the batch's top-`nprobe` cluster
//!   lists as sorted, coalesced row ranges — which the batched scan
//!   walks through the same `RowBlock` tile path, unchanged.
//!
//! In the paper's tier vocabulary the centroid table is the shared-
//! memory analogue: a small, hot working set consulted on every batch
//! so that trips to the HBM tier (the shards) only touch the probed
//! fraction of rows.
//!
//! Seeding is greedy farthest-point ("k-center") traversal, which is
//! deterministic given the seed and guarantees well-separated planted
//! clusters each receive a centroid — random seeding can collapse two
//! blobs into one cell, which quietly doubles probe traffic.

use super::ann::TopK;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;
use crate::vecops;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Lloyd iterations run at export; assignment converges much earlier on
/// clusterable data (the loop exits on a fixed point).
pub const DEFAULT_KMEANS_ITERS: usize = 12;

/// Rows/queries scored per centroid-table pass (bounds the tile
/// scratch, same role as `ROW_TILE` in the shard scan).
const ASSIGN_CHUNK: usize = 32;

/// One cluster's contiguous row range in the cluster-reordered store.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterRange {
    pub start_row: usize,
    pub rows: usize,
}

/// The persisted coarse index: centroid table (f32 plus its int8
/// quantization), per-cluster row ranges, and the row→original-id
/// permutation (`row_ids[new_row] = id`).
#[derive(Debug, Clone, PartialEq)]
pub struct IvfMeta {
    pub clusters: Vec<ClusterRange>,
    /// `clusters.len() * dim` f32, row-major, L2-normalized.
    pub centroids: Vec<f32>,
    /// Per-centroid symmetric int8 scales (same scheme as shard rows).
    pub centroid_scales: Vec<f32>,
    /// `clusters.len() * dim` int8 centroid codes — the probe planner's
    /// prescore table, 4x smaller than `centroids` so it stays
    /// cache-resident at large cluster counts.
    pub centroid_codes: Vec<i8>,
    /// Original word id of each reordered store row.  Shared (`Arc`)
    /// because the store hands the same table to every loaded shard —
    /// one vocab-sized allocation per store, not per shard.
    pub row_ids: Arc<[u32]>,
}

impl IvfMeta {
    /// Build a meta from its structural parts, deriving the centroid
    /// table's int8 quantization — so every construction path (export,
    /// v2 JSON parse) agrees bit-for-bit on the prescore data; the v3
    /// sidecar persists and reloads the same derived values.
    pub fn new(
        clusters: Vec<ClusterRange>,
        centroids: Vec<f32>,
        row_ids: Arc<[u32]>,
    ) -> IvfMeta {
        let k = clusters.len();
        let dim = if k > 0 { centroids.len() / k } else { 0 };
        let mut centroid_scales = Vec::with_capacity(k);
        let mut centroid_codes = Vec::with_capacity(centroids.len());
        if dim > 0 {
            for row in centroids.chunks_exact(dim) {
                let (scale, q) = super::store::quantize_row(row);
                centroid_scales.push(scale);
                centroid_codes.extend_from_slice(&q);
            }
        }
        IvfMeta {
            clusters,
            centroids,
            centroid_scales,
            centroid_codes,
            row_ids,
        }
    }

    pub fn num_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Inverse permutation: `row_of[id] = reordered row`.
    pub fn row_of_ids(&self) -> Vec<u32> {
        let mut inv = vec![0u32; self.row_ids.len()];
        for (row, &id) in self.row_ids.iter().enumerate() {
            inv[id as usize] = row as u32;
        }
        inv
    }

    /// Structural validation against the owning manifest: cluster ranges
    /// must tile `[0, vocab_size)` contiguously, the permutation must be
    /// a bijection on ids, and the centroid table must be finite and
    /// exactly `clusters x dim` — all with checked arithmetic, since a
    /// manifest is attacker-controllable input.
    pub fn validate(&self, vocab_size: usize, dim: usize) -> Result<()> {
        if self.clusters.is_empty() {
            bail!("ivf index has no clusters");
        }
        let k = self.clusters.len();
        let want = k
            .checked_mul(dim)
            .ok_or_else(|| anyhow!("ivf centroid table size overflows"))?;
        if self.centroids.len() != want {
            bail!(
                "ivf has {} centroid values, expected {k} x {dim}",
                self.centroids.len()
            );
        }
        if self.centroids.iter().any(|c| !c.is_finite()) {
            bail!("ivf centroid table contains non-finite values");
        }
        if self.centroid_scales.len() != k {
            bail!(
                "ivf has {} centroid scales, expected {k}",
                self.centroid_scales.len()
            );
        }
        if self.centroid_codes.len() != want {
            bail!(
                "ivf has {} centroid codes, expected {k} x {dim}",
                self.centroid_codes.len()
            );
        }
        if self
            .centroid_scales
            .iter()
            .any(|s| !s.is_finite() || *s < 0.0)
        {
            bail!("ivf centroid scales must be finite and non-negative");
        }
        let mut next = 0usize;
        for (c, r) in self.clusters.iter().enumerate() {
            if r.start_row != next {
                bail!("cluster {c} starts at {} expected {next}", r.start_row);
            }
            next = next
                .checked_add(r.rows)
                .ok_or_else(|| anyhow!("cluster row counts overflow"))?;
        }
        if next != vocab_size {
            bail!("clusters cover {next} rows, vocab is {vocab_size}");
        }
        if self.row_ids.len() != vocab_size {
            bail!(
                "row permutation has {} entries, vocab is {vocab_size}",
                self.row_ids.len()
            );
        }
        let mut seen = vec![false; vocab_size];
        for &id in self.row_ids.iter() {
            match seen.get_mut(id as usize) {
                Some(s) if !*s => *s = true,
                Some(_) => bail!("row permutation repeats id {id}"),
                None => bail!("row permutation id {id} out of range"),
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "clusters",
                Json::Arr(
                    self.clusters
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("start_row", Json::Num(c.start_row as f64)),
                                ("rows", Json::Num(c.rows as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "centroids",
                Json::Arr(
                    // f32 -> f64 -> text -> f64 -> f32 round-trips exactly
                    self.centroids
                        .iter()
                        .map(|&x| Json::Num(x as f64))
                        .collect(),
                ),
            ),
            (
                "row_ids",
                Json::Arr(
                    self.row_ids
                        .iter()
                        .map(|&x| Json::Num(x as f64))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<IvfMeta> {
        let arr = |key: &str| {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("ivf missing '{key}'"))
        };
        let clusters = arr("clusters")?
            .iter()
            .map(|c| -> Result<ClusterRange> {
                let f = |key: &str| {
                    c.get(key)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("ivf cluster missing '{key}'"))
                };
                Ok(ClusterRange { start_row: f("start_row")?, rows: f("rows")? })
            })
            .collect::<Result<Vec<_>>>()?;
        let centroids = arr("centroids")?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|n| n as f32)
                    .ok_or_else(|| anyhow!("ivf centroid is not a number"))
            })
            .collect::<Result<Vec<_>>>()?;
        let row_ids = arr("row_ids")?
            .iter()
            .map(|x| {
                x.as_usize()
                    .and_then(|n| u32::try_from(n).ok())
                    .ok_or_else(|| anyhow!("ivf row id is not a valid id"))
            })
            .collect::<Result<Vec<_>>>()?;
        // the quantized prescore table is derived, not persisted, in
        // the v2 JSON format — `new` recomputes it deterministically
        Ok(IvfMeta::new(clusters, centroids, row_ids.into()))
    }
}

/// A trained (not yet persisted) quantizer: L2-normalized centroids and
/// one cluster assignment per input row.
#[derive(Debug, Clone)]
pub struct IvfModel {
    /// `k * dim`, row-major.
    pub centroids: Vec<f32>,
    pub assignments: Vec<u32>,
}

/// Spherical k-means over L2-normalized rows: greedy farthest-point
/// seeding, then up to `iters` Lloyd rounds (assignment via the
/// [`vecops`] tile kernels, update = normalized cluster mean).  Empty
/// clusters are reseeded to the worst-served row.  Fully deterministic
/// for a given `(rows, k, iters, seed)`.
pub fn train_kmeans(
    rows: &[f32],
    dim: usize,
    k: usize,
    iters: usize,
    seed: u64,
) -> IvfModel {
    assert!(dim > 0, "kmeans needs a positive dim");
    assert_eq!(rows.len() % dim, 0, "rows length not a multiple of dim");
    let v = rows.len() / dim;
    assert!(v > 0, "kmeans needs at least one row");
    let k = k.clamp(1, v);

    // farthest-point seeding: each next centroid is the row with the
    // lowest best-dot against the seeds chosen so far
    let mut rng = Pcg32::new(seed);
    let first = (rng.next_u64() % v as u64) as usize;
    let mut centroids = Vec::with_capacity(k * dim);
    centroids.extend_from_slice(&rows[first * dim..(first + 1) * dim]);
    let mut best = vec![f32::NEG_INFINITY; v];
    for _ in 1..k {
        let last = centroids[centroids.len() - dim..].to_vec();
        let mut next = 0usize;
        let mut next_score = f32::INFINITY;
        for (i, row) in rows.chunks_exact(dim).enumerate() {
            let d = vecops::dot(row, &last);
            if d > best[i] {
                best[i] = d;
            }
            if best[i] < next_score {
                next_score = best[i];
                next = i;
            }
        }
        centroids.extend_from_slice(&rows[next * dim..(next + 1) * dim]);
    }

    let mut assign = vec![u32::MAX; v];
    let mut scores = vec![0.0f32; ASSIGN_CHUNK * k];
    for _ in 0..iters.max(1) {
        let changed =
            assign_rows(rows, dim, &centroids, &mut assign, &mut scores, &mut best);

        // update: spherical mean (sum, then L2-normalize) per cluster
        let mut sums = vec![0.0f32; k * dim];
        let mut counts = vec![0u32; k];
        for (i, row) in rows.chunks_exact(dim).enumerate() {
            let c = assign[i] as usize;
            vecops::axpy(1.0, row, &mut sums[c * dim..(c + 1) * dim]);
            counts[c] += 1;
        }
        let mut reseeded = false;
        for c in 0..k {
            let sum = &sums[c * dim..(c + 1) * dim];
            let norm = vecops::dot_f64(sum, sum).sqrt();
            if counts[c] == 0 || norm == 0.0 {
                // dead cluster: reseed to the row the current centroids
                // serve worst, and exclude it from further reseeds this
                // round
                let mut worst = 0usize;
                let mut worst_score = f32::INFINITY;
                for (i, &s) in best.iter().enumerate() {
                    if s < worst_score {
                        worst_score = s;
                        worst = i;
                    }
                }
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&rows[worst * dim..(worst + 1) * dim]);
                best[worst] = f32::INFINITY;
                reseeded = true;
            } else {
                for (dst, &s) in
                    centroids[c * dim..(c + 1) * dim].iter_mut().zip(sum)
                {
                    *dst = (s as f64 / norm) as f32;
                }
            }
        }
        if changed == 0 && !reseeded {
            break;
        }
    }
    // one final pass so assignments match the final centroid table
    assign_rows(rows, dim, &centroids, &mut assign, &mut scores, &mut best);
    IvfModel { centroids, assignments: assign }
}

/// One Lloyd assignment pass: every row scored against the whole
/// centroid table in [`ASSIGN_CHUNK`]-row tile passes (each centroid is
/// loaded once per chunk and reused across the chunk's rows — the same
/// reuse shape as the serving scan).  Returns how many rows changed
/// cluster; `best` receives each row's winning dot.
fn assign_rows(
    rows: &[f32],
    dim: usize,
    centroids: &[f32],
    assign: &mut [u32],
    scores: &mut [f32],
    best: &mut [f32],
) -> usize {
    let k = centroids.len() / dim;
    let v = rows.len() / dim;
    let mut changed = 0usize;
    let mut start = 0usize;
    while start < v {
        let n = ASSIGN_CHUNK.min(v - start);
        let queries: Vec<&[f32]> = (start..start + n)
            .map(|i| &rows[i * dim..(i + 1) * dim])
            .collect();
        let tile = &mut scores[..n * k];
        vecops::tile_scores_f32(centroids, dim, &queries, tile);
        for (q, row_scores) in tile.chunks_exact(k).enumerate() {
            let mut c_best = 0usize;
            let mut s_best = f32::NEG_INFINITY;
            // strict > keeps the first maximum: ties break toward the
            // smaller cluster id, deterministically
            for (c, &s) in row_scores.iter().enumerate() {
                if s > s_best {
                    s_best = s;
                    c_best = c;
                }
            }
            let i = start + q;
            if assign[i] != c_best as u32 {
                changed += 1;
                assign[i] = c_best as u32;
            }
            best[i] = s_best;
        }
        start += n;
    }
    changed
}

/// Turn a trained quantizer into the store layout: rows ordered by
/// `(cluster, id)` — so each cluster is one contiguous row block and
/// in-cluster tie order stays by id — plus the per-cluster ranges.
/// Returns `(row_ids, cluster_ranges)` with `row_ids[new_row] = id`.
pub fn build_layout(
    model: &IvfModel,
    dim: usize,
) -> (Vec<u32>, Vec<ClusterRange>) {
    let k = model.centroids.len() / dim.max(1);
    let v = model.assignments.len();
    let mut counts = vec![0usize; k];
    for &c in &model.assignments {
        counts[c as usize] += 1;
    }
    let mut ranges = Vec::with_capacity(k);
    let mut start = 0usize;
    for &n in &counts {
        ranges.push(ClusterRange { start_row: start, rows: n });
        start += n;
    }
    let mut offsets: Vec<usize> =
        ranges.iter().map(|r| r.start_row).collect();
    let mut row_ids = vec![0u32; v];
    for (id, &c) in model.assignments.iter().enumerate() {
        row_ids[offsets[c as usize]] = id as u32;
        offsets[c as usize] += 1;
    }
    (row_ids, ranges)
}

/// A batch's probe set: which rows the probed scan will touch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProbePlan {
    /// Sorted, coalesced global row ranges `(start_row, rows)`.
    pub ranges: Vec<(usize, usize)>,
    /// Distinct clusters in the union of the batch's probe lists.
    pub clusters_probed: usize,
    /// Total rows the ranges cover.
    pub rows: usize,
}

/// Score the whole micro-batch against the centroid table (two-stage
/// int8-prescore + f32-rescore selection, see [`select_clusters`]) and
/// take the **union** of each query's top-`nprobe` clusters, returned
/// as sorted coalesced row ranges.  The union keeps the downstream scan
/// maximally batched — every loaded row feeds every query's heap in one
/// pass — at the cost of inflating per-query row traffic; the default
/// dispatcher now plans with [`plan_probes_per_query`] instead, and
/// this union plan remains as the comparison baseline (and for
/// callers that want one flat range list).
///
/// Empty clusters (k-means cells that ended with no rows) are skipped
/// during selection so a probe is never wasted on a list with nothing
/// in it, and if the union somehow covers zero rows the plan degrades
/// to the full row range — a probed query must never silently return
/// an empty answer on a non-empty store.  (An aggressive `nprobe` can
/// still yield *fewer than k* neighbors when the union holds fewer
/// than k rows; that is the documented ANN trade.)
pub fn plan_probes(
    meta: &IvfMeta,
    dim: usize,
    queries: &[&[f32]],
    nprobe: usize,
) -> ProbePlan {
    let k = meta.clusters.len();
    let mut picked = vec![false; k];
    for ids in select_clusters(meta, dim, queries, nprobe) {
        for c in ids {
            picked[c as usize] = true;
        }
    }

    let mut ranges: Vec<(usize, usize)> = Vec::new();
    let mut clusters_probed = 0usize;
    let mut rows = 0usize;
    for (c, &p) in picked.iter().enumerate() {
        if !p {
            continue;
        }
        clusters_probed += 1;
        let r = &meta.clusters[c];
        rows += r.rows;
        match ranges.last_mut() {
            // adjacent probed clusters fuse into one scan range, so the
            // tile loop sees the longest possible contiguous blocks
            Some((s, l)) if *s + *l == r.start_row => *l += r.rows,
            _ => ranges.push((r.start_row, r.rows)),
        }
    }
    if rows == 0 && !queries.is_empty() {
        // nothing selected (e.g. a degenerate index): fall back to the
        // exhaustive row range rather than answering with nothing
        let total = meta
            .clusters
            .last()
            .map(|r| r.start_row + r.rows)
            .unwrap_or(0);
        if total > 0 {
            return ProbePlan {
                ranges: vec![(0, total)],
                clusters_probed: k,
                rows: total,
            };
        }
    }
    ProbePlan { ranges, clusters_probed, rows }
}

/// Per-query top-`nprobe` cluster selection, shared by the union and
/// per-query planners.  Scoring is two-stage: an **int8 prescore** of
/// the whole centroid table (the quantized table is 4x smaller than the
/// f32 one, so it stays cache-resident at large cluster counts) picks a
/// widened candidate set of `W = min(k, max(2*nprobe, nprobe+4))`
/// clusters per query, then an **exact f32 rescore** of just those
/// candidates — walked in ascending cluster-id order, matching the
/// all-f32 scan's iteration order so tie-breaking is identical — makes
/// the final `nprobe` picks.  With `W >= k` the result is exactly the
/// f32 argmax selection by construction; the widened margin keeps the
/// two identical at larger k too (pinned by test).  Returned cluster
/// ids are sorted ascending.
fn select_clusters(
    meta: &IvfMeta,
    dim: usize,
    queries: &[&[f32]],
    nprobe: usize,
) -> Vec<Vec<u32>> {
    let k = meta.clusters.len();
    let nprobe = nprobe.clamp(1, k);
    let w = k.min((2 * nprobe).max(nprobe + 4));
    let mut selected = Vec::with_capacity(queries.len());
    let mut scores = vec![0.0f32; ASSIGN_CHUNK * k];
    let mut start = 0usize;
    while start < queries.len() {
        let n = ASSIGN_CHUNK.min(queries.len() - start);
        let tile = &mut scores[..n * k];
        vecops::tile_scores_i8(
            &meta.centroid_codes,
            &meta.centroid_scales,
            dim,
            &queries[start..start + n],
            tile,
        );
        for (q, row_scores) in tile.chunks_exact(k).enumerate() {
            let mut top = TopK::new(w);
            for (c, &s) in row_scores.iter().enumerate() {
                // empty cells never earn a probe — a wasted list
                if meta.clusters[c].rows > 0 {
                    top.consider(c as u32, s);
                }
            }
            let mut cands: Vec<u32> =
                top.into_sorted().iter().map(|nb| nb.id).collect();
            cands.sort_unstable();
            let query = queries[start + q];
            let mut exact = TopK::new(nprobe);
            for c in cands {
                let cu = c as usize;
                let cent = &meta.centroids[cu * dim..(cu + 1) * dim];
                exact.consider(c, vecops::dot(cent, query));
            }
            let mut ids: Vec<u32> =
                exact.into_sorted().iter().map(|nb| nb.id).collect();
            ids.sort_unstable();
            selected.push(ids);
        }
        start += n;
    }
    selected
}

/// One group of queries sharing an identical probe set: the ranges its
/// scan pass walks and the batch-local indexes of the queries whose
/// heaps advance over them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProbeGroup {
    /// Sorted, coalesced global row ranges `(start_row, rows)`.
    pub ranges: Vec<(usize, usize)>,
    /// Batch-local query indexes in this group.
    pub queries: Vec<usize>,
    /// Rows the group's ranges cover.
    pub rows: usize,
}

/// A batch's per-query probe plan: queries grouped by identical cluster
/// sets (so co-probing queries share one scan pass and its row loads),
/// plus the union metrics the old batch-union plan would have had — the
/// comparison `bench_serve` reports.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PerQueryPlan {
    pub groups: Vec<ProbeGroup>,
    /// Distinct clusters across all groups (the union's cluster count).
    pub clusters_probed: usize,
    /// Rows the union of all groups covers — what a union scan loads.
    pub union_rows: usize,
    /// Σ over queries of that query's own probe rows — what the grouped
    /// scan's heaps actually advance over.  Always `<=
    /// union_rows * queries.len()`, the union scan's advance total.
    pub advanced_rows: u64,
}

/// Per-query probe planning: same two-stage selection as
/// [`plan_probes`], but instead of flattening the batch into one union
/// range list, queries with identical cluster sets are grouped
/// (first-appearance order, deterministic) and each group gets its own
/// coalesced ranges.  Each query's heap then advances only over rows
/// its own probe list selected — the per-query row traffic the union
/// plan inflates by every co-batched query's clusters.
pub fn plan_probes_per_query(
    meta: &IvfMeta,
    dim: usize,
    queries: &[&[f32]],
    nprobe: usize,
) -> PerQueryPlan {
    let k = meta.clusters.len();
    let selected = select_clusters(meta, dim, queries, nprobe);
    let mut sigs: Vec<(Vec<u32>, Vec<usize>)> = Vec::new();
    for (q, ids) in selected.into_iter().enumerate() {
        match sigs.iter_mut().find(|(sig, _)| *sig == ids) {
            Some((_, members)) => members.push(q),
            None => sigs.push((ids, vec![q])),
        }
    }
    let mut picked = vec![false; k];
    let mut advanced_rows = 0u64;
    let mut groups = Vec::with_capacity(sigs.len());
    for (sig, members) in sigs {
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        let mut rows = 0usize;
        for &c in &sig {
            picked[c as usize] = true;
            let r = &meta.clusters[c as usize];
            rows += r.rows;
            match ranges.last_mut() {
                // cluster ids are sorted, so adjacency fuses here too
                Some((s, l)) if *s + *l == r.start_row => *l += r.rows,
                _ => ranges.push((r.start_row, r.rows)),
            }
        }
        advanced_rows += rows as u64 * members.len() as u64;
        groups.push(ProbeGroup { ranges, queries: members, rows });
    }
    let mut clusters_probed = 0usize;
    let mut union_rows = 0usize;
    for (c, &p) in picked.iter().enumerate() {
        if p {
            clusters_probed += 1;
            union_rows += meta.clusters[c].rows;
        }
    }
    if union_rows == 0 && !queries.is_empty() {
        // same degenerate-index fallback as the union planner: scan
        // everything once, every query in one group
        let total = meta
            .clusters
            .last()
            .map(|r| r.start_row + r.rows)
            .unwrap_or(0);
        if total > 0 {
            return PerQueryPlan {
                groups: vec![ProbeGroup {
                    ranges: vec![(0, total)],
                    queries: (0..queries.len()).collect(),
                    rows: total,
                }],
                clusters_probed: k,
                union_rows: total,
                advanced_rows: total as u64 * queries.len() as u64,
            };
        }
    }
    PerQueryPlan { groups, clusters_probed, union_rows, advanced_rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::embeddings::normalize_rows_in_place;

    /// `v` rows in `blobs` tight, well-separated clusters (row i belongs
    /// to blob `i % blobs`), L2-normalized.
    fn planted(v: usize, dim: usize, blobs: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        let mut centers = vec![0.0f32; blobs * dim];
        for c in centers.iter_mut() {
            *c = rng.next_f32() * 2.0 - 1.0;
        }
        let mut rows = vec![0.0f32; v * dim];
        for i in 0..v {
            let b = i % blobs;
            for j in 0..dim {
                rows[i * dim + j] =
                    centers[b * dim + j] + (rng.next_f32() - 0.5) * 0.1;
            }
        }
        normalize_rows_in_place(&mut rows, dim);
        rows
    }

    #[test]
    fn kmeans_recovers_planted_blobs() {
        let (v, dim, blobs) = (96, 12, 4);
        let rows = planted(v, dim, blobs, 3);
        let m = train_kmeans(&rows, dim, blobs, 10, 7);
        assert_eq!(m.assignments.len(), v);
        assert_eq!(m.centroids.len(), blobs * dim);
        // every row in a planted blob must share a cluster, and
        // different blobs must get different clusters
        for b in 0..blobs {
            let cluster = m.assignments[b];
            for i in (b..v).step_by(blobs) {
                assert_eq!(
                    m.assignments[i], cluster,
                    "row {i} split off from blob {b}"
                );
            }
        }
        let mut distinct: Vec<u32> = m.assignments.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert_eq!(distinct.len(), blobs, "blobs merged into one cluster");
        // centroids are unit-normalized
        for c in m.centroids.chunks_exact(dim) {
            let n: f32 = c.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "centroid norm {n}");
        }
    }

    #[test]
    fn kmeans_is_deterministic_and_handles_edge_ks() {
        let rows = planted(40, 8, 4, 11);
        let a = train_kmeans(&rows, 8, 4, 8, 5);
        let b = train_kmeans(&rows, 8, 4, 8, 5);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.centroids, b.centroids);
        // k = 1: everything in one cluster
        let one = train_kmeans(&rows, 8, 1, 4, 5);
        assert!(one.assignments.iter().all(|&c| c == 0));
        // k > v clamps to v; every cluster must stay non-empty
        let tiny = planted(3, 8, 3, 2);
        let over = train_kmeans(&tiny, 8, 10, 4, 5);
        assert_eq!(over.centroids.len(), 3 * 8);
        let mut cs: Vec<u32> = over.assignments.clone();
        cs.sort_unstable();
        cs.dedup();
        assert_eq!(cs.len(), 3, "a cluster starved despite k == v");
    }

    #[test]
    fn layout_orders_rows_by_cluster_then_id() {
        let model = IvfModel {
            centroids: vec![0.0; 3 * 4],
            assignments: vec![2, 0, 1, 0, 2, 1, 0],
        };
        let (row_ids, ranges) = build_layout(&model, 4);
        // cluster 0: ids 1,3,6; cluster 1: ids 2,5; cluster 2: ids 0,4
        assert_eq!(row_ids, vec![1, 3, 6, 2, 5, 0, 4]);
        assert_eq!(
            ranges,
            vec![
                ClusterRange { start_row: 0, rows: 3 },
                ClusterRange { start_row: 3, rows: 2 },
                ClusterRange { start_row: 5, rows: 2 },
            ]
        );
    }

    fn meta_for_tests() -> IvfMeta {
        // 3 clusters over 7 rows in 2-d
        IvfMeta::new(
            vec![
                ClusterRange { start_row: 0, rows: 3 },
                ClusterRange { start_row: 3, rows: 2 },
                ClusterRange { start_row: 5, rows: 2 },
            ],
            vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0],
            vec![1, 3, 6, 2, 5, 0, 4].into(),
        )
    }

    #[test]
    fn meta_validates_and_roundtrips_json() {
        let m = meta_for_tests();
        m.validate(7, 2).unwrap();
        let j = m.to_json().to_string();
        let back = IvfMeta::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
        // inverse permutation really inverts
        let inv = m.row_of_ids();
        for (row, &id) in m.row_ids.iter().enumerate() {
            assert_eq!(inv[id as usize] as usize, row);
        }
    }

    /// Rebuild a meta's (shared, hence immutable) permutation with one
    /// entry patched.
    fn with_row_id(meta: &IvfMeta, idx: usize, id: u32) -> IvfMeta {
        let mut v = meta.row_ids.to_vec();
        v[idx] = id;
        IvfMeta { row_ids: v.into(), ..meta.clone() }
    }

    #[test]
    fn meta_validation_rejects_corruption() {
        let good = meta_for_tests();
        let dup = with_row_id(&good, 0, good.row_ids[1]); // repeated id
        assert!(dup.validate(7, 2).is_err());
        let oob = with_row_id(&good, 0, 99);
        assert!(oob.validate(7, 2).is_err());
        let mut gap = good.clone();
        gap.clusters[1].start_row = 4; // hole between clusters
        assert!(gap.validate(7, 2).is_err());
        let mut nan = good.clone();
        nan.centroids[2] = f32::NAN;
        assert!(nan.validate(7, 2).is_err());
        let mut short = good.clone();
        short.centroids.pop();
        assert!(short.validate(7, 2).is_err());
        assert!(good.validate(8, 2).is_err()); // wrong vocab
        // the quantized prescore table is validated too
        let mut badscale = good.clone();
        badscale.centroid_scales[0] = f32::NAN;
        assert!(badscale.validate(7, 2).is_err());
        let mut negscale = good.clone();
        negscale.centroid_scales[1] = -0.5;
        assert!(negscale.validate(7, 2).is_err());
        let mut shortcodes = good.clone();
        shortcodes.centroid_codes.pop();
        assert!(shortcodes.validate(7, 2).is_err());
        let mut shortscales = good;
        shortscales.centroid_scales.pop();
        assert!(shortscales.validate(7, 2).is_err());
    }

    #[test]
    fn probe_plan_unions_and_coalesces() {
        let m = meta_for_tests();
        // query equal to centroid 0, nprobe 1: exactly cluster 0
        let q0: &[f32] = &[1.0, 0.0];
        let p = plan_probes(&m, 2, &[q0], 1);
        assert_eq!(p.ranges, vec![(0, 3)]);
        assert_eq!((p.clusters_probed, p.rows), (1, 3));
        // two queries picking clusters 0 and 1: adjacent ranges coalesce
        let q1: &[f32] = &[0.0, 1.0];
        let p = plan_probes(&m, 2, &[q0, q1], 1);
        assert_eq!(p.ranges, vec![(0, 5)]);
        assert_eq!((p.clusters_probed, p.rows), (2, 5));
        // nprobe >= k degenerates to the full row range
        let p = plan_probes(&m, 2, &[q0], 10);
        assert_eq!(p.ranges, vec![(0, 7)]);
        assert_eq!(p.clusters_probed, 3);
        // clusters 0 and 2 (non-adjacent): two ranges
        let q2: &[f32] = &[-1.0, 0.0];
        let p = plan_probes(&m, 2, &[q0, q2], 1);
        assert_eq!(p.ranges, vec![(0, 3), (5, 2)]);
    }

    #[test]
    fn probe_plan_handles_empty_clusters_and_batches() {
        let mut m = meta_for_tests();
        // make the middle cluster empty: [0,3) [3,0) [3,4)
        m.clusters = vec![
            ClusterRange { start_row: 0, rows: 3 },
            ClusterRange { start_row: 3, rows: 0 },
            ClusterRange { start_row: 3, rows: 4 },
        ];
        let q0: &[f32] = &[1.0, 0.0];
        // the empty cluster is skipped at selection, so nprobe 2 spends
        // both probes on clusters that actually hold rows (c0 and c2,
        // despite c1 scoring higher than c2) — and their ranges fuse
        let p = plan_probes(&m, 2, &[q0], 2);
        assert_eq!(p.ranges, vec![(0, 7)]);
        assert_eq!((p.clusters_probed, p.rows), (2, 7));
        assert!(p.ranges.iter().all(|&(_, l)| l > 0));
        let none = plan_probes(&m, 2, &[], 2);
        assert!(none.ranges.is_empty());
        assert_eq!(none.rows, 0);
        // a fully-empty index degrades to the exhaustive range instead
        // of an empty plan (a probed query must never answer with
        // nothing on a non-empty store)
        let mut all_empty = meta_for_tests();
        all_empty.clusters = vec![
            ClusterRange { start_row: 0, rows: 0 },
            ClusterRange { start_row: 0, rows: 0 },
            ClusterRange { start_row: 0, rows: 7 },
        ];
        // make the only non-empty cluster invisible to selection by
        // checking the zero-rows fallback directly: selection skips
        // empties, so this still probes c2
        let p = plan_probes(&all_empty, 2, &[q0], 1);
        assert_eq!(p.ranges, vec![(0, 7)]);
    }

    /// The int8 prescore must not change which clusters get probed: on
    /// a real trained index the union plan's selection equals a
    /// pure-f32 reference for every tested nprobe.  (nprobe >= 4 makes
    /// the candidate width W reach k here, where identity holds by
    /// construction; nprobe 1 exercises the narrow-W path, where the
    /// planted separation dwarfs the quantization error.)
    #[test]
    fn int8_prescore_keeps_f32_probe_selection() {
        let (v, dim, blobs) = (160, 16, 8);
        let rows = planted(v, dim, blobs, 17);
        let km = train_kmeans(&rows, dim, blobs, 10, 9);
        let (row_ids, ranges) = build_layout(&km, dim);
        let meta = IvfMeta::new(ranges, km.centroids.clone(), row_ids.into());
        meta.validate(v, dim).unwrap();
        let queries: Vec<&[f32]> =
            (0..40).map(|i| &rows[i * dim..(i + 1) * dim]).collect();
        for nprobe in [1usize, 4, 6, 8] {
            let plan = plan_probes(&meta, dim, &queries, nprobe);
            // pure-f32 reference selection, same iteration order
            let mut picked = vec![false; meta.num_clusters()];
            for q in &queries {
                let mut top = TopK::new(nprobe.min(meta.num_clusters()));
                for (c, r) in meta.clusters.iter().enumerate() {
                    if r.rows > 0 {
                        let cent = &meta.centroids[c * dim..(c + 1) * dim];
                        top.consider(c as u32, vecops::dot(cent, q));
                    }
                }
                for nb in top.into_sorted() {
                    picked[nb.id as usize] = true;
                }
            }
            let mut want_rows = 0usize;
            let mut want_clusters = 0usize;
            for (c, &p) in picked.iter().enumerate() {
                if p {
                    want_clusters += 1;
                    want_rows += meta.clusters[c].rows;
                }
            }
            assert_eq!(
                plan.clusters_probed, want_clusters,
                "nprobe {nprobe}: prescore changed the probed set"
            );
            assert_eq!(plan.rows, want_rows, "nprobe {nprobe}");
        }
    }

    #[test]
    fn per_query_plan_groups_by_cluster_set() {
        let m = meta_for_tests();
        let q0: &[f32] = &[1.0, 0.0];
        let q1: &[f32] = &[0.0, 1.0];
        let q2: &[f32] = &[-1.0, 0.0];
        let batch: Vec<&[f32]> = vec![q0, q1, q0, q2];
        let plan = plan_probes_per_query(&m, 2, &batch, 1);
        // three distinct cluster sets; the two q0 queries share a group
        assert_eq!(plan.groups.len(), 3);
        assert_eq!(plan.groups[0].ranges, vec![(0, 3)]);
        assert_eq!(plan.groups[0].queries, vec![0, 2]);
        assert_eq!(plan.groups[1].ranges, vec![(3, 2)]);
        assert_eq!(plan.groups[1].queries, vec![1]);
        assert_eq!(plan.groups[2].ranges, vec![(5, 2)]);
        assert_eq!(plan.groups[2].queries, vec![3]);
        // union metrics agree with the union planner on the same batch
        let union = plan_probes(&m, 2, &batch, 1);
        assert_eq!(plan.union_rows, union.rows);
        assert_eq!(plan.clusters_probed, union.clusters_probed);
        // heap advance: 3*2 + 2 + 2 = 10, vs the union scan's 7*4 = 28
        assert_eq!(plan.advanced_rows, 10);
        assert!(
            plan.advanced_rows
                <= plan.union_rows as u64 * batch.len() as u64
        );
        // nprobe >= k: every query selects everything -> one group with
        // one fused full range
        let all = plan_probes_per_query(&m, 2, &batch, 10);
        assert_eq!(all.groups.len(), 1);
        assert_eq!(all.groups[0].ranges, vec![(0, 7)]);
        assert_eq!(all.groups[0].queries, vec![0, 1, 2, 3]);
        assert_eq!(all.advanced_rows, 28);
    }

    #[test]
    fn per_query_plan_handles_empty_and_degenerate_batches() {
        let m = meta_for_tests();
        let none = plan_probes_per_query(&m, 2, &[], 2);
        assert!(none.groups.is_empty());
        assert_eq!((none.union_rows, none.advanced_rows), (0, 0));
        // degenerate index: every cluster empty except an unselectable
        // layout -> full-range fallback, all queries in one group
        let mut all_empty = meta_for_tests();
        all_empty.clusters = vec![
            ClusterRange { start_row: 0, rows: 0 },
            ClusterRange { start_row: 0, rows: 0 },
            ClusterRange { start_row: 0, rows: 7 },
        ];
        let q0: &[f32] = &[1.0, 0.0];
        let p = plan_probes_per_query(&all_empty, 2, &[q0], 1);
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].rows, 7);
        assert!(p.advanced_rows >= 7);
    }
}
