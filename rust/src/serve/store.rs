//! On-disk sharded embedding store: the cold tier of the serving engine.
//!
//! A store directory holds `store.json` (manifest), `vocab.tsv`, and per
//! shard two binary files with a fixed little-endian layout:
//!
//! * `shard_NNN.f32` — magic `FW2S`, version u32, start_row u64, rows u64,
//!   dim u64, then `rows * dim` f32 (row-major, L2-normalized at export).
//! * `shard_NNN.i8`  — magic `FW2Q`, same header, then `rows` f32 per-row
//!   scales followed by `rows * dim` i8 quantized values.
//!
//! Rows are normalized once at export so cosine similarity degrades to a
//! dot product at query time — the same move-work-off-the-hot-path logic
//! as the paper's batch-time indirection.  Int8 quantization is symmetric
//! per row (`scale = max_abs / 127`), cutting the footprint ~4x with a
//! per-component error of at most `scale / 2`.
//!
//! Shards are *paged in lazily*: [`ShardedStore::open`] reads the
//! manifest and validates every shard's **header** (magic, version, row
//! range, dim, on-disk length) up front — so a truncated or mismatched
//! shard fails at open instead of surfacing mid-query as a worker error
//! — while row payloads still load on first touch.  The hot tier above
//! this ([`super::cache::HotCache`]) keeps the Zipf head in RAM,
//! mirroring the paper's registers/shared-memory/HBM hierarchy.
//!
//! **Format v2 (IVF):** [`export_store_clustered`] trains a k-means
//! coarse quantizer ([`super::ivf`]), reorders rows by cluster so every
//! cluster's inverted list is a contiguous row block, and persists the
//! centroid table + cluster ranges + row→id permutation in
//! `store.json` (`format: 2`).  v1 stores (no index) keep opening and
//! serving exhaustively.  Boundary hygiene both ways: non-finite model
//! rows are zeroed (with a warning) at export — a single NaN score
//! would outrank every real neighbor under `total_cmp` — and a shard
//! whose payload contains non-finite values is rejected at load.
//!
//! **Format v3 (binary IVF sidecar):** the default export format.  The
//! index metadata moves out of `store.json` into `ivf.bin` — magic
//! `FW2I`, versioned little-endian header, cluster ranges, the f32
//! centroid table plus its int8 quantization (scales + codes, used by
//! the probe planner's prefilter), and the row→id permutation — so
//! opening a store parses an O(shards) JSON manifest and does one
//! length-validated binary read instead of an O(vocab) JSON walk.
//! `export_store_clustered_as` still writes v2 on request; v1/v2 stores
//! open bit-identically to before.
//!
//! **Paging (mmap):** on little-endian linux, shard payloads are
//! memory-mapped ([`super::mmapfile`]) instead of heap-copied, so
//! "paging in" a cold shard is an address-space reservation and row
//! traffic is demand-paged by the kernel.  `RowBlock` views come
//! straight off the mapping.  Heap loading remains the fallback (other
//! targets, `FULLW2V_NO_MMAP=1`, any syscall failure) and is
//! bit-identical; [`ShardedStore::bytes_mapped`] /
//! [`ShardedStore::bytes_heap_loaded`] account which tier paid.

use super::ivf::{self, IvfMeta};
use super::mmapfile::{self, MappedShard};
use crate::corpus::vocab::Vocab;
use crate::model::embeddings::normalize_rows_in_place;
use crate::model::EmbeddingModel;
use crate::util::json::{obj, Json};
use crate::vecops;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

const MAGIC_F32: &[u8; 4] = b"FW2S";
const MAGIC_I8: &[u8; 4] = b"FW2Q";
/// Magic of the format-3 binary IVF sidecar (`ivf.bin`).
const MAGIC_IVF: &[u8; 4] = b"FW2I";
const VERSION: u32 = 1;
/// magic(4) + version(4) + start_row(8) + rows(8) + dim(8).
const HEADER_BYTES: u64 = 32;
/// The v3 sidecar file name, next to the shard files.
pub const SIDECAR_FILE: &str = "ivf.bin";
/// Seed for the export-time k-means (deterministic stores).
const KMEANS_SEED: u64 = 0x1Fa5_C0DE;

/// Which shard files a store reads at query time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 rows — exact cosine.
    Exact,
    /// Int8 rows with per-row scales — ~4x smaller, approximate.
    Quantized,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::Exact => "exact",
            Precision::Quantized => "quantized",
        }
    }
}

/// Row range covered by one shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMeta {
    pub start_row: usize,
    pub rows: usize,
}

/// Parsed `store.json`.  `ivf` is present for cluster-reordered (v2/v3)
/// stores and absent for flat v1 stores; `sidecar` marks a format-3
/// store whose index lives in the binary `ivf.bin` next to the shards
/// (stitched into `ivf` by [`ShardedStore::open`], so a freshly parsed
/// v3 manifest has `sidecar == true` and `ivf == None`).
#[derive(Debug, Clone, PartialEq)]
pub struct StoreManifest {
    pub vocab_size: usize,
    pub dim: usize,
    pub shards: Vec<ShardMeta>,
    pub ivf: Option<IvfMeta>,
    pub sidecar: bool,
}

impl StoreManifest {
    pub fn to_json(&self) -> Json {
        let format = if self.sidecar {
            3.0
        } else if self.ivf.is_some() {
            2.0
        } else {
            1.0
        };
        let mut fields = vec![
            ("format", Json::Num(format)),
            ("vocab_size", Json::Num(self.vocab_size as f64)),
            ("dim", Json::Num(self.dim as f64)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("start_row", Json::Num(s.start_row as f64)),
                                ("rows", Json::Num(s.rows as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if !self.sidecar {
            if let Some(ivf) = &self.ivf {
                fields.push(("ivf", ivf.to_json()));
            }
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<StoreManifest> {
        let get_usize = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing '{key}'"))
        };
        let format = get_usize("format")?;
        if !(1..=3).contains(&format) {
            bail!("unsupported store format {format}");
        }
        let vocab_size = get_usize("vocab_size")?;
        let dim = get_usize("dim")?;
        let shards = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'shards'"))?
            .iter()
            .map(|s| -> Result<ShardMeta> {
                let f = |key: &str| {
                    s.get(key)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("shard missing '{key}'"))
                };
                Ok(ShardMeta { start_row: f("start_row")?, rows: f("rows")? })
            })
            .collect::<Result<Vec<_>>>()?;
        let (ivf, sidecar) = match (format, j.get("ivf")) {
            (2, Some(x)) => (Some(IvfMeta::from_json(x)?), false),
            (2, None) => bail!("format 2 store is missing its ivf index"),
            (3, None) => (None, true),
            (3, Some(_)) => bail!(
                "format 3 store keeps its ivf index in the binary sidecar, \
                 not the manifest"
            ),
            (_, Some(_)) => bail!("format 1 store must not carry an ivf index"),
            (_, None) => (None, false),
        };
        let m = StoreManifest { vocab_size, dim, shards, ivf, sidecar };
        m.validate()?;
        Ok(m)
    }

    /// Shards must tile [0, vocab_size) contiguously without gaps, with
    /// checked sums (a manifest is attacker-controllable input); any
    /// embedded IVF index is validated against the same bounds.
    pub fn validate(&self) -> Result<()> {
        if self.dim == 0 {
            bail!("store dim must be positive");
        }
        let mut next = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            if s.start_row != next {
                bail!("shard {i} starts at {} expected {next}", s.start_row);
            }
            next = next
                .checked_add(s.rows)
                .ok_or_else(|| anyhow!("shard row counts overflow"))?;
        }
        if next != self.vocab_size {
            bail!("shards cover {next} rows, vocab is {}", self.vocab_size);
        }
        if let Some(ivf) = &self.ivf {
            ivf.validate(self.vocab_size, self.dim)?;
        }
        Ok(())
    }

    /// (shard index, local row) for a *store row* (the post-reordering
    /// position, not the word id).  `rows_per_shard_hint` is the uniform
    /// layout the exporter writes, making the division exact; the
    /// adjustment loops make irregular (but validated-contiguous)
    /// manifests correct too, including empty shards, and are bounds-
    /// checked so an adversarial hint or manifest yields `None` rather
    /// than an underflow/overflow panic.
    pub fn locate_row(
        &self,
        row: usize,
        rows_per_shard_hint: usize,
    ) -> Option<(usize, usize)> {
        if row >= self.vocab_size || self.shards.is_empty() {
            return None;
        }
        let mut idx =
            (row / rows_per_shard_hint.max(1)).min(self.shards.len() - 1);
        while idx > 0 && self.shards[idx].start_row > row {
            idx -= 1;
        }
        loop {
            let s = &self.shards[idx];
            if row >= s.start_row && row < s.start_row.checked_add(s.rows)? {
                return Some((idx, row - s.start_row));
            }
            idx += 1;
            if idx >= self.shards.len() {
                return None;
            }
        }
    }
}

/// Symmetric per-row int8 quantization: `scale = max_abs / 127`.
/// Returns the scale and quantized values; a zero row quantizes to
/// scale 0 and all-zero codes.
pub fn quantize_row(row: &[f32]) -> (f32, Vec<i8>) {
    let max_abs = row.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    if max_abs == 0.0 {
        return (0.0, vec![0; row.len()]);
    }
    let scale = max_abs / 127.0;
    let q = row
        .iter()
        .map(|x| (x / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (scale, q)
}

/// Inverse of [`quantize_row`].
pub fn dequantize_into(scale: f32, q: &[i8], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(q) {
        *o = v as f32 * scale;
    }
}

enum ShardData {
    F32(Vec<f32>),
    I8 { scales: Vec<f32>, codes: Vec<i8> },
    /// `rows * dim` f32 payload viewed directly over the file mapping.
    MappedF32(MappedShard),
    /// Scales (f32 region) + codes (i8 region) over the file mapping.
    MappedI8(MappedShard),
}

/// Borrowed view of a contiguous block of shard rows in the shard's
/// native precision — what the batched scan's tile kernels consume.
/// No per-row allocation or dequantization happens to produce one;
/// tiles read straight out of shard memory.
#[derive(Debug, Clone, Copy)]
pub enum RowBlock<'a> {
    /// `rows * dim` f32, row-major.
    F32(&'a [f32]),
    /// One scale per row plus `rows * dim` int8 codes, row-major.
    I8 { scales: &'a [f32], codes: &'a [i8] },
}

/// One loaded shard: a contiguous block of rows.
pub struct Shard {
    pub start_row: usize,
    pub rows: usize,
    pub dim: usize,
    /// The store's full row→id permutation for cluster-reordered (v2)
    /// stores, shared across every shard (one `Arc` clone per load, no
    /// per-shard copy); `None` when row position == id (flat v1
    /// layout).  This shard's rows are the
    /// `[start_row, start_row + rows)` window of it.
    ids: Option<Arc<[u32]>>,
    data: ShardData,
}

impl Shard {
    /// Original word id of shard-local row `local`.
    #[inline]
    pub fn id_of(&self, local: usize) -> u32 {
        match &self.ids {
            Some(v) => v[self.start_row + local],
            None => (self.start_row + local) as u32,
        }
    }

    /// Word ids of `n` rows from `start`, when the store is cluster-
    /// reordered; `None` for the flat layout (id == global row).
    pub fn ids_block(&self, start: usize, n: usize) -> Option<&[u32]> {
        // same checked arithmetic as row_block: a wrapped end would
        // panic later with a misleading slice error in release builds
        // LINT: allow(panic-path): an overflowing block request means a
        // corrupted manifest or caller bug, not client input — fail
        // loudly at the source instead of slicing garbage.
        let lo = self
            .start_row
            .checked_add(start)
            .unwrap_or_else(|| panic!("ids block start {start} overflows"));
        // LINT: allow(panic-path): same manifest-corruption invariant
        // as `lo` above.
        let hi = lo
            .checked_add(n)
            .unwrap_or_else(|| panic!("ids block [{start}, {start}+{n}) overflows"));
        self.ids.as_ref().map(|v| &v[lo..hi])
    }

    /// Whether this shard serves rows straight off a file mapping
    /// (mmap-resident) rather than a heap copy.
    pub fn is_mapped(&self) -> bool {
        matches!(
            self.data,
            ShardData::MappedF32(_) | ShardData::MappedI8(_)
        )
    }

    /// File bytes behind this shard's mapping; 0 for heap-loaded shards.
    pub fn mapped_file_bytes(&self) -> usize {
        match &self.data {
            ShardData::MappedF32(m) | ShardData::MappedI8(m) => {
                m.mapped_bytes()
            }
            _ => 0,
        }
    }

    /// Materialize row `local` (shard-relative index) into `out`.
    pub fn row_into(&self, local: usize, out: &mut [f32]) {
        assert!(local < self.rows, "local row {local} >= {}", self.rows);
        assert_eq!(out.len(), self.dim);
        match self.row_block(local, 1) {
            RowBlock::F32(row) => out.copy_from_slice(row),
            RowBlock::I8 { scales, codes } => {
                dequantize_into(scales[0], codes, out);
            }
        }
    }

    /// Borrow `n` rows starting at shard-local row `start`, in native
    /// precision.  `row_block(0, self.rows)` views the whole shard.
    pub fn row_block(&self, start: usize, n: usize) -> RowBlock<'_> {
        // checked: for adversarial inputs `start + n` wraps in release
        // builds, slipping past the bound check only to panic later
        // with a misleading slice error
        // LINT: allow(panic-path): overflow means a caller bug (scan
        // ranges come from the manifest, not the wire) — fail loudly.
        let end = start
            .checked_add(n)
            .unwrap_or_else(|| panic!("block [{start}, {start}+{n}) overflows"));
        assert!(
            end <= self.rows,
            "block [{start}, {end}) exceeds {} rows",
            self.rows
        );
        let base = start * self.dim;
        let len = n * self.dim;
        match &self.data {
            ShardData::F32(rows) => RowBlock::F32(&rows[base..base + len]),
            ShardData::I8 { scales, codes } => RowBlock::I8 {
                scales: &scales[start..start + n],
                codes: &codes[base..base + len],
            },
            // zero-copy views straight off the file mapping: bounds and
            // alignment were validated when the mapping was constructed
            ShardData::MappedF32(m) => {
                RowBlock::F32(&m.f32s()[base..base + len])
            }
            ShardData::MappedI8(m) => RowBlock::I8 {
                scales: &m.f32s()[start..start + n],
                codes: &m.i8s()[base..base + len],
            },
        }
    }

    /// Dot-product `query` against every row, calling `f(word_id,
    /// score)` per row (the id goes through the v2 permutation when the
    /// store is cluster-reordered).  The precision dispatch is hoisted
    /// out of the row loop; both paths use the shared [`crate::vecops`]
    /// kernels, so per-query scores match the batched tile scan bit for
    /// bit.
    pub fn for_each_score<F: FnMut(u32, f32)>(&self, query: &[f32], mut f: F) {
        assert_eq!(query.len(), self.dim);
        // the whole-shard block view unifies heap and mmap storage: the
        // per-precision loops below never care where the bytes live
        match self.row_block(0, self.rows) {
            RowBlock::F32(rows) => {
                for (local, row) in rows.chunks_exact(self.dim).enumerate() {
                    f(self.id_of(local), vecops::dot(row, query));
                }
            }
            RowBlock::I8 { scales, codes } => {
                for (local, row) in codes.chunks_exact(self.dim).enumerate() {
                    f(
                        self.id_of(local),
                        vecops::dot_i8(row, scales[local], query),
                    );
                }
            }
        }
    }

    /// Footprint of the row payload in bytes (heap or mapped file).
    pub fn payload_bytes(&self) -> usize {
        match &self.data {
            ShardData::F32(rows) => rows.len() * 4,
            ShardData::I8 { scales, codes } => scales.len() * 4 + codes.len(),
            ShardData::MappedF32(m) | ShardData::MappedI8(m) => {
                m.payload_bytes()
            }
        }
    }
}

/// Zero any row containing a non-finite value.  A divergent model must
/// not poison the store: `Entry`'s `total_cmp` ordering would rank a
/// NaN score above every real neighbor in every query's top-k.  Returns
/// how many rows were zeroed.
fn sanitize_rows(rows: &mut [f32], dim: usize) -> usize {
    let mut zeroed = 0usize;
    for row in rows.chunks_exact_mut(dim) {
        if row.iter().any(|x| !x.is_finite()) {
            row.fill(0.0);
            zeroed += 1;
        }
    }
    zeroed
}

/// Export a trained model as a flat (format v1) sharded store directory.
///
/// Rows are L2-normalized `syn0` rows; both the f32 and the int8 file are
/// written for every shard so a store can be opened at either precision.
/// Non-finite rows are zeroed with a warning (see [`sanitize_rows`]).
pub fn export_store(
    model: &EmbeddingModel,
    vocab: &Vocab,
    dir: &Path,
    shards: usize,
) -> Result<StoreManifest> {
    export_store_clustered(model, vocab, dir, shards, 0)
}

/// Which on-disk layout a clustered export writes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreFormat {
    /// Format 2: the IVF index embedded in `store.json` (legacy).
    V2Manifest,
    /// Format 3: the IVF index in the binary `ivf.bin` sidecar
    /// (default — store open stays O(shards + clusters), not O(vocab)).
    V3Sidecar,
}

impl StoreFormat {
    pub fn name(self) -> &'static str {
        match self {
            StoreFormat::V2Manifest => "v2",
            StoreFormat::V3Sidecar => "v3",
        }
    }
}

/// [`export_store`] plus an IVF coarse index: `clusters > 1` trains a
/// k-means quantizer over the normalized rows, reorders them by cluster
/// (each cluster one contiguous row block), and persists the centroid
/// table (f32 and its int8 quantization), cluster ranges, and row→id
/// permutation — in the binary `ivf.bin` sidecar (format 3, the
/// default).  `clusters <= 1` writes a flat v1 store.
pub fn export_store_clustered(
    model: &EmbeddingModel,
    vocab: &Vocab,
    dir: &Path,
    shards: usize,
    clusters: usize,
) -> Result<StoreManifest> {
    export_store_clustered_as(
        model,
        vocab,
        dir,
        shards,
        clusters,
        StoreFormat::V3Sidecar,
    )
}

/// [`export_store_clustered`] with an explicit on-disk format —
/// `V2Manifest` keeps writing the legacy JSON-embedded index for
/// downgrade paths and format-matrix tests.
pub fn export_store_clustered_as(
    model: &EmbeddingModel,
    vocab: &Vocab,
    dir: &Path,
    shards: usize,
    clusters: usize,
    format: StoreFormat,
) -> Result<StoreManifest> {
    if model.dim == 0 {
        bail!("model dim must be positive (got a 0-dim model)");
    }
    if vocab.len() != model.vocab_size {
        bail!(
            "vocab size {} != model vocab size {}",
            vocab.len(),
            model.vocab_size
        );
    }
    let shards = shards.max(1);
    let v = model.vocab_size;
    let d = model.dim;
    let rows_per_shard = v.div_ceil(shards);
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;

    let mut normalized = model.syn0.clone();
    let zeroed = sanitize_rows(&mut normalized, d);
    if zeroed > 0 {
        crate::log_warn!(
            "export: zeroed {zeroed} non-finite embedding row(s) — the \
             model diverged for those words; they will score 0 against \
             every query"
        );
    }
    normalize_rows_in_place(&mut normalized, d);

    let ivf_meta = if clusters > 1 && v > 1 {
        let km = ivf::train_kmeans(
            &normalized,
            d,
            clusters.min(v),
            ivf::DEFAULT_KMEANS_ITERS,
            KMEANS_SEED,
        );
        let (row_ids, ranges) = ivf::build_layout(&km, d);
        // reorder rows by cluster so every probe list is one contiguous
        // row block the tile scan can walk unchanged
        let mut reordered = vec![0.0f32; normalized.len()];
        for (new_row, &id) in row_ids.iter().enumerate() {
            let src = id as usize * d;
            reordered[new_row * d..(new_row + 1) * d]
                .copy_from_slice(&normalized[src..src + d]);
        }
        normalized = reordered;
        // `new` derives the centroid table's int8 quantization so the
        // probe planner's prefilter data ships with the index
        Some(IvfMeta::new(ranges, km.centroids, row_ids.into()))
    } else {
        None
    };

    let mut metas = Vec::new();
    let mut start = 0usize;
    for i in 0..shards {
        let end = (start + rows_per_shard).min(v);
        let rows = end - start;
        let block = &normalized[start * d..end * d];
        write_f32_shard(&shard_path(dir, i, Precision::Exact), start, d, block)?;
        write_i8_shard(&shard_path(dir, i, Precision::Quantized), start, d, block)?;
        metas.push(ShardMeta { start_row: start, rows });
        start = end;
    }
    let sidecar =
        ivf_meta.is_some() && format == StoreFormat::V3Sidecar;
    let manifest = StoreManifest {
        vocab_size: v,
        dim: d,
        shards: metas,
        ivf: ivf_meta,
        sidecar,
    };
    manifest.validate()?;
    vocab
        .save(&dir.join("vocab.tsv"))
        .context("writing vocab.tsv")?;
    if sidecar {
        if let Some(ivf) = &manifest.ivf {
            write_ivf_sidecar(&dir.join(SIDECAR_FILE), ivf, d, v)?;
        }
    }
    std::fs::write(dir.join("store.json"), manifest.to_json().to_string())
        .context("writing store.json")?;
    Ok(manifest)
}

/// Write the format-3 binary IVF sidecar: magic `FW2I`, version, then a
/// k / dim / vocab header followed by cluster ranges, the f32 centroid
/// table, its int8 quantization (scales + codes), and the row→id
/// permutation — all little-endian, mirroring the shard file layout.
fn write_ivf_sidecar(
    path: &Path,
    ivf: &IvfMeta,
    dim: usize,
    vocab_size: usize,
) -> Result<()> {
    let mut f = BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC_IVF)?;
    f.write_all(&VERSION.to_le_bytes())?;
    let k = ivf.num_clusters();
    f.write_all(&(k as u64).to_le_bytes())?;
    f.write_all(&(dim as u64).to_le_bytes())?;
    f.write_all(&(vocab_size as u64).to_le_bytes())?;
    for c in &ivf.clusters {
        f.write_all(&(c.start_row as u64).to_le_bytes())?;
        f.write_all(&(c.rows as u64).to_le_bytes())?;
    }
    for x in &ivf.centroids {
        f.write_all(&x.to_le_bytes())?;
    }
    for s in &ivf.centroid_scales {
        f.write_all(&s.to_le_bytes())?;
    }
    // i8 -> u8 is a bit-pattern reinterpretation, valid for any value
    let bytes: Vec<u8> =
        ivf.centroid_codes.iter().map(|&c| c as u8).collect();
    f.write_all(&bytes)?;
    for &id in ivf.row_ids.iter() {
        f.write_all(&id.to_le_bytes())?;
    }
    Ok(())
}

/// Read and validate a format-3 `ivf.bin` sidecar.  The header must
/// agree with the manifest's `dim`/`vocab_size` and the on-disk length
/// must match what the header implies — computed with checked u64 math
/// (the header is attacker-controllable input) *before* any payload
/// allocation — so truncation or corruption fails the open fast.
fn read_ivf_sidecar(
    path: &Path,
    dim: usize,
    vocab_size: usize,
) -> Result<IvfMeta> {
    fn next_u64(f: &mut impl Read) -> Result<u64> {
        let mut b = [0u8; 8];
        f.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let actual_len = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut f = BufReader::new(file);
    let mut m = [0u8; 4];
    f.read_exact(&mut m)
        .with_context(|| format!("reading {} header", path.display()))?;
    if &m != MAGIC_IVF {
        bail!("{}: bad sidecar magic", path.display());
    }
    let mut u4 = [0u8; 4];
    f.read_exact(&mut u4)?;
    let version = u32::from_le_bytes(u4);
    if version != VERSION {
        bail!("{}: unsupported sidecar version {version}", path.display());
    }
    let k = next_u64(&mut f)?;
    let hdim = next_u64(&mut f)?;
    let hvocab = next_u64(&mut f)?;
    if hdim != dim as u64 || hvocab != vocab_size as u64 {
        bail!(
            "{}: sidecar header (k={k}, dim={hdim}, vocab={hvocab}) \
             disagrees with manifest (dim={dim}, vocab={vocab_size})",
            path.display()
        );
    }
    let payload = k
        .checked_mul(dim as u64)
        .and_then(|kd| {
            // ranges 16k + centroids 4kd + scales 4k + codes kd + ids 4V
            let ranges = k.checked_mul(16)?;
            let cents = kd.checked_mul(4)?;
            let scales = k.checked_mul(4)?;
            let ids = (vocab_size as u64).checked_mul(4)?;
            ranges
                .checked_add(cents)?
                .checked_add(scales)?
                .checked_add(kd)?
                .checked_add(ids)
        })
        .ok_or_else(|| {
            anyhow!("{}: sidecar header sizes overflow", path.display())
        })?;
    let expected = HEADER_BYTES
        .checked_add(payload)
        .ok_or_else(|| anyhow!("{}: sidecar size overflows", path.display()))?;
    if actual_len != expected {
        bail!(
            "{}: {actual_len} bytes on disk, header implies {expected} \
             (truncated or corrupt sidecar)",
            path.display()
        );
    }
    let k = k as usize;
    let mut clusters = Vec::with_capacity(k);
    for _ in 0..k {
        let start_row = next_u64(&mut f)? as usize;
        let rows = next_u64(&mut f)? as usize;
        clusters.push(ivf::ClusterRange { start_row, rows });
    }
    let read_f32s = |f: &mut BufReader<std::fs::File>,
                     n: usize|
     -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    };
    let centroids = read_f32s(&mut f, k * dim)?;
    let centroid_scales = read_f32s(&mut f, k)?;
    let mut code_bytes = vec![0u8; k * dim];
    f.read_exact(&mut code_bytes)?;
    let centroid_codes: Vec<i8> =
        code_bytes.iter().map(|&b| b as i8).collect();
    let mut id_bytes = vec![0u8; vocab_size * 4];
    f.read_exact(&mut id_bytes)?;
    let row_ids: Vec<u32> = id_bytes
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(IvfMeta {
        clusters,
        centroids,
        centroid_scales,
        centroid_codes,
        row_ids: row_ids.into(),
    })
}

fn shard_path(dir: &Path, i: usize, precision: Precision) -> PathBuf {
    let ext = match precision {
        Precision::Exact => "f32",
        Precision::Quantized => "i8",
    };
    dir.join(format!("shard_{i:03}.{ext}"))
}

fn write_header(
    f: &mut impl Write,
    magic: &[u8; 4],
    start_row: usize,
    rows: usize,
    dim: usize,
) -> std::io::Result<()> {
    f.write_all(magic)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(start_row as u64).to_le_bytes())?;
    f.write_all(&(rows as u64).to_le_bytes())?;
    f.write_all(&(dim as u64).to_le_bytes())?;
    Ok(())
}

fn write_f32_shard(
    path: &Path,
    start_row: usize,
    dim: usize,
    block: &[f32],
) -> Result<()> {
    let rows = block.len() / dim.max(1);
    let mut f = BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    write_header(&mut f, MAGIC_F32, start_row, rows, dim)?;
    for x in block {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_i8_shard(
    path: &Path,
    start_row: usize,
    dim: usize,
    block: &[f32],
) -> Result<()> {
    let rows = block.len() / dim.max(1);
    let mut scales = Vec::with_capacity(rows);
    let mut codes: Vec<i8> = Vec::with_capacity(block.len());
    for row in block.chunks_exact(dim) {
        let (scale, q) = quantize_row(row);
        scales.push(scale);
        codes.extend_from_slice(&q);
    }
    let mut f = BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    write_header(&mut f, MAGIC_I8, start_row, rows, dim)?;
    for s in &scales {
        f.write_all(&s.to_le_bytes())?;
    }
    // i8 -> u8 is a bit-pattern reinterpretation, valid for any value
    let bytes: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
    f.write_all(&bytes)?;
    Ok(())
}

fn read_header(
    f: &mut impl Read,
    magic: &[u8; 4],
    path: &Path,
) -> Result<(usize, usize, usize)> {
    let mut m = [0u8; 4];
    f.read_exact(&mut m)?;
    if &m != magic {
        bail!("{}: bad magic", path.display());
    }
    let mut u4 = [0u8; 4];
    f.read_exact(&mut u4)?;
    let version = u32::from_le_bytes(u4);
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let mut u8b = [0u8; 8];
    let mut next = || -> Result<usize> {
        f.read_exact(&mut u8b)?;
        Ok(u64::from_le_bytes(u8b) as usize)
    };
    let start_row = next()?;
    let rows = next()?;
    let dim = next()?;
    Ok((start_row, rows, dim))
}

fn shard_magic(precision: Precision) -> &'static [u8; 4] {
    match precision {
        Precision::Exact => MAGIC_F32,
        Precision::Quantized => MAGIC_I8,
    }
}

/// Header-only shard validation, run for every shard at
/// [`ShardedStore::open`]: magic/version/row-range/dim must agree with
/// the manifest and the on-disk length must match the payload the
/// header promises — so truncation or a stale file fails the open
/// instead of surfacing mid-query as a whole-batch worker error.  Row
/// payloads are not read (paging stays lazy); sizes use checked u64
/// math since the header is attacker-controllable input.
fn validate_shard_file(
    path: &Path,
    precision: Precision,
    meta: &ShardMeta,
    dim: usize,
) -> Result<()> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let actual_len = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut f = BufReader::new(file);
    let (start_row, rows, d) =
        read_header(&mut f, shard_magic(precision), path)?;
    if start_row != meta.start_row || rows != meta.rows || d != dim {
        bail!(
            "{}: header ({start_row},{rows},{d}) disagrees with manifest \
             ({},{},{dim})",
            path.display(),
            meta.start_row,
            meta.rows,
        );
    }
    let cells = (rows as u64).checked_mul(d as u64);
    let payload = match precision {
        Precision::Exact => cells.and_then(|c| c.checked_mul(4)),
        Precision::Quantized => cells
            .and_then(|c| c.checked_add((rows as u64).checked_mul(4)?)),
    }
    .ok_or_else(|| {
        anyhow!("{}: header row/dim sizes overflow", path.display())
    })?;
    let expected = HEADER_BYTES
        .checked_add(payload)
        .ok_or_else(|| anyhow!("{}: shard size overflows", path.display()))?;
    if actual_len != expected {
        bail!(
            "{}: {actual_len} bytes on disk, header implies {expected} \
             (truncated or corrupt shard)",
            path.display()
        );
    }
    Ok(())
}

fn load_shard(
    path: &Path,
    precision: Precision,
    meta: &ShardMeta,
    dim: usize,
    ids: Option<Arc<[u32]>>,
) -> Result<Shard> {
    let mut f = BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let (start_row, rows, d) =
        read_header(&mut f, shard_magic(precision), path)?;
    if start_row != meta.start_row || rows != meta.rows || d != dim {
        bail!(
            "{}: header ({start_row},{rows},{d}) disagrees with manifest \
             ({},{},{dim})",
            path.display(),
            meta.start_row,
            meta.rows,
        );
    }
    let read_f32s = |f: &mut BufReader<std::fs::File>,
                     n: usize|
     -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    };
    // non-finite payloads are rejected, never served: one NaN row would
    // outrank every real neighbor in every query (total_cmp ordering)
    let data = match precision {
        Precision::Exact => {
            let values = read_f32s(&mut f, rows * d)?;
            if values.iter().any(|x| !x.is_finite()) {
                bail!(
                    "{}: shard payload contains non-finite values \
                     (corrupt file or unsanitized export)",
                    path.display()
                );
            }
            ShardData::F32(values)
        }
        Precision::Quantized => {
            let scales = read_f32s(&mut f, rows)?;
            if scales.iter().any(|x| !x.is_finite()) {
                bail!(
                    "{}: non-finite quantization scales (corrupt file or \
                     unsanitized export)",
                    path.display()
                );
            }
            let mut bytes = vec![0u8; rows * d];
            f.read_exact(&mut bytes)?;
            let codes = bytes.iter().map(|&b| b as i8).collect();
            ShardData::I8 { scales, codes }
        }
    };
    Ok(Shard { start_row, rows, dim: d, ids, data })
}

/// Try to memory-map a shard instead of heap-loading it.  `Ok(None)`
/// means mapping declined (unsupported target, `FULLW2V_NO_MMAP=1`,
/// syscall failure, size overflow) and the caller should heap-load;
/// `Err` means actual corruption.  A mapped shard gets the same header
/// re-validation and non-finite payload scan as [`load_shard`], with
/// identical error messages, so the two tiers are indistinguishable to
/// callers — corruption never silently "falls back".
fn map_shard(
    path: &Path,
    precision: Precision,
    meta: &ShardMeta,
    dim: usize,
    ids: Option<Arc<[u32]>>,
) -> Result<Option<Shard>> {
    if !mmapfile::enabled() {
        return Ok(None);
    }
    // re-validate the header through the reader (open() already did,
    // but the file may have changed since) before trusting offsets
    let mut f = BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let (start_row, rows, d) =
        read_header(&mut f, shard_magic(precision), path)?;
    if start_row != meta.start_row || rows != meta.rows || d != dim {
        bail!(
            "{}: header ({start_row},{rows},{d}) disagrees with manifest \
             ({},{},{dim})",
            path.display(),
            meta.start_row,
            meta.rows,
        );
    }
    drop(f);
    let Some(map) = mmapfile::map(path) else {
        return Ok(None);
    };
    let header = HEADER_BYTES as usize;
    let Some(cells) = rows.checked_mul(d) else {
        return Ok(None);
    };
    let data = match precision {
        Precision::Exact => {
            let Some(m) = MappedShard::new(map, header, cells, 0, 0) else {
                return Ok(None);
            };
            if m.f32s().iter().any(|x| !x.is_finite()) {
                bail!(
                    "{}: shard payload contains non-finite values \
                     (corrupt file or unsanitized export)",
                    path.display()
                );
            }
            ShardData::MappedF32(m)
        }
        Precision::Quantized => {
            let Some(codes_off) =
                rows.checked_mul(4).and_then(|b| b.checked_add(header))
            else {
                return Ok(None);
            };
            let Some(m) =
                MappedShard::new(map, header, rows, codes_off, cells)
            else {
                return Ok(None);
            };
            if m.f32s().iter().any(|x| !x.is_finite()) {
                bail!(
                    "{}: non-finite quantization scales (corrupt file or \
                     unsanitized export)",
                    path.display()
                );
            }
            ShardData::MappedI8(m)
        }
    };
    Ok(Some(Shard { start_row, rows, dim: d, ids, data }))
}

/// A store opened at a chosen precision, with lazily-loaded shards.
pub struct ShardedStore {
    dir: PathBuf,
    precision: Precision,
    manifest: StoreManifest,
    /// Rows per full shard (every shard except possibly the last).
    rows_per_shard: usize,
    /// Inverse of the v2/v3 permutation (`row_of[id] = store row`);
    /// `None` for flat v1 stores where id == row.
    row_of: Option<Vec<u32>>,
    cells: Vec<OnceLock<Shard>>,
    /// File bytes behind live shard mappings (the mmap cold tier).
    bytes_mapped: AtomicU64,
    /// Payload bytes heap-copied by the fallback loader.
    bytes_heap_loaded: AtomicU64,
}

impl ShardedStore {
    /// Read the manifest and validate every shard's header and on-disk
    /// size ([`validate_shard_file`]); row payloads load on first touch.
    pub fn open(dir: &Path, precision: Precision) -> Result<ShardedStore> {
        let text = std::fs::read_to_string(dir.join("store.json"))
            .with_context(|| format!("reading {}/store.json", dir.display()))?;
        let doc = Json::parse(&text).context("parsing store.json")?;
        let mut manifest = StoreManifest::from_json(&doc)?;
        if manifest.sidecar {
            // format 3: stitch the index in from the binary sidecar —
            // one length-validated read, no O(vocab) JSON walk
            manifest.ivf = Some(read_ivf_sidecar(
                &dir.join(SIDECAR_FILE),
                manifest.dim,
                manifest.vocab_size,
            )?);
            manifest.validate()?;
        }
        for (i, meta) in manifest.shards.iter().enumerate() {
            validate_shard_file(
                &shard_path(dir, i, precision),
                precision,
                meta,
                manifest.dim,
            )?;
        }
        let rows_per_shard =
            manifest.shards.first().map(|s| s.rows).unwrap_or(1).max(1);
        let row_of = manifest.ivf.as_ref().map(IvfMeta::row_of_ids);
        let cells =
            (0..manifest.shards.len()).map(|_| OnceLock::new()).collect();
        Ok(ShardedStore {
            dir: dir.to_path_buf(),
            precision,
            manifest,
            rows_per_shard,
            row_of,
            cells,
            bytes_mapped: AtomicU64::new(0),
            bytes_heap_loaded: AtomicU64::new(0),
        })
    }

    pub fn dim(&self) -> usize {
        self.manifest.dim
    }

    pub fn vocab_size(&self) -> usize {
        self.manifest.vocab_size
    }

    pub fn num_shards(&self) -> usize {
        self.manifest.shards.len()
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    /// The IVF coarse index, when this is a cluster-reordered v2/v3
    /// store (for v3 it was stitched in from the sidecar at open).
    pub fn ivf(&self) -> Option<&IvfMeta> {
        self.manifest.ivf.as_ref()
    }

    /// How many shards have been paged in so far.
    pub fn loaded_shards(&self) -> usize {
        self.cells.iter().filter(|c| c.get().is_some()).count()
    }

    /// File bytes behind live shard mappings (0 when the mmap tier is
    /// unavailable or disabled).
    pub fn bytes_mapped(&self) -> u64 {
        self.bytes_mapped.load(Ordering::Relaxed)
    }

    /// Payload bytes heap-copied by the fallback loader.
    pub fn bytes_heap_loaded(&self) -> u64 {
        self.bytes_heap_loaded.load(Ordering::Relaxed)
    }

    /// Whether `id`'s shard is currently paged in as an mmap view — the
    /// hot cache uses this to skip pin-copies that would duplicate
    /// already-resident bytes.
    pub fn row_is_mapped(&self, id: u32) -> bool {
        self.locate(id)
            .and_then(|(idx, _)| self.cells[idx].get())
            .is_some_and(Shard::is_mapped)
    }

    /// (shard index, local row) for an original word id.  For cluster-
    /// reordered (v2) stores the id is first mapped through the stored
    /// permutation; flat stores use the id as the row directly.
    pub fn locate(&self, id: u32) -> Option<(usize, usize)> {
        let id = id as usize;
        if id >= self.manifest.vocab_size {
            return None;
        }
        let row = match &self.row_of {
            Some(inv) => inv[id] as usize,
            None => id,
        };
        self.manifest.locate_row(row, self.rows_per_shard)
    }

    /// Shard accessor; pages the shard in on first touch.
    pub fn shard(&self, i: usize) -> Result<&Shard> {
        if let Some(s) = self.cells[i].get() {
            return Ok(s);
        }
        let meta = &self.manifest.shards[i];
        // Arc clone of the manifest's shared permutation — no copy
        let ids = self.manifest.ivf.as_ref().map(|ivf| ivf.row_ids.clone());
        let path = shard_path(&self.dir, i, self.precision);
        // mmap first (zero-copy cold tier); heap load is the fallback
        // when mapping declines — never when it finds corruption
        let loaded = match map_shard(
            &path,
            self.precision,
            meta,
            self.manifest.dim,
            ids.clone(),
        )? {
            Some(s) => s,
            None => load_shard(
                &path,
                self.precision,
                meta,
                self.manifest.dim,
                ids,
            )?,
        };
        let mapped = loaded.mapped_file_bytes() as u64;
        let heap =
            if loaded.is_mapped() { 0 } else { loaded.payload_bytes() as u64 };
        // a concurrent loader may have won the race; either value is
        // identical so the loser's copy is just dropped — and only the
        // winner's bytes are accounted, so the counters never double
        if self.cells[i].set(loaded).is_ok() {
            self.bytes_mapped.fetch_add(mapped, Ordering::Relaxed);
            self.bytes_heap_loaded.fetch_add(heap, Ordering::Relaxed);
        }
        self.cells[i]
            .get()
            .ok_or_else(|| anyhow!("internal: shard {i} cell empty after set"))
    }

    /// Materialize a global row.  `None` for out-of-range ids.
    pub fn fetch_row(&self, row: u32, out: &mut [f32]) -> Result<Option<()>> {
        match self.locate(row) {
            None => Ok(None),
            Some((idx, local)) => {
                self.shard(idx)?.row_into(local, out);
                Ok(Some(()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab(n: usize) -> Vocab {
        Vocab::from_counts(
            (0..n).map(|i| (format!("w{i:03}"), (n - i) as u64 * 10)),
            1,
        )
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("fullw2v_store_test").join(name);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn quantize_roundtrip_error_bound() {
        let row: Vec<f32> =
            (0..64).map(|i| ((i as f32) * 0.37).sin() * 0.8).collect();
        let (scale, q) = quantize_row(&row);
        assert!(scale > 0.0);
        let mut back = vec![0.0; 64];
        dequantize_into(scale, &q, &mut back);
        for (x, y) in row.iter().zip(&back) {
            assert!(
                (x - y).abs() <= scale * 0.5 + 1e-7,
                "error {} above bound {}",
                (x - y).abs(),
                scale * 0.5
            );
        }
    }

    #[test]
    fn quantize_zero_row() {
        let (scale, q) = quantize_row(&[0.0; 8]);
        assert_eq!(scale, 0.0);
        assert!(q.iter().all(|&c| c == 0));
    }

    #[test]
    fn export_open_roundtrip_exact() {
        let v = vocab(10);
        let m = EmbeddingModel::init(10, 8, 3);
        let dir = tmpdir("exact");
        let manifest = export_store(&m, &v, &dir, 3).unwrap();
        assert_eq!(manifest.shards.len(), 3);
        // 10 rows over 3 shards: 4 + 4 + 2 (uneven last shard)
        assert_eq!(manifest.shards[2].rows, 2);

        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        assert_eq!(store.loaded_shards(), 0); // lazy until touched
        let normalized = m.normalized_rows();
        let mut out = vec![0.0; 8];
        for id in 0..10u32 {
            store.fetch_row(id, &mut out).unwrap().unwrap();
            assert_eq!(&out, &normalized[id as usize * 8..(id as usize + 1) * 8]);
        }
        assert_eq!(store.loaded_shards(), 3);
        assert!(store.fetch_row(10, &mut out).unwrap().is_none());
    }

    /// Regression for the panic-path fix in `shard`: when several
    /// threads race the first-touch load of the same shard, exactly one
    /// `set` wins and every caller — winner and losers alike — gets
    /// `Ok` with the same loaded shard, never a panic or an error.
    #[test]
    fn concurrent_first_touch_loads_resolve_for_all_racers() {
        let v = vocab(12);
        let m = EmbeddingModel::init(12, 8, 5);
        let dir = tmpdir("race");
        export_store(&m, &v, &dir, 2).unwrap();
        let store =
            Arc::new(ShardedStore::open(&dir, Precision::Exact).unwrap());
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let store = Arc::clone(&store);
                    s.spawn(move || {
                        let shard = store.shard(1).expect("load resolves");
                        shard.rows
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), 6, "all racers see the shard");
            }
        });
        assert_eq!(store.loaded_shards(), 1, "only shard 1 paged in");
    }

    #[test]
    fn quantized_rows_within_bound() {
        let v = vocab(7);
        let m = EmbeddingModel::init(7, 16, 9);
        let dir = tmpdir("quant");
        export_store(&m, &v, &dir, 2).unwrap();
        let store = ShardedStore::open(&dir, Precision::Quantized).unwrap();
        let normalized = m.normalized_rows();
        let mut out = vec![0.0; 16];
        for id in 0..7u32 {
            store.fetch_row(id, &mut out).unwrap().unwrap();
            let row = &normalized[id as usize * 16..(id as usize + 1) * 16];
            let max_abs = row.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            let bound = max_abs / 127.0 * 0.5 + 1e-7;
            for (x, y) in row.iter().zip(&out) {
                assert!((x - y).abs() <= bound);
            }
        }
    }

    #[test]
    fn export_rejects_zero_dim_model() {
        let v = vocab(3);
        let m = EmbeddingModel::init(3, 0, 1);
        let dir = tmpdir("zerodim");
        assert!(export_store(&m, &v, &dir, 2).is_err());
    }

    #[test]
    fn manifest_validation_rejects_gaps() {
        let bad = StoreManifest {
            vocab_size: 10,
            dim: 4,
            shards: vec![
                ShardMeta { start_row: 0, rows: 4 },
                ShardMeta { start_row: 5, rows: 5 },
            ],
            ivf: None,
            sidecar: false,
        };
        assert!(bad.validate().is_err());
        let short = StoreManifest {
            vocab_size: 10,
            dim: 4,
            shards: vec![ShardMeta { start_row: 0, rows: 9 }],
            ivf: None,
            sidecar: false,
        };
        assert!(short.validate().is_err());
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = StoreManifest {
            vocab_size: 12,
            dim: 6,
            shards: vec![
                ShardMeta { start_row: 0, rows: 6 },
                ShardMeta { start_row: 6, rows: 6 },
            ],
            ivf: None,
            sidecar: false,
        };
        let j = m.to_json().to_string();
        assert!(j.contains("\"format\":1"), "flat store must stay format 1");
        let back = StoreManifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn v2_manifest_roundtrips_and_format_fields_agree() {
        let m = StoreManifest {
            vocab_size: 4,
            dim: 2,
            shards: vec![ShardMeta { start_row: 0, rows: 4 }],
            ivf: Some(IvfMeta::new(
                vec![
                    ivf::ClusterRange { start_row: 0, rows: 3 },
                    ivf::ClusterRange { start_row: 3, rows: 1 },
                ],
                vec![1.0, 0.0, 0.0, 1.0],
                vec![2, 0, 3, 1].into(),
            )),
            sidecar: false,
        };
        let j = m.to_json().to_string();
        assert!(j.contains("\"format\":2"));
        let back = StoreManifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
        // a format-2 manifest with its index stripped must not parse
        let stripped = j.replacen("\"format\":2", "\"format\":1", 1);
        assert!(StoreManifest::from_json(&Json::parse(&stripped).unwrap())
            .is_err());
        let mut flat = m.clone();
        flat.ivf = None;
        let noivf = flat.to_json().to_string().replacen(
            "\"format\":1",
            "\"format\":2",
            1,
        );
        assert!(
            StoreManifest::from_json(&Json::parse(&noivf).unwrap()).is_err()
        );
    }

    #[test]
    fn locate_row_handles_irregular_and_empty_shards() {
        // irregular but contiguous: 1 + 8 + 0 + 1 rows
        let m = StoreManifest {
            vocab_size: 10,
            dim: 4,
            shards: vec![
                ShardMeta { start_row: 0, rows: 1 },
                ShardMeta { start_row: 1, rows: 8 },
                ShardMeta { start_row: 9, rows: 0 },
                ShardMeta { start_row: 9, rows: 1 },
            ],
            ivf: None,
            sidecar: false,
        };
        m.validate().unwrap();
        // the uniform-layout hint is wrong for every shard here; the
        // adjustment loops must still land on the right one
        for hint in [1usize, 2, 3, 10, usize::MAX] {
            assert_eq!(m.locate_row(0, hint), Some((0, 0)));
            assert_eq!(m.locate_row(1, hint), Some((1, 0)));
            assert_eq!(m.locate_row(8, hint), Some((1, 7)));
            // row 9 skips the empty shard 2
            assert_eq!(m.locate_row(9, hint), Some((3, 0)));
            assert_eq!(m.locate_row(10, hint), None);
        }
        // hint 0 must not divide by zero
        assert_eq!(m.locate_row(5, 0), Some((1, 4)));
    }

    #[test]
    fn row_block_rejects_wrapping_ranges() {
        let v = vocab(6);
        let m = EmbeddingModel::init(6, 4, 8);
        let dir = tmpdir("wrap");
        export_store(&m, &v, &dir, 2).unwrap();
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        let shard = store.shard(0).unwrap();
        // `start + n` wraps usize: must panic on the bound check (both
        // debug and release), not slip through to a slice error
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shard.row_block(1, usize::MAX)
        }));
        assert!(r.is_err(), "wrapping block range must not be handed out");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shard.row_block(2, shard.rows)
        }));
        assert!(r.is_err(), "out-of-range block must panic");
    }

    #[test]
    fn nonfinite_rows_zeroed_at_export() {
        let v = vocab(6);
        let mut m = EmbeddingModel::init(6, 4, 3);
        m.syn0_row_mut(2)[1] = f32::NAN;
        m.syn0_row_mut(4)[0] = f32::INFINITY;
        let dir = tmpdir("nanexport");
        export_store(&m, &v, &dir, 2).unwrap();
        for precision in [Precision::Exact, Precision::Quantized] {
            let store = ShardedStore::open(&dir, precision).unwrap();
            let mut out = vec![9.0f32; 4];
            store.fetch_row(2, &mut out).unwrap().unwrap();
            assert_eq!(out, vec![0.0; 4], "{} row 2", precision.name());
            store.fetch_row(4, &mut out).unwrap().unwrap();
            assert_eq!(out, vec![0.0; 4], "{} row 4", precision.name());
            // untouched rows survive
            store.fetch_row(0, &mut out).unwrap().unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
            assert!(out.iter().any(|&x| x != 0.0));
        }
    }

    #[test]
    fn nonfinite_shard_payload_rejected_at_load() {
        let v = vocab(6);
        let m = EmbeddingModel::init(6, 4, 5);
        let dir = tmpdir("nanload");
        export_store(&m, &v, &dir, 1).unwrap();
        // poison one f32 just past the 32-byte header: headers and file
        // size stay valid, so open succeeds and the load must catch it
        let p = dir.join("shard_000.f32");
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[32..36].copy_from_slice(&f32::NAN.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        let err = match store.shard(0) {
            Ok(_) => panic!("NaN payload must not load"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("non-finite"), "unexpected error: {err}");
    }

    #[test]
    fn truncated_or_mismatched_shard_rejected_at_open() {
        let v = vocab(8);
        let m = EmbeddingModel::init(8, 4, 6);
        let dir = tmpdir("truncated");
        export_store(&m, &v, &dir, 2).unwrap();
        let p = dir.join("shard_001.f32");
        let bytes = std::fs::read(&p).unwrap();
        // truncated payload fails at open, not mid-query
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = match ShardedStore::open(&dir, Precision::Exact) {
            Ok(_) => panic!("truncated shard must fail open"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("truncated"), "unexpected error: {err}");
        // the untouched precision still opens
        ShardedStore::open(&dir, Precision::Quantized).unwrap();
        // header dim tampered (bytes 24..32): manifest disagreement
        let mut tampered = bytes.clone();
        tampered[24..32].copy_from_slice(&5u64.to_le_bytes());
        std::fs::write(&p, &tampered).unwrap();
        assert!(ShardedStore::open(&dir, Precision::Exact).is_err());
        // restored bytes open again
        std::fs::write(&p, &bytes).unwrap();
        ShardedStore::open(&dir, Precision::Exact).unwrap();
    }

    #[test]
    fn clustered_export_roundtrips_through_permutation() {
        let v = vocab(12);
        let m = EmbeddingModel::init(12, 8, 21);
        let dir = tmpdir("clustered");
        let manifest = export_store_clustered(&m, &v, &dir, 3, 4).unwrap();
        let ivf = manifest.ivf.as_ref().expect("clustered export has index");
        assert_eq!(ivf.row_ids.len(), 12);
        assert_eq!(ivf.centroids.len(), ivf.num_clusters() * 8);
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        assert!(store.ivf().is_some());
        // fetch_row(id) must return id's row despite the reordering
        let normalized = m.normalized_rows();
        let mut out = vec![0.0f32; 8];
        for id in 0..12u32 {
            store.fetch_row(id, &mut out).unwrap().unwrap();
            assert_eq!(
                &out,
                &normalized[id as usize * 8..(id as usize + 1) * 8],
                "row {id} lost through the cluster permutation"
            );
        }
        // shards report original ids through the permutation
        for si in 0..store.num_shards() {
            let shard = store.shard(si).unwrap();
            for local in 0..shard.rows {
                let id = shard.id_of(local);
                assert_eq!(
                    ivf.row_ids[shard.start_row + local],
                    id,
                    "shard {si} local {local}"
                );
            }
        }
    }

    #[test]
    fn locate_hits_shard_boundaries() {
        let v = vocab(10);
        let m = EmbeddingModel::init(10, 4, 1);
        let dir = tmpdir("locate");
        export_store(&m, &v, &dir, 4).unwrap(); // 3+3+3+1
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        assert_eq!(store.locate(0), Some((0, 0)));
        assert_eq!(store.locate(2), Some((0, 2)));
        assert_eq!(store.locate(3), Some((1, 0)));
        assert_eq!(store.locate(9), Some((3, 0)));
        assert_eq!(store.locate(10), None);
    }

    #[test]
    fn single_shard_store() {
        let v = vocab(5);
        let m = EmbeddingModel::init(5, 4, 2);
        let dir = tmpdir("single");
        let manifest = export_store(&m, &v, &dir, 1).unwrap();
        assert_eq!(manifest.shards.len(), 1);
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        assert_eq!(store.num_shards(), 1);
        let mut out = vec![0.0; 4];
        store.fetch_row(4, &mut out).unwrap().unwrap();
    }

    #[test]
    fn quantize_single_element_rows() {
        let (scale, q) = quantize_row(&[3.5]);
        assert_eq!(q.len(), 1);
        let mut back = [0.0f32];
        dequantize_into(scale, &q, &mut back);
        assert!(back[0].is_finite());
        assert!((back[0] - 3.5).abs() <= scale * 0.5 + 1e-6);

        let (s0, q0) = quantize_row(&[0.0]);
        assert_eq!(s0, 0.0);
        assert_eq!(q0, vec![0]);
        let mut z = [9.9f32];
        dequantize_into(s0, &q0, &mut z);
        assert_eq!(z, [0.0]);
    }

    #[test]
    fn quantize_extreme_magnitudes_roundtrip_finite() {
        // large (near-overflow) and subnormal-scale rows must both
        // round-trip to finite values with the usual error bound
        for mag in [1e37f32, 1e-38, 1e-42] {
            let row = [mag, -mag, mag * 0.5, 0.0];
            let (scale, q) = quantize_row(&row);
            assert!(scale.is_finite() && scale >= 0.0, "mag={mag}");
            let mut back = [0.0f32; 4];
            dequantize_into(scale, &q, &mut back);
            for (x, y) in row.iter().zip(&back) {
                assert!(y.is_finite(), "mag={mag}: {y} not finite");
                // a full quantum, not the usual half: at subnormal
                // scales the rounding of `scale` itself can cost up to
                // another half-quantum through the clamp
                assert!(
                    (x - y).abs() <= scale + mag.abs() * 1e-6,
                    "mag={mag}: err {}",
                    (x - y).abs()
                );
            }
        }
    }

    /// The fused int8 dot must agree with dequantize-then-dot: the
    /// quantized scan path never materializes f32 rows, so this is the
    /// agreement the engine's quantized answers rest on.
    #[test]
    fn fused_i8_dot_agrees_with_dequantized_dot() {
        let row: Vec<f32> =
            (0..37).map(|i| ((i as f32) * 0.61).cos() * 1.3).collect();
        let query: Vec<f32> =
            (0..37).map(|i| ((i as f32) * 0.23).sin()).collect();
        let (scale, q) = quantize_row(&row);
        let mut deq = vec![0.0f32; row.len()];
        dequantize_into(scale, &q, &mut deq);
        let want = vecops::dot(&deq, &query);
        let got = vecops::dot_i8(&q, scale, &query);
        assert!(
            (got - want).abs() <= want.abs() * 1e-5 + 1e-5,
            "fused {got} vs dequantized {want}"
        );
    }

    #[test]
    fn row_block_views_match_row_into() {
        let v = vocab(9);
        let m = EmbeddingModel::init(9, 8, 4);
        let dir = tmpdir("rowblock");
        export_store(&m, &v, &dir, 2).unwrap();
        for precision in [Precision::Exact, Precision::Quantized] {
            let store = ShardedStore::open(&dir, precision).unwrap();
            let shard = store.shard(0).unwrap();
            let mut want = vec![0.0f32; shard.dim];
            // a 2-row window into the middle of the shard
            match shard.row_block(1, 2) {
                RowBlock::F32(rows) => {
                    assert_eq!(rows.len(), 2 * shard.dim);
                    shard.row_into(1, &mut want);
                    assert_eq!(&rows[..shard.dim], &want[..]);
                    shard.row_into(2, &mut want);
                    assert_eq!(&rows[shard.dim..], &want[..]);
                }
                RowBlock::I8 { scales, codes } => {
                    assert_eq!(scales.len(), 2);
                    assert_eq!(codes.len(), 2 * shard.dim);
                    let mut got = vec![0.0f32; shard.dim];
                    shard.row_into(1, &mut want);
                    dequantize_into(scales[0], &codes[..shard.dim], &mut got);
                    assert_eq!(got, want);
                }
            }
        }
    }

    #[test]
    fn v3_sidecar_export_opens_without_manifest_index() {
        let v = vocab(14);
        let m = EmbeddingModel::init(14, 8, 31);
        let dir = tmpdir("v3");
        let manifest = export_store_clustered(&m, &v, &dir, 3, 4).unwrap();
        assert!(manifest.sidecar, "clustered export defaults to v3");
        // the manifest stays O(shards): no index payload in the JSON
        let text = std::fs::read_to_string(dir.join("store.json")).unwrap();
        assert!(text.contains("\"format\":3"), "manifest: {text}");
        assert!(!text.contains("row_ids"), "permutation leaked into JSON");
        assert!(!text.contains("centroids"), "centroids leaked into JSON");
        assert!(dir.join(SIDECAR_FILE).exists());
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        let ivf = store.ivf().expect("sidecar stitched in at open");
        assert_eq!(ivf.row_ids.len(), 14);
        assert_eq!(ivf.centroid_codes.len(), ivf.num_clusters() * 8);
        // rows still resolve by original id through the permutation
        let normalized = m.normalized_rows();
        let mut out = vec![0.0f32; 8];
        for id in 0..14u32 {
            store.fetch_row(id, &mut out).unwrap().unwrap();
            assert_eq!(
                &out,
                &normalized[id as usize * 8..(id as usize + 1) * 8]
            );
        }
    }

    #[test]
    fn v2_and_v3_exports_carry_identical_indexes() {
        let v = vocab(11);
        let m = EmbeddingModel::init(11, 8, 17);
        let d2 = tmpdir("fmt_v2");
        let d3 = tmpdir("fmt_v3");
        let m2 = export_store_clustered_as(
            &m,
            &v,
            &d2,
            2,
            3,
            StoreFormat::V2Manifest,
        )
        .unwrap();
        let m3 = export_store_clustered_as(
            &m,
            &v,
            &d3,
            2,
            3,
            StoreFormat::V3Sidecar,
        )
        .unwrap();
        assert!(!m2.sidecar);
        assert!(
            std::fs::read_to_string(d2.join("store.json"))
                .unwrap()
                .contains("\"format\":2")
        );
        assert_eq!(m2.ivf, m3.ivf, "index must not depend on the format");
        for precision in [Precision::Exact, Precision::Quantized] {
            let s2 = ShardedStore::open(&d2, precision).unwrap();
            let s3 = ShardedStore::open(&d3, precision).unwrap();
            assert_eq!(s2.ivf(), s3.ivf(), "{}", precision.name());
            let mut a = vec![0.0f32; 8];
            let mut b = vec![0.0f32; 8];
            for id in 0..11u32 {
                s2.fetch_row(id, &mut a).unwrap().unwrap();
                s3.fetch_row(id, &mut b).unwrap().unwrap();
                assert_eq!(a, b, "{} id {id}", precision.name());
            }
        }
    }

    #[test]
    fn sidecar_corruption_fails_open_fast() {
        let v = vocab(10);
        let m = EmbeddingModel::init(10, 4, 13);
        let dir = tmpdir("sidecar_corrupt");
        export_store_clustered(&m, &v, &dir, 2, 3).unwrap();
        let p = dir.join(SIDECAR_FILE);
        let bytes = std::fs::read(&p).unwrap();
        // truncated sidecar
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        let err = match ShardedStore::open(&dir, Precision::Exact) {
            Ok(_) => panic!("truncated sidecar must fail open"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("truncated"), "unexpected error: {err}");
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        std::fs::write(&p, &bad).unwrap();
        let err = match ShardedStore::open(&dir, Precision::Exact) {
            Ok(_) => panic!("bad magic must fail open"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("bad sidecar magic"), "unexpected: {err}");
        // header vocab disagrees with the manifest (bytes 24..32)
        let mut tampered = bytes.clone();
        tampered[24..32].copy_from_slice(&99u64.to_le_bytes());
        std::fs::write(&p, &tampered).unwrap();
        let err = match ShardedStore::open(&dir, Precision::Exact) {
            Ok(_) => panic!("header mismatch must fail open"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("disagrees"), "unexpected: {err}");
        // missing sidecar
        std::fs::remove_file(&p).unwrap();
        assert!(ShardedStore::open(&dir, Precision::Exact).is_err());
        // restored bytes open again
        std::fs::write(&p, &bytes).unwrap();
        ShardedStore::open(&dir, Precision::Exact).unwrap();
    }

    #[test]
    fn shard_load_accounts_exactly_one_byte_tier() {
        let v = vocab(9);
        let m = EmbeddingModel::init(9, 8, 25);
        let dir = tmpdir("byte_tiers");
        export_store(&m, &v, &dir, 2).unwrap();
        let store = ShardedStore::open(&dir, Precision::Exact).unwrap();
        assert_eq!(store.bytes_mapped() + store.bytes_heap_loaded(), 0);
        let shard = store.shard(0).unwrap();
        if mmapfile::enabled() {
            assert!(shard.is_mapped(), "linux/LE shards should map");
            assert!(store.bytes_mapped() > 0);
            assert_eq!(store.bytes_heap_loaded(), 0);
            assert!(store.row_is_mapped(0));
        } else {
            assert!(!shard.is_mapped());
            assert!(store.bytes_heap_loaded() > 0);
            assert_eq!(store.bytes_mapped(), 0);
            assert!(!store.row_is_mapped(0));
        }
        // untouched shard: nothing accounted, nothing mapped
        assert!(!store.row_is_mapped(8));
    }
}
