//! fullw2v — leader binary: CLI over the FULL-W2V training system.

use anyhow::{anyhow, Context, Result};
use fullw2v::cli::{self, Cli, Command};
use fullw2v::config::Config;
use fullw2v::coordinator::{train_all, SgnsTrainer};
use fullw2v::corpus::reader::{read_all, ReaderOptions};
use fullw2v::corpus::synthetic::SyntheticSpec;
use fullw2v::corpus::vocab::Vocab;
use fullw2v::eval::similarity::spearman;
use fullw2v::model::EmbeddingModel;
use fullw2v::util::log;
use fullw2v::workbench::Workbench;
use std::path::Path;
use std::sync::Arc;

fn main() {
    log::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn spec_by_name(name: &str) -> Result<SyntheticSpec> {
    Ok(match name {
        "tiny" => SyntheticSpec::tiny(),
        "text8" | "text8-mini" => SyntheticSpec::text8_mini(),
        "1bw" | "1bw-mini" => SyntheticSpec::obw_mini(),
        other => return Err(anyhow!("unknown synthetic spec '{other}'")),
    })
}

fn run(cli: Cli) -> Result<()> {
    // visible under -v: which vecops kernel table this process runs
    log::log(
        log::Level::Debug,
        format_args!(
            "simd: {} (source: {})",
            cli.simd.level, cli.simd.source
        ),
    );
    match cli.command {
        Command::Help => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        Command::Version => {
            println!("fullw2v {}", fullw2v::version());
            Ok(())
        }
        Command::Manifest => {
            let dir = fullw2v::workbench::default_artifacts_dir();
            let m = fullw2v::runtime::Manifest::load(Path::new(&dir))?;
            println!("artifacts in {dir}:");
            for e in &m.executables {
                println!(
                    "  {:36} variant={:13} B={} S={} d={} N={} Wf={}",
                    e.name, e.variant, e.b, e.s, e.d, e.n, e.wf
                );
            }
            Ok(())
        }
        Command::GpuSim => {
            let w = fullw2v::memmodel::Workload::text8_paper();
            for p in fullw2v::gpusim::project_all(&w) {
                println!(
                    "{:8} {:14} {:>8.1} Mwords/s  ipc {:.2}  occupancy {:.0}%",
                    p.arch,
                    p.variant.name(),
                    p.sim.words_per_sec / 1e6,
                    p.sim.ipc,
                    100.0 * p.occupancy.occupancy_frac
                );
            }
            println!(
                "(full tables: cargo run --release --example gpusim_report)"
            );
            // The CPU side of the same Figure 1 argument: measure the
            // vecops kernels on this host and judge them against the
            // roofline at the active SIMD level.
            use fullw2v::memmodel::cpu;
            let spec = cpu::CpuSpec::detect();
            let level = cli.simd.level;
            println!(
                "\ncpu roofline ({}, simd {} via {}, {:.1} GHz {}, \
                 {:.1} GB/s {}):",
                std::env::consts::ARCH,
                level,
                cli.simd.source,
                spec.clock_ghz,
                spec.clock_source,
                spec.mem_bw_gbs,
                spec.bw_source,
            );
            let measures = cpu::measure_kernels(
                &spec,
                level,
                cpu::DEFAULT_ROWS,
                cpu::DEFAULT_DIM,
            )
            .map_err(anyhow::Error::msg)?;
            for m in &measures {
                println!(
                    "  {:8} AI {:>5.2}  {:>7.2} GF/s  ceiling {:>7.2}  \
                     achieved {:>5.1}%",
                    m.kernel,
                    m.ai,
                    m.gflops,
                    m.ceiling_gflops,
                    100.0 * m.achieved_frac
                );
            }
            Ok(())
        }
        Command::GenCorpus { spec, out } => {
            let spec = spec_by_name(&spec)?;
            let corpus =
                fullw2v::corpus::synthetic::SyntheticCorpus::generate(spec);
            std::fs::create_dir_all(&out)?;
            let dir = Path::new(&out);
            std::fs::write(dir.join("corpus.txt"), corpus.to_text())?;
            let mut pairs = String::new();
            for p in corpus.gold_similarity_pairs(500, 7) {
                pairs.push_str(&format!(
                    "{}\t{}\t{:.6}\n",
                    p.a, p.b, p.score
                ));
            }
            std::fs::write(dir.join("gold_pairs.tsv"), pairs)?;
            let mut ana = String::new();
            for g in corpus.gold_analogies(300, 7) {
                ana.push_str(&format!("{} {} {} {}\n", g.a, g.b, g.c, g.d));
            }
            std::fs::write(dir.join("gold_analogies.txt"), ana)?;
            println!(
                "wrote corpus.txt, gold_pairs.tsv, gold_analogies.txt to {out}"
            );
            Ok(())
        }
        Command::Train {
            corpus,
            synthetic,
            implementation,
            threads,
            out,
            store,
            shards,
            clusters,
        } => train_cmd(
            cli.config,
            corpus,
            synthetic,
            implementation,
            threads,
            out,
            store,
            shards,
            clusters,
        ),
        Command::Eval { model, pairs } => eval_cmd(&model, &pairs),
        Command::Nn { model, store, word, k, quantized, nprobe } => {
            match store {
                Some(dir) => nn_store_cmd(&dir, &word, k, quantized, nprobe),
                None => {
                    nn_cmd(&model.expect("cli enforces one source"), &word, k)
                }
            }
        }
        Command::ExportStore { model, out, shards, clusters, format } => {
            export_store_cmd(&model, &out, shards, clusters, format)
        }
        Command::Lint { json, root } => lint_cmd(json, root),
        Command::BenchDiff { old, new, fail_on } => {
            benchdiff_cmd(&old, &new, &fail_on)
        }
        Command::Serve { store, queries, listen, k, quantized, batch, nprobe } => {
            match (queries, listen) {
                (Some(queries), _) => {
                    serve_cmd(&store, &queries, k, quantized, batch, nprobe)
                }
                (None, Some(listen)) => serve_net_cmd(
                    &cli.config,
                    &store,
                    &listen,
                    k,
                    quantized,
                    batch,
                    nprobe,
                ),
                (None, None) => unreachable!("cli enforces one serve mode"),
            }
        }
    }
}

/// `fullw2v lint [--json] [--root DIR]`: run the repo-invariant lints
/// and exit non-zero on findings (the CI/test gate, callable ad hoc).
fn lint_cmd(json: bool, root: Option<String>) -> Result<()> {
    let root = root.unwrap_or_else(|| env!("CARGO_MANIFEST_DIR").to_string());
    let report = fullw2v::analysis::run(Path::new(&root))
        .map_err(anyhow::Error::msg)?;
    if json {
        println!("{}", fullw2v::analysis::render_json(&report));
    } else {
        print!("{}", fullw2v::analysis::render_text(&report));
    }
    if !report.clean() {
        return Err(anyhow!(
            "{} lint finding(s) — see above",
            report.findings.len()
        ));
    }
    Ok(())
}

/// `fullw2v benchdiff OLD.json NEW.json [--fail-on PATTERN=PCT]...`:
/// gate a bench artifact against a baseline; non-zero exit past
/// tolerance on any pinned perf series (the CI perf-trajectory gate).
fn benchdiff_cmd(old: &str, new: &str, fail_on: &[String]) -> Result<()> {
    let (report, regressed) = fullw2v::obs::artifact::benchdiff(
        Path::new(old),
        Path::new(new),
        fail_on,
    )
    .map_err(anyhow::Error::msg)?;
    print!("{report}");
    if regressed {
        return Err(anyhow!("bench artifact regressed — see above"));
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn train_cmd(
    mut cfg: Config,
    corpus: Option<String>,
    synthetic: Option<String>,
    implementation: Option<String>,
    threads: Option<usize>,
    out: Option<String>,
    store: Option<String>,
    shards: usize,
    clusters: usize,
) -> Result<()> {
    if let Some(t) = threads {
        cfg.train.threads = t;
    }
    let epochs = cfg.train.epochs;
    // corpus preparation is implementation-independent
    let (vocab, sentences) = match (corpus, synthetic) {
        (Some(path), None) => {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading corpus {path}"))?;
            let vocab =
                Vocab::build(text.split_whitespace(), cfg.train.min_count);
            let opts = ReaderOptions {
                max_sentence_len: cfg.train.max_sentence_len,
                ignore_delimiters: cfg.train.ignore_delimiters,
                pack_len: cfg.train.max_sentence_len,
            };
            let (sents, raw) = read_all(text.as_bytes(), &vocab, opts);
            log::log(
                log::Level::Info,
                format_args!(
                    "corpus: {raw} raw tokens, vocab {}, {} sentences",
                    vocab.len(),
                    sents.len()
                ),
            );
            (vocab, Arc::new(sents))
        }
        (None, syn) => {
            let spec = spec_by_name(&syn.unwrap_or_else(|| "tiny".into()))?;
            let wb = Workbench::prepare(spec, cfg.train.min_count);
            (wb.vocab, wb.sentences)
        }
        (Some(_), Some(_)) => {
            return Err(anyhow!("--corpus and --synthetic are exclusive"))
        }
    };
    let total: u64 = sentences.iter().map(|s| s.len() as u64).sum();
    let mut trainer: Box<dyn SgnsTrainer> = match implementation.as_deref() {
        Some(name) if fullw2v::trainer::is_cpu_impl(name) => {
            // hint = one epoch's words; the constructor spans epochs
            fullw2v::trainer::build_cpu_trainer(
                name, &cfg.train, &vocab, total,
            )?
        }
        other => {
            // a PJRT kernel variant (possibly overridden via --impl)
            if let Some(variant) = other {
                cfg.train.variant = variant.to_string();
            }
            if cfg.artifacts_dir == "artifacts" {
                cfg.artifacts_dir =
                    fullw2v::workbench::default_artifacts_dir();
            }
            Box::new(fullw2v::coordinator::Coordinator::new(
                cfg.clone(),
                &vocab,
                total,
            )?)
        }
    };
    log::log(
        log::Level::Info,
        format_args!(
            "training {} for {epochs} epochs ({} threads)",
            trainer.name(),
            cfg.train.resolved_threads()
        ),
    );
    let report = train_all(trainer.as_mut(), &sentences, epochs)?;
    let model = trainer.model().clone();
    for e in &report.epochs {
        println!(
            "epoch {}: {:>9.0} words/s  loss/word {:.4}  batching {:>9.0} w/s",
            e.epoch, e.words_per_sec, e.loss_per_word, e.batching_rate
        );
    }
    println!("aggregate: {:.0} words/s", report.words_per_sec());
    if log::enabled(log::Level::Debug) {
        if let Some(e) = report.epochs.last() {
            if !e.stages.is_empty() {
                print!(
                    "{}",
                    e.stages
                        .render_table("stage breakdown (last epoch, all workers)")
                );
            }
        }
    }
    if let Some(path) = out {
        model.save_text(&vocab, Path::new(&path))?;
        println!("model written to {path} (word2vec text format)");
    }
    if let Some(dir) = store {
        let manifest = fullw2v::serve::export_store_clustered(
            &model,
            &vocab,
            Path::new(&dir),
            shards,
            clusters,
        )?;
        println!(
            "serving store written to {dir} ({} shards, f32 + int8{})",
            manifest.shards.len(),
            match &manifest.ivf {
                Some(ivf) => format!(", {} IVF clusters", ivf.num_clusters()),
                None => String::new(),
            }
        );
    }
    Ok(())
}

fn eval_cmd(model_path: &str, pairs_path: &str) -> Result<()> {
    let (words, model) = EmbeddingModel::load_text(Path::new(model_path))?;
    let index: std::collections::HashMap<&str, u32> = words
        .iter()
        .enumerate()
        .map(|(i, w)| (w.as_str(), i as u32))
        .collect();
    let text = std::fs::read_to_string(pairs_path)?;
    let mut model_scores = Vec::new();
    let mut gold_scores = Vec::new();
    let mut skipped = 0;
    for line in text.lines() {
        let mut it = line.split('\t');
        let (a, b, score) = match (it.next(), it.next(), it.next()) {
            (Some(a), Some(b), Some(s)) => (a, b, s),
            _ => continue,
        };
        match (index.get(a), index.get(b), score.parse::<f64>()) {
            (Some(&ia), Some(&ib), Ok(s)) => {
                model_scores.push(fullw2v::model::embeddings::cosine(
                    model.syn0_row(ia),
                    model.syn0_row(ib),
                ));
                gold_scores.push(s);
            }
            _ => skipped += 1,
        }
    }
    println!(
        "spearman {:.4} over {} pairs ({skipped} skipped)",
        spearman(&model_scores, &gold_scores),
        model_scores.len()
    );
    Ok(())
}

fn nn_cmd(model_path: &str, word: &str, k: usize) -> Result<()> {
    let (words, model) = EmbeddingModel::load_text(Path::new(model_path))?;
    let id = words
        .iter()
        .position(|w| w == word)
        .ok_or_else(|| anyhow!("word '{word}' not in model"))? as u32;
    for (nid, sim) in model.nearest(id, k) {
        println!("{:24} {:.4}", words[nid as usize], sim);
    }
    Ok(())
}

fn store_precision(quantized: bool) -> fullw2v::serve::Precision {
    if quantized {
        fullw2v::serve::Precision::Quantized
    } else {
        fullw2v::serve::Precision::Exact
    }
}

/// Load a store directory's vocab and check it matches the manifest, so
/// a stale/truncated vocab.tsv surfaces as an error instead of an
/// out-of-bounds panic when printing neighbor words.
fn load_store_vocab(
    dir: &Path,
    store: &fullw2v::serve::ShardedStore,
) -> Result<Vocab> {
    let vocab = Vocab::load(&dir.join("vocab.tsv"))?;
    if vocab.len() != store.vocab_size() {
        return Err(anyhow!(
            "vocab.tsv has {} words but the store manifest says {} — \
             stale or truncated store directory?",
            vocab.len(),
            store.vocab_size()
        ));
    }
    Ok(vocab)
}

fn nn_store_cmd(
    store_dir: &str,
    word: &str,
    k: usize,
    quantized: bool,
    nprobe: usize,
) -> Result<()> {
    use fullw2v::serve::{ServeEngine, ServeOptions, ShardedStore};
    let dir = Path::new(store_dir);
    // ad-hoc lookups pay the store-open cost every invocation, so it
    // must stay O(shards + clusters): a v3 store reads the binary
    // `ivf.bin` sidecar instead of parsing an O(vocab) JSON index
    let open_start = std::time::Instant::now();
    let store =
        Arc::new(ShardedStore::open(dir, store_precision(quantized))?);
    log::log(
        log::Level::Debug,
        format_args!(
            "store open: {:.2}ms ({} shards, {} clusters, {})",
            open_start.elapsed().as_secs_f64() * 1e3,
            store.num_shards(),
            store.ivf().map(|m| m.num_clusters()).unwrap_or(0),
            if store.manifest().sidecar { "v3 sidecar" } else { "manifest" },
        ),
    );
    let vocab = load_store_vocab(dir, &store)?;
    let id = vocab
        .id(word)
        .ok_or_else(|| anyhow!("word '{word}' not in store vocab"))?;
    // the same IVF plan `serve --nprobe` uses, so an ad-hoc lookup
    // returns exactly what the served path would
    let engine = ServeEngine::start(
        store,
        ServeOptions { nprobe, ..ServeOptions::default() },
    );
    let client = engine.client();
    let neighbors = client.query_id(id, k).map_err(anyhow::Error::msg)?;
    for n in &neighbors {
        println!("{:24} {:.4}", vocab.word(n.id), n.score);
    }
    drop(client);
    engine.shutdown();
    Ok(())
}

fn export_store_cmd(
    model_path: &str,
    out: &str,
    shards: usize,
    clusters: usize,
    format: fullw2v::serve::StoreFormat,
) -> Result<()> {
    let (words, model) = EmbeddingModel::load_text(Path::new(model_path))?;
    // text models carry no counts; synthesize strictly-descending counts
    // so store ids keep the model's row order (= frequency rank)
    let n = words.len() as u64;
    let vocab = Vocab::from_counts(
        words.into_iter().enumerate().map(|(i, w)| (w, n - i as u64)),
        1,
    );
    let manifest = fullw2v::serve::export_store_clustered_as(
        &model,
        &vocab,
        Path::new(out),
        shards,
        clusters,
        format,
    )?;
    println!(
        "store written to {out}: {} rows x {} dims in {} shards (f32 + int8{})",
        manifest.vocab_size,
        manifest.dim,
        manifest.shards.len(),
        match &manifest.ivf {
            Some(ivf) => format!(
                ", {} IVF clusters, format {}",
                ivf.num_clusters(),
                format.name()
            ),
            None => String::new(),
        }
    );
    Ok(())
}

fn serve_cmd(
    store_dir: &str,
    queries_path: &str,
    k: usize,
    quantized: bool,
    batch: usize,
    nprobe: usize,
) -> Result<()> {
    use fullw2v::serve::{ServeEngine, ServeOptions, ShardedStore};
    let dir = Path::new(store_dir);
    let store =
        Arc::new(ShardedStore::open(dir, store_precision(quantized))?);
    let vocab = load_store_vocab(dir, &store)?;
    let engine = ServeEngine::start(
        store,
        ServeOptions { batch_max: batch, nprobe, ..ServeOptions::default() },
    );
    let client = engine.client();

    let text = std::fs::read_to_string(queries_path)
        .with_context(|| format!("reading queries {queries_path}"))?;
    let words: Vec<&str> =
        text.lines().map(str::trim).filter(|w| !w.is_empty()).collect();
    // submit everything first so concurrent requests micro-batch
    let submitted: Vec<_> = words
        .iter()
        .map(|&w| match vocab.id(w) {
            Some(id) => Ok(client.submit_id(id, k)),
            None => Err(format!("word '{w}' not in store vocab")),
        })
        .collect();
    for (w, sub) in words.iter().zip(submitted) {
        match sub {
            Ok(rx) => match rx.recv() {
                Ok(Ok(neighbors)) => {
                    let line: Vec<String> = neighbors
                        .iter()
                        .map(|n| {
                            format!("{}:{:.3}", vocab.word(n.id), n.score)
                        })
                        .collect();
                    println!("{w:20} {}", line.join(" "));
                }
                Ok(Err(e)) => println!("{w:20} ERROR {e}"),
                Err(_) => println!("{w:20} ERROR engine stopped"),
            },
            Err(e) => println!("{w:20} ERROR {e}"),
        }
    }
    drop(client);
    let report = engine.shutdown();
    println!("\n{}", report.summary());
    if log::enabled(log::Level::Debug) && !report.stages.is_empty() {
        print!(
            "{}",
            report.stages.render_table("serve stage breakdown (all batches)")
        );
    }
    Ok(())
}

/// Network serving mode: run the HTTP front-end until a graceful drain
/// is requested (`POST /admin/shutdown`), then print the final report.
#[allow(clippy::too_many_arguments)]
fn serve_net_cmd(
    cfg: &Config,
    store_dir: &str,
    listen: &str,
    k: usize,
    quantized: bool,
    batch: usize,
    nprobe: usize,
) -> Result<()> {
    use fullw2v::net::{NetOptions, NetServer};
    use fullw2v::serve::{ServeEngine, ServeOptions, ShardedStore};
    use std::io::Write;
    let dir = Path::new(store_dir);
    let store =
        Arc::new(ShardedStore::open(dir, store_precision(quantized))?);
    let vocab = load_store_vocab(dir, &store)?;
    let engine = ServeEngine::start(
        store,
        ServeOptions { batch_max: batch, nprobe, ..ServeOptions::default() },
    );
    let server = NetServer::start(
        engine,
        Some(vocab),
        listen,
        NetOptions {
            max_inflight: cfg.serve.max_inflight,
            default_k: k,
            ..NetOptions::default()
        },
    )?;
    println!("fullw2v serving on http://{}", server.local_addr());
    println!(
        "routes: POST /v1/nn /v1/embed | GET /healthz /stats /metrics \
         /debug/traces | POST /admin/shutdown (drain)"
    );
    // smoke scripts grep the port from redirected stdout: flush past
    // the pipe's block buffering before parking in join()
    std::io::stdout().flush()?;
    let report = server.join();
    println!("{}", report.summary());
    Ok(())
}
