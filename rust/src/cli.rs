//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! Subcommands:
//!   train        train a model on a corpus file or synthetic spec
//!   eval         evaluate a saved model (similarity vs gold file)
//!   nn           nearest neighbors of a word (saved model or store)
//!   export-store shard a saved model into a serving store directory
//!   serve        answer a batch of top-k queries from a store
//!   gen-corpus   write a synthetic corpus (+ gold sets) to disk
//!   gpusim       print the analytical Tables 4/5/6 + projections
//!   manifest     list AOT executables
//!   lint         run the repo-invariant lints (analysis/) over sources
//!   benchdiff    gate one BENCH_*.json artifact against a baseline
//!
//! Global flags: -c/--config FILE, -s/--set section.key=value (repeat),
//! -v/--verbose, -q/--quiet, --simd auto|scalar|avx2|avx512|neon.

use crate::config::Config;
use crate::serve::StoreFormat;
use crate::util::log::{self, Level};
use crate::vecops::SimdSelection;
use anyhow::{anyhow, bail, Result};

/// Parsed invocation.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: Command,
    pub config: Config,
    /// The SIMD level the process runs at, resolved at parse time
    /// (`--simd` > `FULLW2V_SIMD` > auto-detect) so every command gets
    /// the fast vecops paths with no further wiring.
    pub simd: SimdSelection,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Train {
        corpus: Option<String>,
        synthetic: Option<String>,
        /// Trainer implementation: a CPU trainer (mikolov | pword2vec |
        /// psgnscc | fullw2v) or a PJRT kernel variant.  None = the
        /// config's PJRT variant, as before.
        implementation: Option<String>,
        /// Hogwild worker threads (overrides `train.threads`; 0 = auto).
        threads: Option<usize>,
        out: Option<String>,
        /// Export a sharded serving store here after training.
        store: Option<String>,
        shards: usize,
        /// IVF clusters for the exported store (0 = flat v1 store).
        clusters: usize,
    },
    Eval {
        model: String,
        pairs: String,
    },
    Nn {
        model: Option<String>,
        store: Option<String>,
        word: String,
        k: usize,
        quantized: bool,
        /// IVF probe width for --store lookups (0 = exhaustive), the
        /// same plan `serve` uses — ad-hoc answers match served ones.
        nprobe: usize,
    },
    ExportStore {
        model: String,
        out: String,
        shards: usize,
        /// IVF clusters to train at export (0 = flat v1 store).
        clusters: usize,
        /// On-disk layout for clustered exports: v3 (binary `ivf.bin`
        /// sidecar, the default) or v2 (legacy JSON-embedded index).
        format: StoreFormat,
    },
    Serve {
        store: String,
        /// File mode: answer a queries file and exit.
        queries: Option<String>,
        /// Network mode: run the HTTP front-end on this address
        /// (`--listen`, falling back to `serve.listen` in the config).
        listen: Option<String>,
        k: usize,
        quantized: bool,
        /// Max queries folded into one micro-batch (scan-reuse factor).
        batch: usize,
        /// IVF probe width (0 = exact exhaustive scan).
        nprobe: usize,
    },
    GenCorpus {
        spec: String,
        out: String,
    },
    GpuSim,
    Manifest,
    /// Run the `analysis/` repo-invariant lints and exit non-zero on
    /// findings (the same suite `rust/tests/lint_repo.rs` self-hosts).
    Lint {
        /// Render findings as JSON instead of text.
        json: bool,
        /// Repo root to lint (default: the compiled-in manifest dir).
        root: Option<String>,
    },
    /// Compare two bench artifacts under the pinned perf rules
    /// (obs/artifact.rs) and exit non-zero on a regression.
    BenchDiff {
        old: String,
        new: String,
        /// Extra `PATTERN=PCT` gates (repeatable `--fail-on`).
        fail_on: Vec<String>,
    },
    Help,
    Version,
}

pub const USAGE: &str = "\
fullw2v — FULL-W2V reproduction (Rust + JAX + Pallas, AOT via PJRT)

USAGE:
  fullw2v [FLAGS] <COMMAND> [ARGS]

COMMANDS:
  train [--corpus FILE | --synthetic tiny|text8|1bw]
        [--impl mikolov|pword2vec|psgnscc|fullw2v|<pjrt-variant>]
        [--threads T] [--out MODEL]
        [--store DIR [--shards N] [--clusters C]]
  eval --model MODEL.txt --pairs PAIRS.tsv
  nn (--model MODEL.txt | --store DIR [--quantized] [--nprobe P])
     --word WORD [--k K]
  export-store --model MODEL.txt --out DIR [--shards N] [--clusters C]
               [--format v3|v2]
        clustered exports write the IVF index to the binary ivf.bin
        sidecar by default (format v3: open cost is O(shards+clusters));
        --format v2 keeps the legacy JSON-embedded index
  serve --store DIR (--queries FILE | --listen ADDR)
        [--k K] [--quantized] [--batch N] [--nprobe P]
        file mode answers a queries file and exits; --listen (or
        serve.listen in the config) runs the HTTP front-end:
        POST /v1/nn /v1/embed, GET /healthz /stats /metrics,
        POST /admin/shutdown drains (503s shed; serve.max_inflight)
        GET /metrics is Prometheus text: fullw2v_http_* request
        counters + admission gauges, fullw2v_serve_* engine counters,
        a stage_seconds_total latency decomposition, and
        _bucket/_sum/_count histogram series
  gen-corpus --spec tiny|text8|1bw --out DIR
  gpusim
  manifest
  lint [--json] [--root DIR]
        run the five repo-invariant lints (unsafe-audit, kernel-purity,
        simd-contract, panic-path, ordering-annotation) over the repo's
        sources; exits 1 if anything fires.  --root overrides the repo
        checkout to lint (default: this build's source tree)
  benchdiff OLD.json NEW.json [--fail-on PATTERN=PCT]...
        compare two BENCH_*.json artifacts (schema 1) and exit 1 if a
        pinned perf series regressed past tolerance: rows loaded /
        advanced and latency quantiles may not grow, reuse ratios and
        the roofline fraction may not shrink, stage shares may not
        drift.  --fail-on adds a gate on |relative change| for every
        dotted series path matching PATTERN (subset regex: ^ $ . *)
  help | version

FLAGS:
  -c, --config FILE          TOML config file
  -s, --set section.key=val  config override (repeatable)
  -v, --verbose              debug logging (adds per-stage time tables
                             to train / serve --queries reports, and
                             logs the selected SIMD level)
  -q, --quiet                errors only
  --simd LEVEL               auto|scalar|avx2|avx512|neon — force the
                             vecops kernel level (default: auto-detect;
                             unavailable levels are a hard error; every
                             level is bit-identical to scalar)

ENVIRONMENT:
  FULLW2V_LOG         error|warn|info|debug|trace (same as -v/-q)
  FULLW2V_LOG_FORMAT  text|json — json emits one JSON object per log
                      line (request logs carry req_id)
  FULLW2V_SIMD        same values as --simd (the flag wins)

Benches accept --artifact PATH to persist a BENCH_*.json snapshot
(schema 1: git_rev, config, table rows, stage breakdowns, latency
quantiles — see rust/src/obs/artifact.rs).
";

/// Parse argv (excluding argv[0]).
pub fn parse(args: &[String]) -> Result<Cli> {
    let mut config = Config::new();
    let mut positional: Vec<String> = Vec::new();
    let mut opts: Vec<(String, String)> = Vec::new();
    let mut config_file: Option<String> = None;
    let mut overrides: Vec<String> = Vec::new();

    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let take_value = |i: &mut usize| -> Result<String> {
            *i += 1;
            args.get(*i)
                .cloned()
                .ok_or_else(|| anyhow!("flag {a} needs a value"))
        };
        match a.as_str() {
            "-c" | "--config" => config_file = Some(take_value(&mut i)?),
            "-s" | "--set" => overrides.push(take_value(&mut i)?),
            "-v" | "--verbose" => log::set_level(Level::Debug),
            "-q" | "--quiet" => log::set_level(Level::Error),
            "--corpus" | "--synthetic" | "--out" | "--model" | "--pairs"
            | "--word" | "--k" | "--spec" | "--store" | "--queries"
            | "--shards" | "--batch" | "--clusters" | "--nprobe"
            | "--impl" | "--threads" | "--listen" | "--simd" | "--root"
            | "--format" | "--fail-on" => {
                let key = a.trim_start_matches('-').to_string();
                opts.push((key, take_value(&mut i)?));
            }
            "--quantized" => {
                opts.push(("quantized".to_string(), "true".to_string()));
            }
            "--json" => {
                opts.push(("json".to_string(), "true".to_string()));
            }
            _ if a.starts_with('-') => bail!("unknown flag '{a}'\n{USAGE}"),
            _ => positional.push(a.clone()),
        }
        i += 1;
    }

    if let Some(path) = config_file {
        config = Config::from_file(std::path::Path::new(&path))
            .map_err(anyhow::Error::msg)?;
    }
    for ov in &overrides {
        config.apply_override(ov).map_err(anyhow::Error::msg)?;
    }

    let get = |key: &str| -> Option<String> {
        opts.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone())
    };
    // repeatable flags keep every occurrence, in order
    let get_all = |key: &str| -> Vec<String> {
        opts.iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.clone())
            .collect()
    };
    let cmd = positional.first().map(String::as_str).unwrap_or("help");
    // numeric flags bail on garbage instead of silently using defaults
    let int_flag = |key: &str, default: usize| -> Result<usize> {
        match get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} needs an integer, got '{v}'")),
        }
    };
    // optional numeric flags: absent = None, garbage still bails
    let opt_int_flag = |key: &str| -> Result<Option<usize>> {
        match get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("--{key} needs an integer, got '{v}'")),
        }
    };
    let command = match cmd {
        "train" => Command::Train {
            corpus: get("corpus"),
            synthetic: get("synthetic"),
            implementation: get("impl"),
            threads: opt_int_flag("threads")?,
            out: get("out"),
            store: get("store"),
            shards: int_flag("shards", 4)?,
            clusters: int_flag("clusters", 0)?,
        },
        "eval" => Command::Eval {
            model: get("model").ok_or_else(|| anyhow!("eval needs --model"))?,
            pairs: get("pairs").ok_or_else(|| anyhow!("eval needs --pairs"))?,
        },
        "nn" => {
            let model = get("model");
            let store = get("store");
            if model.is_none() && store.is_none() {
                bail!("nn needs --model or --store");
            }
            if model.is_some() && store.is_some() {
                bail!("nn takes --model or --store, not both");
            }
            if model.is_some() && get("quantized").is_some() {
                bail!("--quantized only applies to --store");
            }
            if model.is_some() && get("nprobe").is_some() {
                bail!("--nprobe only applies to --store");
            }
            Command::Nn {
                model,
                store,
                word: get("word")
                    .ok_or_else(|| anyhow!("nn needs --word"))?,
                k: int_flag("k", crate::serve::DEFAULT_TOP_K)?,
                quantized: get("quantized").is_some(),
                nprobe: int_flag("nprobe", 0)?,
            }
        }
        "export-store" => Command::ExportStore {
            model: get("model")
                .ok_or_else(|| anyhow!("export-store needs --model"))?,
            out: get("out")
                .ok_or_else(|| anyhow!("export-store needs --out"))?,
            shards: int_flag("shards", 4)?,
            clusters: int_flag("clusters", 0)?,
            format: match get("format").as_deref() {
                None | Some("v3") => StoreFormat::V3Sidecar,
                Some("v2") => StoreFormat::V2Manifest,
                Some(v) => {
                    bail!("--format must be v3 or v2, got '{v}'")
                }
            },
        },
        "serve" => {
            let queries = get("queries");
            // --listen wins; with neither flag the config's serve.listen
            // (if set) selects network mode
            let listen = get("listen").or_else(|| {
                if queries.is_none() && !config.serve.listen.is_empty() {
                    Some(config.serve.listen.clone())
                } else {
                    None
                }
            });
            if queries.is_some() && listen.is_some() {
                bail!(
                    "serve takes --queries (file mode) or --listen \
                     (network mode), not both"
                );
            }
            if queries.is_none() && listen.is_none() {
                bail!(
                    "serve needs --queries or --listen (or serve.listen \
                     in the config)"
                );
            }
            Command::Serve {
                store: get("store")
                    .ok_or_else(|| anyhow!("serve needs --store"))?,
                queries,
                listen,
                k: int_flag("k", crate::serve::DEFAULT_TOP_K)?,
                quantized: get("quantized").is_some(),
                batch: int_flag("batch", 32)?,
                nprobe: int_flag("nprobe", 0)?,
            }
        }
        "gen-corpus" => Command::GenCorpus {
            spec: get("spec").unwrap_or_else(|| "tiny".into()),
            out: get("out")
                .ok_or_else(|| anyhow!("gen-corpus needs --out"))?,
        },
        "gpusim" => Command::GpuSim,
        "manifest" => Command::Manifest,
        "lint" => Command::Lint {
            json: get("json").is_some(),
            root: get("root"),
        },
        "benchdiff" => {
            let mut paths = positional.iter().skip(1);
            let old = paths.next().cloned().ok_or_else(|| {
                anyhow!("benchdiff needs OLD.json and NEW.json")
            })?;
            let new = paths.next().cloned().ok_or_else(|| {
                anyhow!("benchdiff needs OLD.json and NEW.json")
            })?;
            if paths.next().is_some() {
                bail!("benchdiff takes exactly two artifact paths");
            }
            Command::BenchDiff {
                old,
                new,
                fail_on: get_all("fail-on"),
            }
        }
        "version" | "--version" => Command::Version,
        "help" | "--help" => Command::Help,
        other => bail!("unknown command '{other}'\n{USAGE}"),
    };
    // Resolve (and force) the process-wide SIMD level now, so a bad
    // `--simd`/`FULLW2V_SIMD` value is a clean CLI error instead of a
    // mid-run panic at first kernel use.
    let simd = crate::vecops::select_simd(get("simd").as_deref())
        .map_err(|e| anyhow!("--simd: {e}"))?;
    Ok(Cli { command, config, simd })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Result<Cli> {
        parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn train_with_overrides() {
        let cli = p(&[
            "train",
            "--synthetic",
            "tiny",
            "-s",
            "train.dim=64",
            "-s",
            "train.variant=wombat",
        ])
        .unwrap();
        assert_eq!(cli.config.train.dim, 64);
        assert_eq!(cli.config.train.variant, "wombat");
        match cli.command {
            Command::Train { synthetic, corpus, .. } => {
                assert_eq!(synthetic.as_deref(), Some("tiny"));
                assert!(corpus.is_none());
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn nn_defaults_k() {
        let cli = p(&["nn", "--model", "m.txt", "--word", "cat"]).unwrap();
        match cli.command {
            Command::Nn { k, word, .. } => {
                assert_eq!(k, 10);
                assert_eq!(word, "cat");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn missing_required_flag_errors() {
        assert!(p(&["eval", "--model", "m"]).is_err());
        assert!(p(&["nn", "--word", "w"]).is_err());
    }

    #[test]
    fn unknown_flag_and_command_error() {
        assert!(p(&["train", "--bogus", "x"]).is_err());
        assert!(p(&["frobnicate"]).is_err());
    }

    #[test]
    fn no_args_is_help() {
        let cli = p(&[]).unwrap();
        assert_eq!(cli.command, Command::Help);
    }

    #[test]
    fn nn_accepts_store_or_model_not_both() {
        let cli =
            p(&["nn", "--store", "d", "--word", "w", "--quantized"]).unwrap();
        match cli.command {
            Command::Nn { store, model, quantized, .. } => {
                assert_eq!(store.as_deref(), Some("d"));
                assert!(model.is_none());
                assert!(quantized);
            }
            _ => panic!(),
        }
        assert!(p(&["nn", "--store", "d", "--model", "m", "--word", "w"])
            .is_err());
        // --quantized is a store-path option
        assert!(p(&["nn", "--model", "m", "--word", "w", "--quantized"])
            .is_err());
    }

    #[test]
    fn export_store_and_serve_parse() {
        let cli = p(&[
            "export-store",
            "--model",
            "m.txt",
            "--out",
            "dir",
            "--shards",
            "8",
        ])
        .unwrap();
        assert_eq!(
            cli.command,
            Command::ExportStore {
                model: "m.txt".into(),
                out: "dir".into(),
                shards: 8,
                clusters: 0,
                format: StoreFormat::V3Sidecar,
            }
        );
        let cli =
            p(&["serve", "--store", "dir", "--queries", "q.txt"]).unwrap();
        match cli.command {
            Command::Serve { k, quantized, batch, nprobe, .. } => {
                assert_eq!(k, 10);
                assert!(!quantized);
                assert_eq!(batch, 32);
                assert_eq!(nprobe, 0, "probing must be opt-in");
            }
            _ => panic!(),
        }
        assert!(p(&["serve", "--store", "dir"]).is_err());
        let cli = p(&[
            "serve", "--store", "dir", "--queries", "q.txt", "--batch", "8",
        ])
        .unwrap();
        match cli.command {
            Command::Serve { batch, .. } => assert_eq!(batch, 8),
            _ => panic!(),
        }
    }

    #[test]
    fn ivf_flags_parse() {
        let cli = p(&[
            "export-store",
            "--model",
            "m.txt",
            "--out",
            "dir",
            "--clusters",
            "64",
        ])
        .unwrap();
        match cli.command {
            Command::ExportStore { clusters, shards, .. } => {
                assert_eq!(clusters, 64);
                assert_eq!(shards, 4);
            }
            _ => panic!(),
        }
        let cli = p(&[
            "serve", "--store", "d", "--queries", "q", "--nprobe", "6",
        ])
        .unwrap();
        match cli.command {
            Command::Serve { nprobe, .. } => assert_eq!(nprobe, 6),
            _ => panic!(),
        }
        let cli = p(&[
            "train", "--synthetic", "tiny", "--store", "s", "--clusters", "8",
        ])
        .unwrap();
        match cli.command {
            Command::Train { clusters, .. } => assert_eq!(clusters, 8),
            _ => panic!(),
        }
        // garbage numerics bail like every other int flag
        assert!(p(&[
            "serve", "--store", "d", "--queries", "q", "--nprobe", "x"
        ])
        .is_err());
        assert!(p(&[
            "export-store", "--model", "m", "--out", "d", "--clusters", "4.5"
        ])
        .is_err());
    }

    #[test]
    fn serve_listen_modes() {
        // network mode via flag
        let cli =
            p(&["serve", "--store", "d", "--listen", "127.0.0.1:0"]).unwrap();
        match cli.command {
            Command::Serve { queries, listen, .. } => {
                assert!(queries.is_none());
                assert_eq!(listen.as_deref(), Some("127.0.0.1:0"));
            }
            _ => panic!(),
        }
        // file and network modes are exclusive
        assert!(p(&[
            "serve", "--store", "d", "--queries", "q", "--listen", "a:1"
        ])
        .is_err());
        // the config's serve.listen selects network mode when no flag
        let cli = p(&[
            "serve", "--store", "d", "-s", "serve.listen=127.0.0.1:9",
        ])
        .unwrap();
        match cli.command {
            Command::Serve { listen, .. } => {
                assert_eq!(listen.as_deref(), Some("127.0.0.1:9"));
            }
            _ => panic!(),
        }
        // ...but an explicit --queries keeps file mode despite the config
        let cli = p(&[
            "serve", "--store", "d", "--queries", "q", "-s",
            "serve.listen=127.0.0.1:9",
        ])
        .unwrap();
        match cli.command {
            Command::Serve { queries, listen, .. } => {
                assert_eq!(queries.as_deref(), Some("q"));
                assert!(listen.is_none());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn nn_nprobe_is_store_only() {
        let cli = p(&[
            "nn", "--store", "d", "--word", "w", "--nprobe", "4",
        ])
        .unwrap();
        match cli.command {
            Command::Nn { nprobe, .. } => assert_eq!(nprobe, 4),
            _ => panic!(),
        }
        // defaults to exhaustive
        let cli = p(&["nn", "--store", "d", "--word", "w"]).unwrap();
        match cli.command {
            Command::Nn { nprobe, .. } => assert_eq!(nprobe, 0),
            _ => panic!(),
        }
        // probing is a store-path option, like --quantized
        assert!(p(&[
            "nn", "--model", "m", "--word", "w", "--nprobe", "4"
        ])
        .is_err());
        assert!(p(&[
            "nn", "--store", "d", "--word", "w", "--nprobe", "x"
        ])
        .is_err());
    }

    #[test]
    fn export_store_format_flag() {
        // v3 is the default; both layouts parse explicitly
        for (args, want) in [
            (vec!["export-store", "--model", "m", "--out", "d"],
             StoreFormat::V3Sidecar),
            (vec!["export-store", "--model", "m", "--out", "d",
                  "--format", "v3"],
             StoreFormat::V3Sidecar),
            (vec!["export-store", "--model", "m", "--out", "d",
                  "--format", "v2"],
             StoreFormat::V2Manifest),
        ] {
            match p(&args).unwrap().command {
                Command::ExportStore { format, .. } => {
                    assert_eq!(format, want, "{args:?}")
                }
                _ => panic!(),
            }
        }
        // unknown layouts bail instead of silently writing v3
        let err = p(&[
            "export-store", "--model", "m", "--out", "d", "--format", "v9",
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("--format must be v3 or v2"), "{err}");
    }

    #[test]
    fn garbage_numeric_flags_bail() {
        // "1O" (letter O) must error, not silently become the default
        assert!(p(&[
            "export-store", "--model", "m", "--out", "d", "--shards", "1O"
        ])
        .is_err());
        assert!(p(&[
            "serve", "--store", "d", "--queries", "q", "--k", "abc"
        ])
        .is_err());
    }

    #[test]
    fn train_impl_and_threads_flags() {
        let cli = p(&[
            "train", "--synthetic", "tiny", "--impl", "fullw2v",
            "--threads", "4",
        ])
        .unwrap();
        match cli.command {
            Command::Train { implementation, threads, .. } => {
                assert_eq!(implementation.as_deref(), Some("fullw2v"));
                assert_eq!(threads, Some(4));
            }
            _ => panic!(),
        }
        // both default to "unset" so the config decides
        let cli = p(&["train", "--synthetic", "tiny"]).unwrap();
        match cli.command {
            Command::Train { implementation, threads, .. } => {
                assert!(implementation.is_none());
                assert!(threads.is_none());
            }
            _ => panic!(),
        }
        // garbage thread counts bail like every other int flag
        assert!(p(&[
            "train", "--synthetic", "tiny", "--threads", "four"
        ])
        .is_err());
    }

    #[test]
    fn simd_flag_parses_and_validates() {
        use crate::vecops::{self, SimdLevel};
        // Lib tests share the process-wide dispatch table, so only
        // force `scalar` here (bit-identical to every other level) and
        // restore the prior selection afterwards.
        let before = vecops::active().level();
        let cli = p(&["train", "--synthetic", "tiny", "--simd", "scalar"])
            .unwrap();
        assert_eq!(cli.simd.level, SimdLevel::Scalar);
        assert_eq!(cli.simd.source, "--simd");
        vecops::force_level(before).unwrap();

        // bad values error before anything is forced
        let err = p(&["train", "--synthetic", "tiny", "--simd", "sse9"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown simd level"), "{err}");
        // forcing a level this host lacks is a hard error
        for l in SimdLevel::ALL {
            if !l.available() {
                let err =
                    p(&["train", "--synthetic", "tiny", "--simd", l.name()])
                        .unwrap_err()
                        .to_string();
                assert!(err.contains("not available"), "{err}");
            }
        }
        // every command resolves a selection even without the flag
        let cli = p(&["gpusim"]).unwrap();
        assert!(cli.simd.level.available());
    }

    #[test]
    fn lint_flags_parse() {
        let cli = p(&["lint"]).unwrap();
        assert_eq!(cli.command, Command::Lint { json: false, root: None });
        let cli = p(&["lint", "--json", "--root", "/tmp/checkout"]).unwrap();
        assert_eq!(
            cli.command,
            Command::Lint {
                json: true,
                root: Some("/tmp/checkout".into())
            }
        );
    }

    #[test]
    fn benchdiff_parses_paths_and_repeatable_fail_on() {
        let cli = p(&["benchdiff", "old.json", "new.json"]).unwrap();
        assert_eq!(
            cli.command,
            Command::BenchDiff {
                old: "old.json".into(),
                new: "new.json".into(),
                fail_on: vec![],
            }
        );
        // --fail-on repeats and keeps order
        let cli = p(&[
            "benchdiff", "a.json", "b.json", "--fail-on", "p50_us$=5",
            "--fail-on", "rows.*=2",
        ])
        .unwrap();
        match cli.command {
            Command::BenchDiff { fail_on, .. } => {
                assert_eq!(fail_on, vec!["p50_us$=5", "rows.*=2"]);
            }
            _ => panic!(),
        }
        // arity is enforced: one path or three is a parse error
        assert!(p(&["benchdiff", "only.json"]).is_err());
        assert!(p(&["benchdiff", "a.json", "b.json", "c.json"]).is_err());
    }

    #[test]
    fn train_store_export_flags() {
        let cli =
            p(&["train", "--synthetic", "tiny", "--store", "s", "--shards", "2"])
                .unwrap();
        match cli.command {
            Command::Train { store, shards, .. } => {
                assert_eq!(store.as_deref(), Some("s"));
                assert_eq!(shards, 2);
            }
            _ => panic!(),
        }
    }
}
