//! Hogwild-shared view of an [`EmbeddingModel`].
//!
//! The Hogwild! training scheme (Niu et al., also the update discipline
//! of pWord2Vec and FULL-W2V itself) lets worker threads read and write
//! the shared embedding matrices **without synchronization**: SGNS
//! updates are sparse, collisions are rare, and the lost-update noise is
//! far below the SGD noise floor.  Rust has no safe vocabulary for that
//! discipline, so this module confines it: [`SharedModel`] is a
//! `SyncUnsafeCell`-style wrapper built from a unique `&mut` borrow of
//! the model (nothing else can touch the matrices while it exists), and
//! callers only ever see row-granular *operations* — rows are copied
//! out, dotted against, or updated in place inside a single call; no
//! reference to shared memory escapes.
//!
//! Mutation never materializes a `&mut [f32]`: two workers updating the
//! same row through aliasing `&mut` (which rustc marks noalias) would be
//! language-level UB beyond the intended lost-update model, so the
//! update methods do their read-modify-write element-wise through raw
//! pointers.  Read methods form transient `&[f32]` views to reuse the
//! `vecops` kernels; a concurrent racy write under such a view is the
//! residual Hogwild trade (torn f32 values cannot occur on the targeted
//! platforms — aligned 32-bit loads/stores), and with one worker the
//! view is exactly as sequential as a plain `&mut EmbeddingModel`.

use super::EmbeddingModel;
use crate::vecops::dot;
use std::marker::PhantomData;

/// Unsynchronized multi-thread view over one model's matrices.
pub struct SharedModel<'a> {
    syn0: *mut f32,
    syn1: *mut f32,
    vocab_size: usize,
    dim: usize,
    _model: PhantomData<&'a mut EmbeddingModel>,
}

// SAFETY: the wrapper owns the only live borrow of the model, and all
// access is row-granular through the methods below, so moving it to
// another thread cannot invalidate any outstanding reference.
unsafe impl Send for SharedModel<'_> {}
// SAFETY: concurrent method calls only race element-wise through raw
// pointers; those data races between workers are the documented Hogwild
// contract (see module docs).
unsafe impl Sync for SharedModel<'_> {}

impl<'a> SharedModel<'a> {
    /// Build a shared view from a unique borrow.  The borrow lasts for
    /// the view's lifetime, so no other code can alias the matrices.
    pub fn new(model: &'a mut EmbeddingModel) -> Self {
        SharedModel {
            syn0: model.syn0.as_mut_ptr(),
            syn1: model.syn1.as_mut_ptr(),
            vocab_size: model.vocab_size,
            dim: model.dim,
            _model: PhantomData,
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    #[inline]
    fn row(&self, base: *mut f32, id: u32) -> &[f32] {
        assert!((id as usize) < self.vocab_size, "row id {id} >= V");
        // SAFETY: in-bounds by the assert; see module docs for the race
        // contract.
        unsafe {
            std::slice::from_raw_parts(
                base.add(id as usize * self.dim),
                self.dim,
            )
        }
    }

    /// `row += alpha * x`, element-wise through the raw pointer — the
    /// same per-element expression as [`crate::vecops::axpy`] (so
    /// single-threaded results are bit-identical to it), but with no
    /// `&mut` formed over memory other workers may touch.  Racing
    /// workers can lose whole element updates; that is the Hogwild
    /// contract.
    #[inline]
    fn axpy_raw(&self, base: *mut f32, id: u32, alpha: f32, x: &[f32]) {
        assert!((id as usize) < self.vocab_size, "row id {id} >= V");
        assert_eq!(x.len(), self.dim, "update width mismatch");
        // SAFETY: in-bounds by the asserts; racy read-modify-write is
        // the documented contract (see module docs).
        unsafe {
            let p = base.add(id as usize * self.dim);
            for (j, &xj) in x.iter().enumerate() {
                let pj = p.add(j);
                pj.write(pj.read() + alpha * xj);
            }
        }
    }

    /// Copy `syn0[id]` into `dst`.
    #[inline]
    pub fn copy_syn0_row(&self, id: u32, dst: &mut [f32]) {
        dst.copy_from_slice(self.row(self.syn0, id));
    }

    /// Copy `syn1[id]` into `dst`.
    #[inline]
    pub fn copy_syn1_row(&self, id: u32, dst: &mut [f32]) {
        dst.copy_from_slice(self.row(self.syn1, id));
    }

    /// `dot(syn0[id], x)` against the live row.
    #[inline]
    pub fn dot_syn0(&self, id: u32, x: &[f32]) -> f32 {
        dot(self.row(self.syn0, id), x)
    }

    /// `dot(syn1[id], x)` against the live row.
    #[inline]
    pub fn dot_syn1(&self, id: u32, x: &[f32]) -> f32 {
        dot(self.row(self.syn1, id), x)
    }

    /// `syn0[id] += delta` (element-wise; `1.0 * v == v` exactly, so
    /// this matches an alpha-1 [`crate::vecops::axpy`] bit-for-bit).
    #[inline]
    pub fn add_syn0_row(&self, id: u32, delta: &[f32]) {
        self.axpy_raw(self.syn0, id, 1.0, delta);
    }

    /// `syn1[id] += delta`.
    #[inline]
    pub fn add_syn1_row(&self, id: u32, delta: &[f32]) {
        self.axpy_raw(self.syn1, id, 1.0, delta);
    }

    /// `syn0[id] += alpha * x`.
    #[inline]
    pub fn axpy_syn0_row(&self, id: u32, alpha: f32, x: &[f32]) {
        self.axpy_raw(self.syn0, id, alpha, x);
    }

    /// `syn1[id] += alpha * x`.
    #[inline]
    pub fn axpy_syn1_row(&self, id: u32, alpha: f32, x: &[f32]) {
        self.axpy_raw(self.syn1, id, alpha, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_ops_match_direct_access() {
        let mut m = EmbeddingModel::init(4, 3, 7);
        let before0 = m.syn0.clone();
        let before1 = m.syn1.clone();
        {
            let view = SharedModel::new(&mut m);
            assert_eq!(view.dim(), 3);
            assert_eq!(view.vocab_size(), 4);
            let mut buf = [0.0f32; 3];
            view.copy_syn0_row(2, &mut buf);
            assert_eq!(&buf, &before0[6..9]);
            let z = view.dot_syn0(2, &[1.0, 2.0, 3.0]);
            let want = before0[6] + 2.0 * before0[7] + 3.0 * before0[8];
            assert!((z - want).abs() < 1e-6);
            view.add_syn0_row(1, &[1.0, 1.0, 1.0]);
            view.axpy_syn1_row(0, 2.0, &[1.0, 0.0, -1.0]);
        }
        for j in 0..3 {
            assert!((m.syn0[3 + j] - (before0[3 + j] + 1.0)).abs() < 1e-7);
        }
        assert!((m.syn1[0] - (before1[0] + 2.0)).abs() < 1e-7);
        assert!((m.syn1[2] - (before1[2] - 2.0)).abs() < 1e-7);
    }

    #[test]
    #[should_panic(expected = ">= V")]
    fn out_of_range_row_panics() {
        let mut m = EmbeddingModel::init(2, 2, 1);
        let view = SharedModel::new(&mut m);
        view.dot_syn0(2, &[0.0, 0.0]);
    }

    #[test]
    fn disjoint_rows_update_concurrently() {
        let mut m = EmbeddingModel::init(8, 4, 3);
        m.syn0.iter_mut().for_each(|x| *x = 0.0);
        {
            let view = SharedModel::new(&mut m);
            std::thread::scope(|s| {
                for t in 0..4u32 {
                    let view = &view;
                    s.spawn(move || {
                        for _ in 0..100 {
                            view.add_syn0_row(t * 2, &[1.0, 1.0, 1.0, 1.0]);
                        }
                    });
                }
            });
        }
        for t in 0..4 {
            let row = m.syn0_row(t * 2);
            assert!(row.iter().all(|&x| (x - 100.0).abs() < 1e-4));
        }
    }
}
