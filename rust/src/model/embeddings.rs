//! Embedding matrices and model I/O.
//!
//! Initialization follows word2vec.c: syn0 uniform in
//! `[-0.5/d, 0.5/d)` per component, syn1neg zeroed.  Persistence supports
//! the word2vec text format (interoperable with gensim et al.) and a raw
//! binary format for fast checkpointing.

use crate::corpus::vocab::Vocab;
use crate::util::rng::Pcg32;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Dense row-major matrix of word embeddings.
#[derive(Debug, Clone)]
pub struct EmbeddingModel {
    /// Input-side vectors (syn0), V x d row-major.
    pub syn0: Vec<f32>,
    /// Output-side vectors (syn1neg), V x d row-major.
    pub syn1: Vec<f32>,
    pub vocab_size: usize,
    pub dim: usize,
}

impl EmbeddingModel {
    /// word2vec-style initialization.
    pub fn init(vocab_size: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Pcg32::with_stream(seed, 0xE3B);
        let scale = 1.0 / dim as f32;
        let syn0 = (0..vocab_size * dim)
            .map(|_| (rng.next_f32() - 0.5) * scale)
            .collect();
        let syn1 = vec![0.0; vocab_size * dim];
        EmbeddingModel { syn0, syn1, vocab_size, dim }
    }

    #[inline]
    pub fn syn0_row(&self, id: u32) -> &[f32] {
        debug_assert!((id as usize) < self.vocab_size, "row id {id} >= V");
        let i = id as usize * self.dim;
        &self.syn0[i..i + self.dim]
    }

    /// Bounds-checked row accessor: `None` for ids at or past the vocab
    /// boundary (and on index overflow), instead of a slice panic.  For
    /// callers that index rows with ids from external input (files,
    /// queries) rather than the vocabulary itself.
    #[inline]
    pub fn try_syn0_row(&self, id: u32) -> Option<&[f32]> {
        if (id as usize) >= self.vocab_size {
            return None;
        }
        let i = (id as usize).checked_mul(self.dim)?;
        let end = i.checked_add(self.dim)?;
        self.syn0.get(i..end)
    }

    #[inline]
    pub fn syn1_row(&self, id: u32) -> &[f32] {
        let i = id as usize * self.dim;
        &self.syn1[i..i + self.dim]
    }

    #[inline]
    pub fn syn0_row_mut(&mut self, id: u32) -> &mut [f32] {
        let i = id as usize * self.dim;
        &mut self.syn0[i..i + self.dim]
    }

    #[inline]
    pub fn syn1_row_mut(&mut self, id: u32) -> &mut [f32] {
        let i = id as usize * self.dim;
        &mut self.syn1[i..i + self.dim]
    }

    /// Cosine similarity between two word ids (input vectors).
    pub fn cosine(&self, a: u32, b: u32) -> f64 {
        cosine(self.syn0_row(a), self.syn0_row(b))
    }

    /// Top-k nearest neighbors of `id` by cosine, excluding itself.
    pub fn nearest(&self, id: u32, k: usize) -> Vec<(u32, f64)> {
        let mut scored: Vec<(u32, f64)> = (0..self.vocab_size as u32)
            .filter(|&x| x != id)
            .map(|x| (x, self.cosine(id, x)))
            .collect();
        scored.sort_by(|a, b| b.1.total_cmp(&a.1));
        scored.truncate(k);
        scored
    }

    /// L2-normalized copy of syn0 (rows), used by the analogy solver.
    pub fn normalized_syn0(&self) -> Vec<f32> {
        self.normalized_rows()
    }

    /// L2-normalized copy of the input-side rows (V x d row-major).
    ///
    /// Cosine similarity over normalized rows reduces to a dot product,
    /// so the serving store normalizes once at export time and every
    /// query afterwards is dot-only.  Zero rows are left as zeros.
    pub fn normalized_rows(&self) -> Vec<f32> {
        let mut out = self.syn0.clone();
        normalize_rows_in_place(&mut out, self.dim);
        out
    }

    /// Save in word2vec *text* format: header `V d`, then
    /// `word v1 v2 ... vd` lines.
    pub fn save_text(&self, vocab: &Vocab, path: &Path) -> std::io::Result<()> {
        let mut f = BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{} {}", self.vocab_size, self.dim)?;
        for id in 0..self.vocab_size as u32 {
            write!(f, "{}", vocab.word(id))?;
            for x in self.syn0_row(id) {
                write!(f, " {x}")?;
            }
            writeln!(f)?;
        }
        Ok(())
    }

    /// Load from word2vec text format; returns (words, model).
    pub fn load_text(path: &Path) -> std::io::Result<(Vec<String>, Self)> {
        let f = BufReader::new(std::fs::File::open(path)?);
        let mut lines = f.lines();
        let header = lines.next().ok_or_else(|| bad("empty file"))??;
        let (v, d) = header.split_once(' ').ok_or_else(|| bad("bad header"))?;
        let vocab_size: usize = v.parse().map_err(|_| bad("bad V"))?;
        let dim: usize = d.trim().parse().map_err(|_| bad("bad d"))?;
        let mut words = Vec::with_capacity(vocab_size);
        let mut syn0 = Vec::with_capacity(vocab_size * dim);
        for line in lines {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let w = it.next().ok_or_else(|| bad("missing word"))?;
            words.push(w.to_string());
            let before = syn0.len();
            for tok in it {
                syn0.push(tok.parse::<f32>().map_err(|_| bad("bad float"))?);
            }
            if syn0.len() - before != dim {
                return Err(bad("wrong vector length"));
            }
        }
        if words.len() != vocab_size {
            return Err(bad("wrong word count"));
        }
        let syn1 = vec![0.0; vocab_size * dim];
        Ok((words, EmbeddingModel { syn0, syn1, vocab_size, dim }))
    }

    /// Save both matrices in a raw little-endian binary checkpoint.
    pub fn save_binary(&self, path: &Path) -> std::io::Result<()> {
        let mut f = BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"FW2V")?;
        f.write_all(&(self.vocab_size as u64).to_le_bytes())?;
        f.write_all(&(self.dim as u64).to_le_bytes())?;
        for m in [&self.syn0, &self.syn1] {
            for x in m {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load a raw binary checkpoint.
    pub fn load_binary(path: &Path) -> std::io::Result<Self> {
        let mut f = BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"FW2V" {
            return Err(bad("bad magic"));
        }
        let mut u = [0u8; 8];
        f.read_exact(&mut u)?;
        let vocab_size = u64::from_le_bytes(u) as usize;
        f.read_exact(&mut u)?;
        let dim = u64::from_le_bytes(u) as usize;
        let mut read_mat = |n: usize| -> std::io::Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect())
        };
        let syn0 = read_mat(vocab_size * dim)?;
        let syn1 = read_mat(vocab_size * dim)?;
        Ok(EmbeddingModel { syn0, syn1, vocab_size, dim })
    }
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

/// L2-normalize each `dim`-wide row of a row-major matrix in place.
/// Zero rows are left untouched.  The slice length must be a multiple of
/// `dim`, so the final chunk is always a full row (the vocab-boundary
/// guarantee the serving store relies on).
pub fn normalize_rows_in_place(rows: &mut [f32], dim: usize) {
    assert!(dim > 0, "dim must be positive");
    assert_eq!(rows.len() % dim, 0, "rows length not a multiple of dim");
    for row in rows.chunks_exact_mut(dim) {
        let n = crate::vecops::dot_f64(row, row);
        let n = n.sqrt() as f32;
        if n > 0.0 {
            for x in row.iter_mut() {
                *x /= n;
            }
        }
    }
}

/// Cosine similarity of two equal-length vectors.  The three inner
/// products route through [`crate::vecops::dot_f64`], so evaluation's
/// hot vocab scans pick up the unrolled/SIMD dispatch paths.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot = crate::vecops::dot_f64(a, b);
    let na = crate::vecops::dot_f64(a, a);
    let nb = crate::vecops::dot_f64(b, b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab3() -> Vocab {
        Vocab::from_counts(
            vec![("a".into(), 30u64), ("b".into(), 20), ("c".into(), 10)],
            1,
        )
    }

    #[test]
    fn init_ranges() {
        let m = EmbeddingModel::init(100, 64, 1);
        assert_eq!(m.syn0.len(), 6400);
        assert!(m.syn1.iter().all(|&x| x == 0.0));
        let bound = 0.5 / 64.0 + 1e-9;
        assert!(m.syn0.iter().all(|&x| x >= -bound && x < bound));
        // not all identical
        assert!(m.syn0.iter().any(|&x| x != m.syn0[0]));
    }

    #[test]
    fn deterministic_init() {
        let a = EmbeddingModel::init(10, 8, 42);
        let b = EmbeddingModel::init(10, 8, 42);
        assert_eq!(a.syn0, b.syn0);
        let c = EmbeddingModel::init(10, 8, 43);
        assert_ne!(a.syn0, c.syn0);
    }

    #[test]
    fn cosine_properties() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn nearest_finds_planted_neighbor() {
        let mut m = EmbeddingModel::init(5, 4, 1);
        m.syn0_row_mut(0).copy_from_slice(&[1.0, 0.0, 0.0, 0.0]);
        m.syn0_row_mut(3).copy_from_slice(&[0.9, 0.1, 0.0, 0.0]);
        let nn = m.nearest(0, 2);
        assert_eq!(nn[0].0, 3);
        assert!(nn[0].1 > 0.9);
    }

    #[test]
    fn text_roundtrip() {
        let v = vocab3();
        let m = EmbeddingModel::init(3, 4, 7);
        let dir = std::env::temp_dir().join("fullw2v_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("emb.txt");
        m.save_text(&v, &p).unwrap();
        let (words, m2) = EmbeddingModel::load_text(&p).unwrap();
        assert_eq!(words, vec!["a", "b", "c"]);
        assert_eq!(m2.dim, 4);
        for (x, y) in m.syn0.iter().zip(&m2.syn0) {
            assert!((x - y).abs() < 1e-5);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_roundtrip_exact() {
        let m = EmbeddingModel::init(7, 5, 3);
        let dir = std::env::temp_dir().join("fullw2v_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("emb.bin");
        m.save_binary(&p).unwrap();
        let m2 = EmbeddingModel::load_binary(&p).unwrap();
        assert_eq!(m.syn0, m2.syn0);
        assert_eq!(m.syn1, m2.syn1);
        assert_eq!(m.vocab_size, m2.vocab_size);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn normalized_rows_are_unit() {
        let m = EmbeddingModel::init(4, 8, 9);
        let n = m.normalized_syn0();
        for r in 0..4 {
            let row = &n[r * 8..(r + 1) * 8];
            let norm: f64 = row.iter().map(|x| (x * x) as f64).sum();
            assert!((norm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn normalized_rows_last_row_is_full_width() {
        // regression: the final row must be a complete dim-wide slice and
        // normalize like any interior row (vocab-boundary case)
        let m = EmbeddingModel::init(5, 3, 11);
        let n = m.normalized_rows();
        assert_eq!(n.len(), 5 * 3);
        let last = &n[4 * 3..5 * 3];
        assert_eq!(last.len(), 3);
        let norm: f64 = last.iter().map(|x| (x * x) as f64).sum();
        assert!((norm - 1.0).abs() < 1e-5);
        // direction preserved vs the unnormalized row
        let raw = m.syn0_row(4);
        let c = cosine(raw, last);
        assert!((c - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalized_rows_keep_zero_rows() {
        let mut m = EmbeddingModel::init(3, 4, 2);
        m.syn0_row_mut(1).fill(0.0);
        let n = m.normalized_rows();
        assert!(n[4..8].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn try_row_bounds() {
        let m = EmbeddingModel::init(3, 4, 5);
        // last valid row: full width, identical to the panicking accessor
        assert_eq!(m.try_syn0_row(2).unwrap(), m.syn0_row(2));
        assert_eq!(m.try_syn0_row(2).unwrap().len(), 4);
        // first invalid id: None instead of a slice panic
        assert!(m.try_syn0_row(3).is_none());
        assert!(m.try_syn0_row(u32::MAX).is_none());
    }
}
