//! The embedding model state: syn0 (input vectors) / syn1neg (output
//! vectors), word2vec-compatible initialization, persistence, similarity
//! queries, and the Hogwild-shared view the parallel training layer
//! hands its worker threads.

pub mod embeddings;
pub mod shared;

pub use embeddings::EmbeddingModel;
pub use shared::SharedModel;
