//! The embedding model state: syn0 (input vectors) / syn1neg (output
//! vectors), word2vec-compatible initialization, persistence, and
//! similarity queries.

pub mod embeddings;

pub use embeddings::EmbeddingModel;
