//! Analogy reconstruction: `a : b :: c : ?` solved over normalized
//! embeddings with 3COSADD and 3COSMUL (Levy & Goldberg / Hyperwords),
//! the protocol the paper's Table 7 COS-ADD / COS-MUL columns use.

use crate::corpus::synthetic::GoldAnalogy;
use crate::corpus::vocab::Vocab;
use crate::model::embeddings::EmbeddingModel;

/// Which objective ranks candidate answers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnalogyMethod {
    /// argmax cos(d, b) - cos(d, a) + cos(d, c)
    CosAdd,
    /// argmax cos'(d,b) * cos'(d,c) / (cos'(d,a) + eps), cos' in [0,1]
    CosMul,
}

/// Aggregate accuracy over an analogy set.
#[derive(Debug, Clone)]
pub struct AnalogyReport {
    pub correct: usize,
    pub total: usize,
    pub skipped: usize,
}

impl AnalogyReport {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

/// Solve a set of analogies; `a`, `b`, `c` are excluded from candidates
/// (standard protocol).
pub fn solve_analogies(
    model: &EmbeddingModel,
    vocab: &Vocab,
    analogies: &[GoldAnalogy],
    method: AnalogyMethod,
) -> AnalogyReport {
    let norm = model.normalized_syn0();
    let d = model.dim;
    let v = model.vocab_size;
    let row = |id: u32| -> &[f32] {
        &norm[id as usize * d..(id as usize + 1) * d]
    };
    let mut correct = 0;
    let mut total = 0;
    let mut skipped = 0;
    for g in analogies {
        let ids = (
            vocab.id(&g.a),
            vocab.id(&g.b),
            vocab.id(&g.c),
            vocab.id(&g.d),
        );
        let (ia, ib, ic, id_ans) = match ids {
            (Some(a), Some(b), Some(c), Some(dd)) => (a, b, c, dd),
            _ => {
                skipped += 1;
                continue;
            }
        };
        total += 1;
        // precompute cosines of every candidate against a, b, c
        let (ra, rb, rc) = (row(ia), row(ib), row(ic));
        let mut best: Option<(u32, f64)> = None;
        for cand in 0..v as u32 {
            if cand == ia || cand == ib || cand == ic {
                continue;
            }
            let rd = row(cand);
            let ca = dot(rd, ra);
            let cb = dot(rd, rb);
            let cc = dot(rd, rc);
            let score = match method {
                AnalogyMethod::CosAdd => cb - ca + cc,
                AnalogyMethod::CosMul => {
                    // shift cosines into [0,1] as Levy & Goldberg do
                    let (ca, cb, cc) =
                        ((ca + 1.0) / 2.0, (cb + 1.0) / 2.0, (cc + 1.0) / 2.0);
                    cb * cc / (ca + 1e-3)
                }
            };
            if best.map(|(_, s)| score > s).unwrap_or(true) {
                best = Some((cand, score));
            }
        }
        if best.map(|(w, _)| w == id_ans).unwrap_or(false) {
            correct += 1;
        }
    }
    AnalogyReport { correct, total, skipped }
}

use crate::vecops::dot_f64 as dot;

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a model with perfect compositional geometry:
    /// vec(word) = cluster_axis + role_axis in a 4-d space.
    fn planted() -> (EmbeddingModel, Vocab, Vec<GoldAnalogy>) {
        // 2 clusters x 2 roles = 4 words: c0r0, c0r1, c1r0, c1r1
        let words = ["c0r0", "c0r1", "c1r0", "c1r1"];
        let vecs: [[f32; 4]; 4] = [
            [1.0, 0.0, 1.0, 0.0], // c0 + r0
            [1.0, 0.0, 0.0, 1.0], // c0 + r1
            [0.0, 1.0, 1.0, 0.0], // c1 + r0
            [0.0, 1.0, 0.0, 1.0], // c1 + r1
        ];
        let v = Vocab::from_counts(
            words.iter().map(|w| (w.to_string(), 10u64)),
            1,
        );
        let mut m = EmbeddingModel::init(4, 4, 1);
        for (i, w) in words.iter().enumerate() {
            let id = v.id(w).unwrap();
            m.syn0_row_mut(id).copy_from_slice(&vecs[i]);
        }
        let gold = vec![GoldAnalogy {
            a: "c0r0".into(),
            b: "c0r1".into(),
            c: "c1r0".into(),
            d: "c1r1".into(),
        }];
        (m, v, gold)
    }

    #[test]
    fn planted_analogy_solved_by_both_methods() {
        let (m, v, gold) = planted();
        for method in [AnalogyMethod::CosAdd, AnalogyMethod::CosMul] {
            let rep = solve_analogies(&m, &v, &gold, method);
            assert_eq!(rep.total, 1);
            assert_eq!(rep.correct, 1, "{method:?}");
        }
    }

    #[test]
    fn oov_analogies_skipped() {
        let (m, v, mut gold) = planted();
        gold.push(GoldAnalogy {
            a: "c0r0".into(),
            b: "nope".into(),
            c: "c1r0".into(),
            d: "c1r1".into(),
        });
        let rep = solve_analogies(&m, &v, &gold, AnalogyMethod::CosAdd);
        assert_eq!(rep.total, 1);
        assert_eq!(rep.skipped, 1);
        assert!((rep.accuracy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_model_fails_planted_analogy() {
        let (_, v, gold) = planted();
        // fresh random init without the planted geometry: with 1 candidate
        // and random vectors, accuracy is not guaranteed 1
        let m = EmbeddingModel::init(4, 4, 99);
        let rep = solve_analogies(&m, &v, &gold, AnalogyMethod::CosAdd);
        assert_eq!(rep.total, 1);
        // either way it must not crash; accuracy is 0 or 1 here
        assert!(rep.correct <= 1);
    }

    #[test]
    fn empty_set() {
        let (m, v, _) = planted();
        let rep = solve_analogies(&m, &v, &[], AnalogyMethod::CosMul);
        assert_eq!(rep.total, 0);
        assert_eq!(rep.accuracy(), 0.0);
    }
}
