//! Embedding-quality evaluation (paper Section 5.1 "Training quality"):
//! Spearman rank correlation against similarity judgements and analogy
//! reconstruction with 3COSADD / 3COSMUL.

pub mod analogy;
pub mod similarity;

pub use analogy::{solve_analogies, AnalogyMethod, AnalogyReport};
pub use similarity::{evaluate_similarity, spearman, SimilarityReport};
