//! Word-similarity evaluation: Spearman's rank correlation between model
//! cosine similarities and gold judgements (the WS-353 / SimLex-999
//! protocol, run here against the synthetic generator's latent gold).

use crate::corpus::synthetic::GoldPair;
use crate::corpus::vocab::Vocab;
use crate::model::embeddings::{cosine, EmbeddingModel};

/// Result of a similarity benchmark run.
#[derive(Debug, Clone)]
pub struct SimilarityReport {
    /// Spearman's rho over scoreable pairs.
    pub spearman: f64,
    /// Pairs evaluated (both words in vocabulary).
    pub used: usize,
    /// Pairs skipped due to OOV words.
    pub skipped: usize,
}

/// Ranks with average-tie handling (the standard Spearman treatment).
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Pearson correlation of two equal-length samples.
fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        // LINT: allow(kernel-purity): f64 rank statistics over a handful
        // of word pairs — not an embedding kernel, nothing to dispatch.
        sxy += (a - mx) * (b - my);
        // LINT: allow(kernel-purity): as above.
        sxx += (a - mx) * (a - mx);
        // LINT: allow(kernel-purity): as above.
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Spearman's rho = Pearson of the rank transforms.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    pearson(&ranks(x), &ranks(y))
}

/// Score a model against gold pairs.
pub fn evaluate_similarity(
    model: &EmbeddingModel,
    vocab: &Vocab,
    gold: &[GoldPair],
) -> SimilarityReport {
    let mut model_scores = Vec::new();
    let mut gold_scores = Vec::new();
    let mut skipped = 0;
    for p in gold {
        match (vocab.id(&p.a), vocab.id(&p.b)) {
            (Some(a), Some(b)) => {
                model_scores
                    .push(cosine(model.syn0_row(a), model.syn0_row(b)));
                gold_scores.push(p.score);
            }
            _ => skipped += 1,
        }
    }
    SimilarityReport {
        spearman: spearman(&model_scores, &gold_scores),
        used: model_scores.len(),
        skipped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_monotone_is_one() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        // any monotone transform keeps rho = 1
        let y2 = vec![1.0, 100.0, 101.0, 1e6];
        assert!((spearman(&x, &y2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reversed_is_minus_one() {
        let x = vec![1.0, 2.0, 3.0];
        let y = vec![3.0, 2.0, 1.0];
        assert!((spearman(&x, &y) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ties_average() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn independent_is_near_zero() {
        // deterministic pseudo-random independence
        let x: Vec<f64> = (0..500).map(|i| ((i * 37) % 101) as f64).collect();
        let y: Vec<f64> = (0..500).map(|i| ((i * 59) % 103) as f64).collect();
        assert!(spearman(&x, &y).abs() < 0.12);
    }

    #[test]
    fn known_small_example() {
        // classic example: d^2 = [0,1,1,4] -> rho = 1 - 6*6/(4*15) = 0.4?
        // compute directly via definition instead:
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![1.0, 3.0, 2.0, 4.0];
        // ranks equal values; d = [0, -1, 1, 0], sum d^2 = 2
        // rho = 1 - 6*2 / (4*(16-1)) = 1 - 12/60 = 0.8
        assert!((spearman(&x, &y) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn degenerate_constant_input() {
        assert_eq!(spearman(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(spearman(&[], &[]), 0.0);
    }

    #[test]
    fn oov_pairs_skipped() {
        use crate::corpus::vocab::Vocab;
        let v = Vocab::from_counts(
            vec![("a".into(), 10u64), ("b".into(), 5)],
            1,
        );
        let m = EmbeddingModel::init(2, 4, 1);
        let gold = vec![
            GoldPair { a: "a".into(), b: "b".into(), score: 0.5 },
            GoldPair { a: "a".into(), b: "zzz".into(), score: 0.9 },
        ];
        let rep = evaluate_similarity(&m, &v, &gold);
        assert_eq!(rep.used, 1);
        assert_eq!(rep.skipped, 1);
    }
}
