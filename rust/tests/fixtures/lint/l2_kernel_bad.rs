//! kernel-purity negative fixture: a hand-rolled f32 multiply-
//! accumulate loop and a map-multiply reduction, both outside vecops/.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

pub fn norm_sq(v: &[f32]) -> f64 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
}
