//! simd-contract positive fixture: allowlisted, non-fused intrinsics.
//! Quiet only when linted at the audited backend path
//! (`rust/src/vecops/simd_x86.rs`); loud anywhere else.
pub fn mul(a: __m256, b: __m256) -> __m256 {
    _mm256_mul_ps(a, b)
}
