//! unsafe-audit positive fixture: the same site, annotated.  Clean only
//! when the lint run also supplies a budget entry for this file.
pub fn read_first(p: *const f32) -> f32 {
    // SAFETY: the caller guarantees `p` is valid for reads.
    unsafe { *p }
}
