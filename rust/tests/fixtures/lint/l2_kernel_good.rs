//! kernel-purity positive fixture: the same reductions routed through
//! the vecops dispatch API (plus integer accounting, which is exempt).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    crate::vecops::dot_f64(a, b)
}

pub fn pairs(m: usize, n: usize) -> u64 {
    let mut acc = 0u64;
    for _ in 0..3 {
        acc += (m * n) as u64;
    }
    acc
}
