//! panic-path negative fixture: a panicking unwrap and wire-facing
//! range indexing, both fatal on a request path.
pub fn frame(buf: &[u8], n: Option<usize>) -> &[u8] {
    let len = n.unwrap();
    &buf[..len]
}
