//! panic-path positive fixture: the checked idiom (get + error
//! response) and a justified waiver for a provably-bounded slice.
pub fn frame(buf: &[u8], n: Option<usize>) -> Result<&[u8], String> {
    let len = n.ok_or_else(|| "missing length".to_string())?;
    buf.get(..len).ok_or_else(|| "truncated frame".to_string())
}

pub fn tail(buf: &[u8], n: usize) -> &[u8] {
    // LINT: allow(panic-path): caller contract guarantees n <= buf.len().
    &buf[..n]
}
