//! simd-contract negative fixture: a raw `std::arch` import, loose
//! intrinsics outside the backends, and an FMA (never waivable).
use std::arch::x86_64::*;

pub fn fused(a: __m256, b: __m256, c: __m256) -> __m256 {
    _mm256_fmadd_ps(a, b, c)
}
