//! ordering-annotation negative fixture: an atomic ordering with no
//! `// ORDERING:` justification, in an audited file.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed)
}
