//! ordering-annotation positive fixture: the same site, justified.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    // ORDERING: Relaxed — independent statistic; the value itself is
    // the only memory published through this atomic.
    c.fetch_add(1, Ordering::Relaxed)
}
