//! unsafe-audit negative fixture: an `unsafe` block with no
//! `// SAFETY:` comment.  Linted through `run_files`, never compiled.
pub fn read_first(p: *const f32) -> f32 {
    unsafe { *p }
}
