//! Hogwild training-layer integration: single-thread determinism, the
//! documented update rule, parallel convergence parity, and the
//! per-chunk accounting fix.  Every test name carries `hogwild` so
//! `cargo test -- hogwild` exercises exactly this suite (the CI release
//! job does).

use fullw2v::config::TrainConfig;
use fullw2v::coordinator::{train_all, SgnsTrainer};
use fullw2v::corpus::synthetic::{SyntheticCorpus, SyntheticSpec};
use fullw2v::corpus::vocab::Vocab;
use fullw2v::sampler::unigram::UnigramTable;
use fullw2v::trainer::{build_cpu_trainer, hogwild, FullW2vTrainer, CPU_IMPLS};
use fullw2v::vecops::{dot, sigmoid};
use std::sync::Arc;

fn tiny_corpus(total_words: u64) -> (Vocab, Arc<Vec<Vec<u32>>>) {
    let mut spec = SyntheticSpec::tiny();
    spec.total_words = total_words;
    let corpus = SyntheticCorpus::generate(spec);
    let text = corpus.to_text();
    let vocab = Vocab::build(text.split_whitespace(), 1);
    let sentences: Vec<Vec<u32>> = corpus
        .sentences
        .iter()
        .map(|s| {
            s.iter()
                .map(|&id| vocab.id(&corpus.words[id as usize]).unwrap())
                .collect()
        })
        .collect();
    (vocab, Arc::new(sentences))
}

fn cfg(threads: usize) -> TrainConfig {
    TrainConfig {
        dim: 16,
        window: 4,
        negatives: 3,
        epochs: 2,
        subsample: 0.0,
        sentence_chunk: 32,
        threads,
        seed: 5,
        ..TrainConfig::default()
    }
}

/// threads = 1 must be bit-reproducible: same seed, same corpus, same
/// bits out, run after run.
#[test]
fn hogwild_threads1_bit_identical_across_runs() {
    let (vocab, sents) = tiny_corpus(20_000);
    let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
    let run = || {
        let mut tr = FullW2vTrainer::new(&cfg(1), &vocab, total);
        train_all(&mut tr, &sents, 2).unwrap();
        (tr.model().syn0.clone(), tr.model().syn1.clone())
    };
    let (a0, a1) = run();
    let (b0, b1) = run();
    assert_eq!(a0, b0, "syn0 must be bit-identical across runs");
    assert_eq!(a1, b1, "syn1 must be bit-identical across runs");
}

/// The driver feeds every kernel the same deterministic stream, so the
/// serial baselines are bit-reproducible through it too.
#[test]
fn hogwild_baselines_bit_identical_across_runs() {
    let (vocab, sents) = tiny_corpus(8_000);
    let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
    for name in ["mikolov", "pword2vec"] {
        let run = || {
            let mut tr =
                build_cpu_trainer(name, &cfg(1), &vocab, total).unwrap();
            tr.train_epoch(&sents, 0).unwrap();
            tr.model().syn0.clone()
        };
        assert_eq!(run(), run(), "{name} must be deterministic at 1 thread");
    }
}

/// The documented update rule on a tiny corpus: one chunk of two words,
/// replayed against a hand-computed pWord2Vec window update with the
/// chunk-shared negatives the kernel draws.  The negative ids are
/// recovered by replaying the worker RNG stream, and the sentence words
/// are chosen to avoid them, so deferred negative write-back and
/// immediate scatter coincide and the oracle is exact.
#[test]
fn hogwild_fullw2v_matches_pword2vec_window_oracle() {
    let vocab =
        Vocab::from_counts((0..40).map(|i| (format!("w{i}"), 10u64)), 1);
    let cfg = TrainConfig {
        dim: 4,
        window: 2, // wf = 1
        negatives: 2,
        epochs: 1,
        subsample: 0.0,
        sentence_chunk: 8,
        threads: 1,
        seed: 9,
        lr: 0.025,
        ..TrainConfig::default()
    };
    let d = cfg.dim;

    // replay the worker stream to learn the chunk's negative draws
    let mut rng = hogwild::worker_rng(cfg.seed, 0, 0);
    let table = UnigramTable::new(&vocab, UnigramTable::DEFAULT_ALPHA);
    let negs = [table.sample(&mut rng), table.sample(&mut rng)];
    assert_ne!(negs[0], negs[1], "pick another seed: duplicate negatives");
    // sentence words disjoint from the negatives
    let words: Vec<u32> =
        (0u32..40).filter(|w| !negs.contains(w)).take(2).collect();
    let (wa, wb) = (words[0], words[1]);

    // planted model state
    let mut tr = FullW2vTrainer::new(&cfg, &vocab, 2);
    for id in 0..40u32 {
        let v: Vec<f32> = (0..d)
            .map(|j| 0.01 * (id as f32 + 1.0) * (j as f32 + 1.0) - 0.05)
            .collect();
        tr.model_mut().syn0_row_mut(id).copy_from_slice(&v);
        let u: Vec<f32> = (0..d)
            .map(|j| 0.02 * (j as f32 + 1.0) - 0.015 * (id as f32 % 5.0))
            .collect();
        tr.model_mut().syn1_row_mut(id).copy_from_slice(&u);
    }

    // oracle: pWord2Vec window updates with the shared negatives, f32,
    // same kernel order (positive column first, then negatives in draw
    // order), lr exactly lr0 for the first chunk
    let mut syn0: Vec<Vec<f32>> =
        (0..40u32).map(|id| tr.model().syn0_row(id).to_vec()).collect();
    let mut syn1: Vec<Vec<f32>> =
        (0..40u32).map(|id| tr.model().syn1_row(id).to_vec()).collect();
    let lr = cfg.lr;
    let sent = [wa, wb];
    for t in 0..2usize {
        let center = sent[t] as usize;
        let ctx = sent[1 - t] as usize;
        let c = syn0[ctx].clone();
        let u0 = syn1[center].clone();
        let uk: Vec<Vec<f32>> =
            negs.iter().map(|&g| syn1[g as usize].clone()).collect();
        let z0 = dot(&c, &u0);
        let g0 = (1.0 - sigmoid(z0)) * lr;
        let gk: Vec<f32> = uk
            .iter()
            .map(|u| {
                let z = dot(&c, u);
                (0.0 - sigmoid(z)) * lr
            })
            .collect();
        // dC from pre-update U, same column order as the kernel
        for j in 0..d {
            let mut dc = g0 * u0[j];
            for (k, u) in uk.iter().enumerate() {
                dc += gk[k] * u[j];
            }
            syn0[ctx][j] += dc;
        }
        // dU from pre-update C
        for j in 0..d {
            syn1[center][j] += g0 * c[j];
        }
        for (k, &g) in negs.iter().enumerate() {
            for j in 0..d {
                syn1[g as usize][j] += gk[k] * c[j];
            }
        }
    }

    let sents = Arc::new(vec![vec![wa, wb]]);
    tr.train_epoch(&sents, 0).unwrap();

    for id in 0..40u32 {
        let got0 = tr.model().syn0_row(id);
        let got1 = tr.model().syn1_row(id);
        for j in 0..d {
            assert!(
                (got0[j] - syn0[id as usize][j]).abs() < 1e-6,
                "syn0[{id}][{j}]: got {} want {}",
                got0[j],
                syn0[id as usize][j]
            );
            assert!(
                (got1[j] - syn1[id as usize][j]).abs() < 1e-6,
                "syn1[{id}][{j}]: got {} want {}",
                got1[j],
                syn1[id as usize][j]
            );
        }
    }
}

/// Hogwild at N threads must land in the same loss region as serial.
#[test]
fn hogwild_threads4_loss_within_tolerance_of_serial() {
    let (vocab, sents) = tiny_corpus(30_000);
    let total: u64 = sents.iter().map(|s| s.len() as u64).sum();

    let mut serial = FullW2vTrainer::new(&cfg(1), &vocab, total);
    let rep1 = train_all(&mut serial, &sents, 2).unwrap();
    let (_, loss1) = rep1.loss_trajectory();

    let mut par = FullW2vTrainer::new(&cfg(4), &vocab, total);
    let rep4 = train_all(&mut par, &sents, 2).unwrap();
    let (_, loss4) = rep4.loss_trajectory();
    assert_eq!(rep4.epochs[0].threads, 4, "4 workers must actually run");

    assert!(
        (loss4 - loss1).abs() < 0.2 * loss1,
        "parallel loss {loss4} strays from serial {loss1}"
    );
    // both trained the same number of words (subsampling off)
    assert_eq!(rep1.total_words(), rep4.total_words());
}

/// All four CPU implementations run through the shared driver, in
/// parallel, and converge.
#[test]
fn hogwild_all_cpu_impls_train_through_driver() {
    let (vocab, sents) = tiny_corpus(8_000);
    let total: u64 = sents.iter().map(|s| s.len() as u64).sum();
    for name in CPU_IMPLS {
        let mut tr =
            build_cpu_trainer(name, &cfg(2), &vocab, total).unwrap();
        let rep = train_all(&mut tr, &sents, 2).unwrap();
        let (first, last) = rep.loss_trajectory();
        assert!(last < first, "{name}: loss did not decrease {first}->{last}");
        assert_eq!(rep.epochs[0].threads, 2, "{name}: 2 workers");
        assert!(rep.total_words() > 0);
    }
}

/// The accounting fix: a sentence spanning several chunks reports one
/// batch per chunk and decays the lr over the chunks, not once per
/// sentence.
#[test]
fn hogwild_accounting_is_per_chunk() {
    let vocab =
        Vocab::from_counts((0..20).map(|i| (format!("w{i}"), 10u64)), 1);
    let mut cfg = cfg(1);
    cfg.sentence_chunk = 16;
    cfg.window = 2;
    cfg.epochs = 1;
    // one 48-word sentence -> 3 chunks of 16
    let sent: Vec<u32> = (0..48u32).map(|i| i % 20).collect();
    let sents = Arc::new(vec![sent]);
    let mut tr = FullW2vTrainer::new(&cfg, &vocab, 48);
    let rep = tr.train_epoch(&sents, 0).unwrap();
    assert_eq!(rep.batches, 3, "batches must count chunks, not sentences");
    assert_eq!(rep.words, 48);
    // lr after the epoch reflects all 48 words through the schedule
    let probe = fullw2v::coordinator::lr::LrSchedule::new(
        cfg.lr,
        cfg.min_lr_ratio,
        48,
    );
    assert_eq!(rep.lr_end.to_bits(), probe.lr_at(48).to_bits());
    // and the negative block was loaded once per chunk
    assert_eq!(rep.neg_rows_loaded, 3 * cfg.negatives as u64);
}
