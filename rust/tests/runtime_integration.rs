//! Runtime integration: load real AOT artifacts, execute on the PJRT CPU
//! client, and validate numerics against a Rust re-implementation of the
//! window-matrix oracle.  Requires `make artifacts` to have run.

use fullw2v::runtime::{Engine, StepInputs};
use fullw2v::util::rng::Pcg32;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Rust-side oracle: shared-negative window-matrix SGNS, identical to
/// python/compile/kernels/ref.py::sgns_window_ref.
#[allow(clippy::too_many_arguments)]
fn window_oracle(
    syn0: &[f32],
    syn1: &[f32],
    neg: &[f32],
    lens: &[i32],
    lr: f32,
    b: usize,
    s: usize,
    n: usize,
    d: usize,
    wf: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut s0 = syn0.to_vec();
    let mut s1 = syn1.to_vec();
    let mut ng = neg.to_vec();
    let mut loss = vec![0.0f32; b];
    let sigmoid = |x: f32| 1.0 / (1.0 + (-x).exp());
    let softplus = |x: f32| (x as f64).exp().ln_1p() as f32;
    for bi in 0..b {
        let len = lens[bi] as usize;
        for t in 0..len.min(s) {
            let ctx: Vec<usize> = (t.saturating_sub(wf)..=(t + wf).min(len - 1))
                .filter(|&j| j != t)
                .collect();
            if ctx.is_empty() {
                continue;
            }
            let m = ctx.len();
            let cols = n + 1;
            // gather U = [center; negs]
            let mut u = vec![0.0f32; cols * d];
            u[0..d].copy_from_slice(
                &s1[(bi * s + t) * d..(bi * s + t + 1) * d],
            );
            for k in 0..n {
                let src = ((bi * s + t) * n + k) * d;
                u[(k + 1) * d..(k + 2) * d]
                    .copy_from_slice(&ng[src..src + d]);
            }
            // G and loss
            let mut g = vec![0.0f32; m * cols];
            for (i, &j) in ctx.iter().enumerate() {
                let c = &s0[(bi * s + j) * d..(bi * s + j + 1) * d];
                for k in 0..cols {
                    let z: f32 = c
                        .iter()
                        .zip(&u[k * d..(k + 1) * d])
                        .map(|(x, y)| x * y)
                        .sum();
                    let label = if k == 0 { 1.0 } else { 0.0 };
                    g[i * cols + k] = (label - sigmoid(z)) * lr;
                    loss[bi] += if k == 0 { softplus(-z) } else { softplus(z) };
                }
            }
            // dU then dC (pre-update operands)
            let mut du = vec![0.0f32; cols * d];
            for (i, &j) in ctx.iter().enumerate() {
                let c = s0[(bi * s + j) * d..(bi * s + j + 1) * d].to_vec();
                for k in 0..cols {
                    let gg = g[i * cols + k];
                    for x in 0..d {
                        du[k * d + x] += gg * c[x];
                    }
                }
            }
            let mut dc = vec![0.0f32; m * d];
            for i in 0..m {
                for k in 0..cols {
                    let gg = g[i * cols + k];
                    for x in 0..d {
                        dc[i * d + x] += gg * u[k * d + x];
                    }
                }
            }
            for (i, &j) in ctx.iter().enumerate() {
                for x in 0..d {
                    s0[(bi * s + j) * d + x] += dc[i * d + x];
                }
            }
            for x in 0..d {
                s1[(bi * s + t) * d + x] += du[x];
            }
            for k in 0..n {
                let dst = ((bi * s + t) * n + k) * d;
                for x in 0..d {
                    ng[dst + x] += du[(k + 1) * d + x];
                }
            }
        }
    }
    let d0: Vec<f32> = s0.iter().zip(syn0).map(|(a, b)| a - b).collect();
    let d1: Vec<f32> = s1.iter().zip(syn1).map(|(a, b)| a - b).collect();
    let dn: Vec<f32> = ng.iter().zip(neg).map(|(a, b)| a - b).collect();
    (d0, d1, dn, loss)
}

fn random_inputs(
    b: usize,
    s: usize,
    n: usize,
    d: usize,
    seed: u64,
) -> StepInputs {
    let mut rng = Pcg32::new(seed);
    let mut randv = |len: usize| -> Vec<f32> {
        (0..len).map(|_| (rng.next_f32() - 0.5) * 0.8).collect()
    };
    let syn0 = randv(b * s * d);
    let syn1 = randv(b * s * d);
    let neg = randv(b * s * n * d);
    let mut rng2 = Pcg32::new(seed + 1);
    let lens: Vec<i32> =
        (0..b).map(|_| rng2.next_bounded(s as u32 + 1) as i32).collect();
    StepInputs { syn0, syn1, neg, lens, lr: 0.025 }
}

#[test]
fn engine_lists_manifest() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let engine = Engine::new(&artifacts_dir()).unwrap();
    assert!(engine.manifest().executables.len() >= 4);
    assert!(engine.platform().to_lowercase().contains("cpu")
        || engine.platform().to_lowercase().contains("host"));
    for variant in ["full_w2v", "full_register", "acc_sgns", "wombat"] {
        assert!(
            !engine.manifest().by_variant(variant).is_empty(),
            "missing variant {variant}"
        );
    }
}

#[test]
fn quickstart_artifact_matches_oracle() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let step = engine.load("full_w2v_b16_s16_d64_n5_w3").unwrap();
    let (b, s, n, d, wf) = (16, 16, 5, 64, 3);
    let inp = random_inputs(b, s, n, d, 7);
    let out = engine.run(&step, &inp).unwrap();
    let (d0, d1, dn, loss) = window_oracle(
        &inp.syn0, &inp.syn1, &inp.neg, &inp.lens, inp.lr, b, s, n, d, wf,
    );
    let check = |got: &[f32], want: &[f32], name: &str| {
        assert_eq!(got.len(), want.len(), "{name} length");
        let max_err = got
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-4, "{name} max err {max_err}");
    };
    check(&out.d_syn0, &d0, "d_syn0");
    check(&out.d_syn1, &d1, "d_syn1");
    check(&out.d_neg, &dn, "d_neg");
    check(&out.loss, &loss, "loss");
    assert!(out.loss.iter().any(|&l| l > 0.0));
}

#[test]
fn full_and_register_artifacts_agree() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let a = engine.load("full_w2v_b64_s32_d128_n5_w3").unwrap();
    let b_ = engine.load("full_register_b64_s32_d128_n5_w3").unwrap();
    let inp = random_inputs(64, 32, 5, 128, 11);
    let out_a = engine.run(&a, &inp).unwrap();
    let out_b = engine.run(&b_, &inp).unwrap();
    let close = |x: &[f32], y: &[f32]| {
        x.iter().zip(y).all(|(p, q)| (p - q).abs() < 3e-4)
    };
    assert!(close(&out_a.d_syn0, &out_b.d_syn0));
    assert!(close(&out_a.d_syn1, &out_b.d_syn1));
    assert!(close(&out_a.d_neg, &out_b.d_neg));
}

#[test]
fn zero_lr_zero_deltas() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let step = engine.load("full_w2v_b16_s16_d64_n5_w3").unwrap();
    let mut inp = random_inputs(16, 16, 5, 64, 3);
    inp.lr = 0.0;
    let out = engine.run(&step, &inp).unwrap();
    assert!(out.d_syn0.iter().all(|&x| x == 0.0));
    assert!(out.d_syn1.iter().all(|&x| x == 0.0));
    assert!(out.d_neg.iter().all(|&x| x == 0.0));
}

#[test]
fn wrong_buffer_size_rejected() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let step = engine.load("full_w2v_b16_s16_d64_n5_w3").unwrap();
    let mut inp = random_inputs(16, 16, 5, 64, 3);
    inp.syn0.pop();
    assert!(step.run(&inp).is_err());
}

#[test]
fn unknown_executable_is_helpful_error() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let err = match engine.load("nonexistent_kernel") {
        Ok(_) => panic!("expected error"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("not in manifest"));
    assert!(err.contains("full_w2v"), "error should list alternatives");
}
